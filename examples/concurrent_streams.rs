//! Concurrent-stream characterization demo (paper §6 / Figs 4-5).
//!
//! Sweeps stream counts for FP32/FP16/FP8 GEMMs on the simulated ACE
//! set and prints the speedup / overlap / fairness trade-off, ending
//! with the coordinator's recommendation for each objective.
//!
//! Run: `cargo run --release --example concurrent_streams`

use mi300a_char::config::Config;
use mi300a_char::coordinator::{decide_concurrency, Objective};
use mi300a_char::isa::Precision;
use mi300a_char::metrics::{fairness, Summary};
use mi300a_char::report::Table;
use mi300a_char::sim::{ConcurrencyProfile, Engine, KernelDesc};

fn main() {
    let cfg = Config::mi300a();
    let engine = Engine::new(&cfg, ConcurrencyProfile::ace());

    let mut table = Table::new(
        "ACE concurrency: speedup vs fairness (512^3 GEMM, 100 iters)",
        &["precision", "streams", "speedup", "overlap", "fairness", "cv"],
    );
    for p in [Precision::F32, Precision::F16, Precision::Fp8] {
        for streams in [2usize, 4, 8] {
            let ks =
                vec![KernelDesc::gemm(512, p).with_iters(100); streams];
            let sp = engine.speedup(&ks, cfg.seed + 1);
            let run = engine.run(&ks, cfg.seed + 1);
            let totals = run.per_stream_totals();
            table.row(vec![
                p.name().into(),
                streams.to_string(),
                format!("{sp:.2}x"),
                format!("{:.0}%", run.overlap_efficiency * 100.0),
                format!("{:.3}", fairness(&totals)),
                format!("{:.2}", Summary::of(&totals).cv()),
            ]);
        }
    }
    println!("{}", table.render());

    println!("coordinator recommendations (paper §9.2):");
    for (label, obj) in [
        ("latency-sensitive", Objective::LatencySensitive),
        ("throughput-oriented", Objective::ThroughputOriented),
        ("strict isolation", Objective::StrictIsolation),
    ] {
        let d = decide_concurrency(obj, Precision::Fp8, 8);
        println!(
            "  {label:<20} -> {} streams (fairness {:.3}{})",
            d.streams,
            d.expected_fairness,
            if d.use_process_isolation {
                ", process-level isolation"
            } else {
                ""
            }
        );
    }
}
