//! Mixed-precision pipeline demo (paper §8.3 / Fig 16).
//!
//! Runs the real FP32 -> FP16 -> FP8 chain artifact via PJRT, then
//! shows the simulator's per-precision execution analysis and the
//! precision-aware co-scheduling plan the coordinator derives from it.
//!
//! Run: `make artifacts && cargo run --release --example mixed_precision_pipeline`

use mi300a_char::config::Config;
use mi300a_char::coordinator::{l2_friendly_pair, plan_coschedule};
use mi300a_char::isa::Precision;
use mi300a_char::report::Table;
use mi300a_char::runtime::{Executor, Manifest};
use mi300a_char::sim::{CostModel, KernelDesc};
use mi300a_char::util::rng::Rng;
use mi300a_char::workload::MixedChain;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = Config::mi300a();

    // --- Real numerics through the AOT'd mixed chain. ---
    match Executor::new(&Manifest::default_dir()) {
        Ok(mut exec) => {
            let n = 256;
            let mut rng = Rng::new(3);
            let mk = |scale: f32, rng: &mut Rng| -> Vec<f32> {
                (0..n * n).map(|_| rng.normal() as f32 * scale).collect()
            };
            let x = mk(1.0, &mut rng);
            let w32 = mk(0.1, &mut rng);
            let w16 = mk(0.1, &mut rng);
            let w8 = mk(0.1, &mut rng);
            let t0 = std::time::Instant::now();
            let out = exec.run_f32("mixed_chain_256", &[x, w32, w16, w8])?;
            println!(
                "mixed_chain_256 via PJRT: {} outputs in {:?}, all finite: {}",
                out.len(),
                t0.elapsed(),
                out.iter().all(|v| v.is_finite())
            );
        }
        Err(e) => println!("(artifacts not built: {e})"),
    }

    // --- Per-op execution analysis (Fig 16 axis). ---
    let cost = CostModel::new(&cfg);
    let chain = MixedChain::new(1024);
    let mut t = Table::new(
        "mixed chain per-op analysis (1024^3)",
        &["op", "solo time (µs)", "GFLOPS", "occupancy target"],
    );
    for op in &chain.ops {
        t.row(vec![
            op.name.into(),
            format!("{:.1}", cost.solo_work_ns(&op.kernel) / 1e3),
            format!("{:.0}", cost.solo_gflops(&op.kernel)),
            mi300a_char::coordinator::occupancy_target(op.kernel.precision)
                .to_string(),
        ]);
    }
    println!("\n{}", t.render());

    // --- Precision-aware co-scheduling (§9.2). ---
    let pool: Vec<KernelDesc> = vec![
        KernelDesc::gemm(1024, Precision::Fp8),
        KernelDesc::gemm(1024, Precision::Fp8),
        KernelDesc::gemm(1024, Precision::F32),
        KernelDesc::gemm(1024, Precision::F32),
        KernelDesc::gemm(1024, Precision::F16),
        KernelDesc::gemm(1024, Precision::F16),
    ];
    let groups = plan_coschedule(&pool, 0.1);
    println!("co-schedule plan (fairness floor 0.1):");
    for (i, g) in groups.iter().enumerate() {
        let names: Vec<&str> =
            g.kernels.iter().map(|k| k.precision.name()).collect();
        println!(
            "  group {i}: [{}] occupancy ratio {:.2}",
            names.join(", "),
            g.occupancy_ratio()
        );
    }
    println!(
        "FP8+FP32 L2-friendly pairing: {}",
        l2_friendly_pair(
            &KernelDesc::gemm(1024, Precision::Fp8),
            &KernelDesc::gemm(1024, Precision::F32)
        )
    );
    Ok(())
}
