//! Sparsity advisor (paper §7 + §9.2) on the scenario/job API.
//!
//! The old advisor hand-rolled loops over sizes and stream counts;
//! this one asks the same questions as **one declarative sweep**
//! (docs/scenarios.md, cookbook sweep 3): a `sparsity`-ask
//! ScenarioSpec swept across problem sizes × concurrency contexts,
//! submitted to a served instance as an **async job** with streamed
//! progress callbacks, then rendered as the advisor table. Every point
//! answers byte-identically to the equivalent v1 `sparsity` request —
//! the sweep is purely a better way to ask.
//!
//! Run: `cargo run --release --example sparsity_advisor`

use mi300a_char::api::{Ask, Client, Response, ScenarioSpec};
use mi300a_char::config::Config;
use mi300a_char::serve::serve;
use std::net::TcpListener;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Reserve an ephemeral port, then serve one connection in-process.
    let probe = TcpListener::bind("127.0.0.1:0")?;
    let addr = probe.local_addr()?.to_string();
    drop(probe);
    let bind_addr = addr.clone();
    let server = std::thread::spawn(move || {
        serve(Config::mi300a(), &bind_addr, Some(1))
    });
    let mut client = Client::connect_retry(addr.as_str(), 200)?;

    // The paper's break-even question (Figs 11/13) as data: should 2:4
    // be enabled, across sizes and isolation-vs-concurrency contexts?
    let mut spec = ScenarioSpec::new(Ask::Sparsity);
    spec.n = 512;
    spec.sweep.n = vec![256, 512, 2048, 8192];
    spec.sweep.streams = vec![1, 4];

    println!("submitting sparsity sweep ({} points) as an async job...",
             spec.expand().len());
    let result = client.submit_and_wait(&spec, |p| {
        // One callback per pushed frame: registration snapshot,
        // queued->running, per-point progress, terminal.
        println!(
            "progress {}/{} (job {}, {})",
            p.completed,
            p.total,
            p.job,
            p.state.as_str()
        );
    })?;

    let points = match result {
        Response::Scenario { points } => points,
        other => return Err(format!("unexpected response: {other:?}").into()),
    };

    println!("\n2:4 sparsity advisor (context-dependent, paper §9.2):");
    println!(
        "  {:>6} {:>8}  {:<7} {:>9} {:>11}  reason",
        "n", "streams", "verdict", "isolated", "concurrent"
    );
    for pr in &points {
        if let Response::Sparsity {
            enable,
            reason,
            isolated_speedup,
            concurrent_speedup,
        } = pr.result.as_ref()
        {
            println!(
                "  {:>6} {:>8}  {:<7} {:>8.2}x {:>10.2}x  {}",
                pr.point.n,
                pr.point.streams,
                if *enable { "SPARSE" } else { "dense" },
                isolated_speedup,
                concurrent_speedup,
                reason
            );
        }
    }
    println!(
        "\nthe paper's headline: break-even in isolation, ~1.3x per \
         stream under concurrency — the decision is context, not a \
         constant."
    );

    client.raw_line("QUIT").ok();
    drop(client);
    server.join().expect("server thread panicked")?;
    Ok(())
}
