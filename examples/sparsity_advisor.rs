//! Sparsity advisor demo (paper §7 + §9.2 "Sparsity decisions").
//!
//! Encodes a real matrix to 2:4 with the Rust encoder, validates the
//! compressed form against the AOT'd Pallas sparse-GEMM artifact via
//! PJRT, then walks the coordinator's context-dependent enablement
//! policy across scenarios.
//!
//! Run: `make artifacts && cargo run --release --example sparsity_advisor`

use mi300a_char::config::Config;
use mi300a_char::coordinator::decide_sparsity;
use mi300a_char::isa::Precision;
use mi300a_char::runtime::{Executor, Input, Manifest};
use mi300a_char::sim::{KernelDesc, SparsityMode};
use mi300a_char::sparsity::{compress_2_4, decompress_2_4, prune_2_4,
                            OverheadModel, SpeedupModel};
use mi300a_char::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = Config::mi300a();
    let n = 256;

    // --- Real numerics: encode 2:4 in Rust, execute the Pallas sparse
    //     GEMM artifact, cross-check against the dense f32 artifact on
    //     the decompressed matrix. ---
    match Executor::new(&Manifest::default_dir()) {
        Ok(mut exec) => {
            let mut rng = Rng::new(42);
            let a: Vec<f32> =
                (0..n * n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> =
                (0..n * n).map(|_| rng.normal() as f32 * 0.1).collect();
            let pruned = prune_2_4(&a, n, n);
            let c = compress_2_4(&pruned, n, n);
            let idx: Vec<i32> = c.indices.iter().map(|&i| i as i32).collect();

            let entry = exec.load("gemm_sparse24_256")?;
            let sparse_out = entry.run(&[
                Input::F32(c.values.clone()),
                Input::I32(idx),
                Input::F32(b.clone()),
            ])?;
            let dense_out =
                exec.run_f32("gemm_f32_256", &[decompress_2_4(&c), b])?;
            let max_err = sparse_out
                .iter()
                .zip(&dense_out)
                .map(|(s, d)| (s - d).abs())
                .fold(0.0f32, f32::max);
            println!(
                "sparse-GEMM artifact vs dense-on-decompressed: max |err| \
                 = {max_err:.2e} over {} elements",
                sparse_out.len()
            );
            assert!(max_err < 1e-2, "sparse artifact numerics diverged");
        }
        Err(e) => println!("(artifacts not built: {e})"),
    }

    // --- The paper's overhead + break-even story. ---
    let overhead = OverheadModel::new(&cfg);
    let speedup = SpeedupModel::new(&cfg);
    println!("\nrocSPARSE-path overhead (constant across sizes):");
    for mode in [SparsityMode::SparseLhs, SparsityMode::SparseBoth] {
        println!(
            "  {:>4}: {:.1} µs",
            mode.name(),
            overhead.mean(mode).total_us()
        );
    }
    println!("\nisolated sparse speedup (break-even, Fig 11):");
    for size in [256usize, 512, 2048, 8192] {
        let s = speedup
            .isolated(
                &KernelDesc::gemm(size, Precision::Fp8),
                SparsityMode::SparseLhs,
            )
            .speedup();
        println!("  {size:>5}^3: {s:.2}x");
    }
    println!(
        "concurrent per-stream speedup (Fig 13c): {:.2}x",
        speedup.concurrent_per_stream(&KernelDesc::gemm(512, Precision::Fp8), 4)
    );

    // --- The coordinator's decisions. ---
    println!("\ncoordinator sparsity decisions (§9.2):");
    let square = KernelDesc::gemm(512, Precision::Fp8);
    let rect = square.clone().with_shape(512, 2048, 1024);
    for (label, kernel, streams) in [
        ("isolated square 512^3", &square, 1),
        ("isolated rectangular 512x2048x1024", &rect, 1),
        ("4-way concurrent 512^3", &square, 4),
    ] {
        let d = decide_sparsity(kernel, streams, true);
        println!(
            "  {label:<36} -> {} ({:?})",
            if d.enable { "SPARSE" } else { "dense " },
            d.reason
        );
    }
    Ok(())
}
