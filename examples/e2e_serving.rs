//! End-to-end serving driver (the repo's full-stack validation run).
//!
//! Loads the AOT'd FP8 transformer block (JAX + Pallas kernels, lowered
//! to HLO text at build time), then serves a synthetic request stream
//! through the full coordinator: occupancy-aware continuous batching ->
//! router/ACE dispatch -> PJRT execution. Python is never on this path.
//!
//! Reports batch statistics, per-request latency percentiles, and token
//! throughput; the run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use mi300a_char::config::Config;
use mi300a_char::coordinator::{Batcher, BatcherConfig, Objective, Router,
                               decide_concurrency};
use mi300a_char::isa::Precision;
use mi300a_char::metrics::Summary;
use mi300a_char::runtime::{Executor, Manifest};
use mi300a_char::util::rng::Rng;
use std::time::Instant;

const ENTRY: &str = "transformer_block_128x256";
const SEQ: usize = 128;
const D_MODEL: usize = 256;
const D_FF: usize = 1024;
const N_REQUESTS: usize = 96;

fn weights(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect()
}

fn main() -> anyhow::Result<()> {
    let cfg = Config::mi300a();
    let mut exec = Executor::new(&Manifest::default_dir())?;
    println!("PJRT platform: {}", exec.platform());

    // Model weights (fixed across requests — the served model).
    let mut rng = Rng::new(2026);
    let wqkv = weights(&mut rng, D_MODEL, 3 * D_MODEL, 0.05);
    let wproj = weights(&mut rng, D_MODEL, D_MODEL, 0.05);
    let w1 = weights(&mut rng, D_MODEL, D_FF, 0.05);
    let w2 = weights(&mut rng, D_FF, D_MODEL, 0.05);
    let ln_g = vec![1.0f32; D_MODEL];
    let ln_b = vec![0.0f32; D_MODEL];

    // Compile once (cold start), measured separately from serving.
    let t0 = Instant::now();
    exec.load(ENTRY)?;
    println!("compiled {ENTRY} in {:?}", t0.elapsed());

    // Coordinator: occupancy-aware batching + concurrency governance.
    // One request = one sequence; its GEMMs put seq/128 * width blocks
    // in flight — the batcher accumulates to the FP8 target.
    let waves_per_request = 8; // 128x768 QKV tile blocks at tile 128
    let mut batcher = Batcher::new(BatcherConfig {
        precision: Precision::Fp8,
        deadline_ns: 1_500_000.0, // 1.5 ms batching window
        max_requests: 16,
    });
    let governor = decide_concurrency(
        Objective::ThroughputOriented,
        Precision::Fp8,
        4,
    );
    let mut router = Router::new(governor.streams, cfg.hw.n_aces as usize, 2);
    println!(
        "governor: {} streams (expected fairness {:.2})",
        governor.streams, governor.expected_fairness
    );

    // Synthetic arrival process: bursty Poisson-ish arrivals.
    let mut arrival_rng = Rng::new(7);
    let mut virtual_now = 0.0f64;
    let serve_start = Instant::now();
    let mut latencies_ns: Vec<f64> = Vec::new();
    let mut batches = 0usize;
    let mut batch_sizes = Vec::new();
    let mut served = 0usize;

    while served < N_REQUESTS {
        // Arrivals until the batcher cuts a batch.
        virtual_now += arrival_rng.range(20_000.0, 220_000.0); // 20-220 µs
        batcher.submit(waves_per_request, virtual_now);
        let Some(batch) = batcher.poll(virtual_now) else {
            continue;
        };
        batches += 1;
        batch_sizes.push(batch.requests.len() as f64);

        // Route the batch to a stream/ACE.
        let dispatch = router
            .submit(batches as u64)
            .expect("stream capacity available");

        // Execute the transformer block once per request in the batch
        // (each request is one sequence through the served model).
        for req in &batch.requests {
            let x: Vec<f32> = (0..SEQ * D_MODEL)
                .map(|i| (((i + req.id as usize) % 17) as f32 - 8.0) / 8.0)
                .collect();
            let t = Instant::now();
            let out = exec.run_f32(
                ENTRY,
                &[
                    x,
                    wqkv.clone(),
                    wproj.clone(),
                    w1.clone(),
                    w2.clone(),
                    ln_g.clone(),
                    ln_b.clone(),
                    ln_g.clone(),
                    ln_b.clone(),
                ],
            )?;
            assert_eq!(out.len(), SEQ * D_MODEL);
            assert!(out.iter().all(|v| v.is_finite()));
            // Latency = queueing (virtual) + execution (real).
            let queue_ns = virtual_now - req.arrival_ns;
            latencies_ns.push(queue_ns + t.elapsed().as_nanos() as f64);
            served += 1;
        }
        router.complete(dispatch.stream);
    }

    let wall = serve_start.elapsed();
    let lat = Summary::of(&latencies_ns);
    let bs = Summary::of(&batch_sizes);
    let tokens = served * SEQ;
    println!("\n=== e2e serving results ===");
    println!("requests served : {served} ({batches} batches, mean batch {:.1})", bs.mean);
    println!("wall time       : {:.2} s", wall.as_secs_f64());
    println!(
        "throughput      : {:.1} req/s, {:.0} tokens/s",
        served as f64 / wall.as_secs_f64(),
        tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "latency         : p50 {:.2} ms, p95 {:.2} ms, max {:.2} ms",
        lat.p50 / 1e6,
        lat.p95 / 1e6,
        lat.max / 1e6
    );
    println!(
        "router          : {} dispatched, {} completed, backlog {}",
        router.dispatched,
        router.completed,
        router.backlog_len()
    );
    Ok(())
}
