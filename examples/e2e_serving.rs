//! End-to-end serving driver (the repo's full-stack validation run).
//!
//! Spins up the TCP serving instance in-process, then drives it with
//! concurrent `api::Client` sessions speaking the versioned JSON-line
//! protocol (DESIGN.md §6) — the exact surface production traffic would
//! use, not hand-rolled TCP strings. Each client mixes the three
//! simulator-path request types; one session additionally attempts a
//! real `run` request, which degrades to a typed `runtime` error when
//! the AOT artifacts are absent.
//!
//! Reports per-request latency percentiles, aggregate throughput, and
//! cross-client determinism (every client must see byte-identical
//! answers; the paper's fairness story at the request level). Because
//! every round repeats the same three requests, the serve-side result
//! cache answers all but the first pass — the final `stats` line shows
//! how few cold engine runs the whole load needed (docs/serving.md).
//!
//! Run: `cargo run --release --example e2e_serving`

use mi300a_char::api::{Client, ErrorCode, Request, Response};
use mi300a_char::config::Config;
use mi300a_char::coordinator::Objective;
use mi300a_char::isa::Precision;
use mi300a_char::metrics::Summary;
use std::net::TcpListener;
use std::time::Instant;

const CLIENTS: usize = 4;
const ROUNDS_PER_CLIENT: usize = 24;

/// The request mix one client session cycles through.
fn request_mix() -> Vec<Request> {
    vec![
        Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        Request::Plan {
            objective: Objective::ThroughputOriented,
            streams: 8,
            n: 512,
            precision: Precision::Fp8,
        },
        Request::Sparsity { n: 512, streams: 4 },
    ]
}

/// One client session: `rounds` passes over the mix, returning each
/// response (as its compact wire line, for cross-client comparison) and
/// per-request latency in nanoseconds.
fn session(addr: &str, rounds: usize) -> std::io::Result<(Vec<String>, Vec<f64>)> {
    let mut client = Client::connect_retry(addr, 200)?;
    let mix = request_mix();
    let mut responses = Vec::new();
    let mut latencies_ns = Vec::new();
    for _ in 0..rounds {
        for req in &mix {
            let t0 = Instant::now();
            let (json, _id) = client.request_json(req)?;
            latencies_ns.push(t0.elapsed().as_nanos() as f64);
            responses.push(json.to_string());
        }
    }
    Ok((responses, latencies_ns))
}

fn main() -> std::io::Result<()> {
    let port = {
        let probe = TcpListener::bind("127.0.0.1:0")?;
        probe.local_addr()?.port()
    };
    let addr = format!("127.0.0.1:{port}");
    let server = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            // CLIENTS concurrent sessions + 1 run-path probe.
            mi300a_char::serve::serve(
                Config::mi300a(),
                &addr,
                Some(CLIENTS + 1),
            )
        })
    };

    // --- Concurrent load: CLIENTS sessions over one shared service ---
    let serve_start = Instant::now();
    let workers: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || session(&addr, ROUNDS_PER_CLIENT))
        })
        .collect();
    let mut all_latencies = Vec::new();
    let mut baseline: Option<Vec<String>> = None;
    for (i, w) in workers.into_iter().enumerate() {
        let (responses, latencies) =
            w.join().expect("client thread panicked")?;
        all_latencies.extend(latencies);
        match &baseline {
            None => baseline = Some(responses),
            Some(b) => assert_eq!(
                &responses, b,
                "client {i} diverged: responses must be deterministic"
            ),
        }
    }
    let wall = serve_start.elapsed();

    // --- Run path + service counters (one probe connection) ---
    let mut probe = Client::connect_retry(addr.as_str(), 200)?;
    // A batch answers the whole mix in one envelope; all three repeat
    // earlier requests, so every item is a cache hit.
    let batched = probe.batch(&request_mix())?;
    assert_eq!(batched.len(), request_mix().len());
    let mut cache_line = String::from("stats request failed");
    if let Response::Stats { cache, engine_runs, .. } =
        probe.request(&Request::Stats)?
    {
        cache_line = format!(
            "{} hits / {} misses, {} cold engine runs, {} entries",
            cache.hits, cache.misses, engine_runs, cache.entries
        );
    }
    match probe.request(&Request::Run { entry: "gemm_fp8_128".into() })? {
        Response::Run { entry, outputs, checksum, exec_ms } => println!(
            "run {entry}: {outputs} outputs, checksum {checksum:.4}, \
             {exec_ms:.1} ms"
        ),
        Response::Error { code, message }
            if code == ErrorCode::Runtime =>
        {
            println!("run path degraded gracefully: {message}")
        }
        other => println!("unexpected run response: {other:?}"),
    }
    drop(probe);
    server.join().expect("server thread panicked")?;

    // --- Report ---
    let served = all_latencies.len();
    let lat = Summary::of(&all_latencies);
    println!("\n=== e2e serving results ===");
    println!(
        "requests served : {served} ({CLIENTS} concurrent clients, \
         {ROUNDS_PER_CLIENT} rounds x {} request types)",
        request_mix().len()
    );
    println!("wall time       : {:.2} s", wall.as_secs_f64());
    println!(
        "throughput      : {:.1} req/s",
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "latency         : p50 {:.2} ms, p95 {:.2} ms, max {:.2} ms",
        lat.p50 / 1e6,
        lat.p95 / 1e6,
        lat.max / 1e6
    );
    println!("determinism     : all clients byte-identical");
    println!("result cache    : {cache_line}");
    Ok(())
}
