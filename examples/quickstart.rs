//! Quickstart: the three layers in one page.
//!
//! 1. Execute a real FP8 GEMM artifact (JAX/Pallas -> HLO text -> PJRT).
//! 2. Ask the simulator for the paper's headline occupancy numbers.
//! 3. Ask the coordinator for a scheduling decision.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use mi300a_char::config::Config;
use mi300a_char::coordinator::{occupancy_target, preferred_precision};
use mi300a_char::isa::Precision;
use mi300a_char::runtime::{Executor, Manifest};
use mi300a_char::sim::MicrobenchModel;

fn main() -> anyhow::Result<()> {
    let cfg = Config::mi300a();

    // --- Layer 1+2: real numerics through the AOT'd Pallas FP8 GEMM ---
    let dir = Manifest::default_dir();
    match Executor::new(&dir) {
        Ok(mut exec) => {
            println!("PJRT platform: {}", exec.platform());
            let n = 128;
            let a: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) / 3.0).collect();
            let b: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
            let t0 = std::time::Instant::now();
            let out = exec.run_f32("gemm_fp8_128", &[a, b])?;
            println!(
                "gemm_fp8_128 via PJRT: {} outputs in {:?} (first {:.4})",
                out.len(),
                t0.elapsed(),
                out[0]
            );
        }
        Err(e) => println!("(artifacts not built: {e}; run `make artifacts`)"),
    }

    // --- Layer 3: the simulated MI300A's execution characteristics ---
    let micro = MicrobenchModel::new(&cfg);
    println!("\nFig-2 check (normalized throughput at 256 wavefronts):");
    for p in Precision::SWEEP {
        let pt = &micro.occupancy_sweep(p, &[256])[0];
        println!("  {:>4}: {:5.1}% of peak", p.name(), pt.normalized * 100.0);
    }

    // --- The coordinator's §9 guidance ---
    println!("\nOccupancy targets (paper §9.1):");
    for p in [Precision::Fp8, Precision::F16, Precision::F32] {
        println!("  {:>4}: {} wavefronts", p.name(), occupancy_target(p));
    }
    println!(
        "at 128 achievable wavefronts, prefer {} (paper: 'FP16 at 128 \
         wavefronts outperforms underutilized FP8')",
        preferred_precision(128).name()
    );
    Ok(())
}
