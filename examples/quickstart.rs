//! Quickstart: the typed service API in one page.
//!
//! 1. Start a serving instance in-process on an ephemeral port.
//! 2. Connect `api::Client` and ask the three characterization
//!    questions — a simulated concurrent run, a coordinator plan, a
//!    sparsity decision — over the versioned wire protocol
//!    (DESIGN.md §6). No hand-rolled TCP strings.
//! 3. Re-ask one question in a batch and read the `stats` counters:
//!    the repeat is served from the result cache with zero DES engine
//!    re-execution (docs/serving.md).
//! 4. Print the coordinator's §9 occupancy guidance.
//!
//! Run: `cargo run --release --example quickstart`

use mi300a_char::api::{Client, Request, Response};
use mi300a_char::config::Config;
use mi300a_char::coordinator::{occupancy_target, preferred_precision,
                               Objective};
use mi300a_char::isa::Precision;
use std::net::TcpListener;

fn main() -> std::io::Result<()> {
    // Reserve an ephemeral port, then serve exactly as many connections
    // as the demo uses.
    let port = {
        let probe = TcpListener::bind("127.0.0.1:0")?;
        probe.local_addr()?.port()
    };
    let addr = format!("127.0.0.1:{port}");
    let server = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            mi300a_char::serve::serve(Config::mi300a(), &addr, Some(1))
        })
    };

    let mut client = Client::connect_retry(addr.as_str(), 200)?;

    // --- Simulated MI300A: 4 concurrent FP8 512^3 GEMM streams ---
    match client.request(&Request::Sim {
        n: 512,
        precision: Precision::Fp8,
        streams: 4,
    })? {
        Response::Sim { makespan_ms, speedup_vs_serial, fairness, .. } => {
            println!(
                "sim 512^3 fp8 x4: {makespan_ms:.2} ms makespan, \
                 {speedup_vs_serial:.2}x vs serial, fairness {fairness:.2}"
            );
        }
        other => println!("unexpected response: {other:?}"),
    }

    // --- Coordinator plan for a throughput-oriented pool ---
    match client.request(&Request::Plan {
        objective: Objective::ThroughputOriented,
        streams: 8,
        n: 512,
        precision: Precision::Fp8,
    })? {
        Response::Plan { objective, sparse, groups } => {
            println!(
                "plan ({objective}): {} groups, sparse kernels: {sparse}",
                groups.len()
            );
            for g in &groups {
                println!(
                    "  {} streams, expected fairness {:.2}, isolation {}",
                    g.streams, g.expected_fairness, g.process_isolation
                );
            }
        }
        other => println!("unexpected response: {other:?}"),
    }

    // --- Context-dependent sparsity decision ---
    for streams in [1usize, 4] {
        match client.request(&Request::Sparsity { n: 512, streams })? {
            Response::Sparsity { enable, reason, concurrent_speedup, .. } => {
                println!(
                    "sparsity at {streams} stream(s): enable={enable} \
                     ({reason}), concurrent speedup {concurrent_speedup:.2}x"
                );
            }
            other => println!("unexpected response: {other:?}"),
        }
    }

    // --- Batching + the result cache ---
    // The sim below repeats the very first request: the service answers
    // it from its canonical-key cache, so `stats` shows a hit and an
    // unchanged engine-invocation count for it.
    let batch = client.batch(&[
        Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        Request::Stats,
    ])?;
    if let Response::Stats { cache, engine_runs, .. } = &batch[1] {
        println!(
            "cache after the batch: {} hits / {} misses, {} cold engine \
             runs",
            cache.hits, cache.misses, engine_runs
        );
    }

    drop(client);
    server.join().expect("server thread panicked")?;

    // --- The coordinator's §9 guidance (plain library calls) ---
    println!("\noccupancy targets (paper §9.1):");
    for p in [Precision::Fp8, Precision::F16, Precision::F32] {
        println!("  {:>4}: {} wavefronts", p.name(), occupancy_target(p));
    }
    println!(
        "at 128 achievable wavefronts, prefer {} (paper: 'FP16 at 128 \
         wavefronts outperforms underutilized FP8')",
        preferred_precision(128).name()
    );
    Ok(())
}
