# AOT pipeline (the single build-time Python step): lower every L2 entry
# point to HLO *text* and write artifacts/<name>.hlo.txt + manifest.json.
#
# HLO text — NOT lowered.compile()/.serialize() — is the interchange
# format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
# the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
# the text parser reassigns ids and round-trips cleanly. See
# /opt/xla-example/README.md and gen_hlo.py.
#
# Every entry returns a TUPLE (return_tuple=True on the XlaComputation), so
# the Rust side unwraps with `Literal::to_tuple`.

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _gemm_specs(m, n, k):
    return [_spec((m, k)), _spec((k, n))]


def _sparse_specs(m, n, k):
    return [_spec((m, k // 2)), _spec((m, k // 2), I32), _spec((k, n))]


def _transformer_specs(seq, d_model, d_ff):
    return [
        _spec((seq, d_model)),            # x
        _spec((d_model, 3 * d_model)),    # wqkv
        _spec((d_model, d_model)),        # wproj
        _spec((d_model, d_ff)),           # w1
        _spec((d_ff, d_model)),           # w2
        _spec((d_model,)), _spec((d_model,)),   # ln1 gamma/beta
        _spec((d_model,)), _spec((d_model,)),   # ln2 gamma/beta
    ]


# name -> (callable, [input specs]). Sizes are chosen so every Pallas block
# divides evenly (see kernels/*.py) and artifacts stay small enough to
# compile quickly on the CPU PJRT client.
ENTRIES = {
    # Microbenchmark GEMMs: one per precision the paper sweeps (Figs 2-3).
    "gemm_fp8_128": (model.gemm_fp8, _gemm_specs(128, 128, 128)),
    "gemm_fp8_256": (model.gemm_fp8, _gemm_specs(256, 256, 256)),
    "gemm_fp8_512": (model.gemm_fp8, _gemm_specs(512, 512, 512)),
    "gemm_bf8_256": (model.gemm_bf8, _gemm_specs(256, 256, 256)),
    "gemm_fp8_bf8_256": (model.gemm_fp8_bf8, _gemm_specs(256, 256, 256)),
    "gemm_f16_256": (model.gemm_f16, _gemm_specs(256, 256, 256)),
    "gemm_bf16_256": (model.gemm_bf16, _gemm_specs(256, 256, 256)),
    "gemm_f32_256": (model.gemm_f32, _gemm_specs(256, 256, 256)),
    # Rectangular FP8 GEMM — the aspect-ratio experiments (Fig 3) and the
    # rectangular sparsity win (512x2048x1024, §7.1.2).
    "gemm_fp8_512x2048x1024": (model.gemm_fp8, _gemm_specs(512, 2048, 1024)),
    # 2:4 structured sparsity (§7).
    "gemm_sparse24_256": (model.gemm_sparse24, _sparse_specs(256, 256, 256)),
    "gemm_sparse24_512": (model.gemm_sparse24, _sparse_specs(512, 512, 512)),
    # Case studies (§8).
    "transformer_block_128x256": (
        functools.partial(model.transformer_block, n_heads=4),
        _transformer_specs(128, 256, 1024)),
    "mixed_chain_256": (model.mixed_chain,
                        [_spec((256, 256))] * 4),  # x, w32, w16, w8
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_entry(name: str):
    fn, specs = ENTRIES[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_avals = lowered.out_info
    outs = jax.tree_util.tree_leaves(out_avals)
    return text, specs, outs


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower all L2 entry points")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of entry names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or list(ENTRIES)
    manifest = {"format": "hlo-text", "entries": []}

    for name in names:
        text, specs, outs = lower_entry(name)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name,
            "path": path,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                       for s in specs],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)}
                        for o in outs],
        })
        print(f"lowered {name}: {len(text)} chars, "
              f"{len(specs)} inputs -> {len(outs)} outputs")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['entries'])} entries "
          f"to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
