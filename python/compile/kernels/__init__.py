# L1: Pallas kernels for the paper's compute hot-spots (all interpret=True).
from .attention import attention_pallas
from .fp8_gemm import fp8_gemm_pallas, gemm_pallas
from .sparse_gemm import sparse_gemm_pallas

__all__ = [
    "attention_pallas",
    "fp8_gemm_pallas",
    "gemm_pallas",
    "sparse_gemm_pallas",
]
