# L1 Pallas kernel: FP8xFP8 GEMM with FP32 accumulation.
#
# CDNA3's FP8 MFMA consumes 16x16x32 wavefront tiles (paper Table 3); the
# TPU re-expression (DESIGN.md §Hardware-Adaptation) keeps the same inner
# block contract — fp8(E4M3/E5M2) operands, f32 accumulate — but expresses
# the HBM->VMEM schedule with a Pallas grid + BlockSpec instead of
# threadblock/LDS staging:
#
#   grid = (M/bm, N/bn, K/bk); each (i, j) output tile accumulates over the
#   k axis in VMEM (the o_ref accumulation pattern), with operand tiles cast
#   through the FP8 register format inside the kernel — exactly where the
#   MFMA's operand conversion sits on CDNA3.
#
# interpret=True everywhere: real-TPU lowering emits a Mosaic custom call
# the CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO so
# the same artifact runs under the Rust PJRT runtime.

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FP8_DTYPE, FP8_MAX

# Default block shape: an MXU-friendly multiple of the CDNA3 16x16x32 FP8
# MFMA tile (8x8x2 tiles per block). Kept modest so VMEM footprint stays
# well under budget at every size we AOT (see DESIGN.md §Perf).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 64


def pick_block(dim: int, pref: int, multiple: int = 1) -> int:
    """Largest divisor of `dim` that is <= pref and a multiple of `multiple`.

    Keeps the Pallas grid exact when a dimension (e.g. 3*d_model = 192)
    is not divisible by the preferred MXU-aligned block.
    """
    b = min(pref, dim)
    while b > 1 and (dim % b != 0 or b % multiple != 0):
        b -= multiple if b % multiple == 0 else 1
    return max(b, multiple)


def _fp8_gemm_kernel(a_ref, b_ref, o_ref, *, nk: int, a_fmt: str, b_fmt: str):
    """One (bm, bn) output tile; k-step `pl.program_id(2)` of `nk`."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Operand conversion through the FP8 register format — the value the
    # MFMA would actually see. Scales are folded outside the kernel
    # (per-tensor symmetric), so the cast here is the full quantization.
    a = a_ref[...].astype(FP8_DTYPE[a_fmt]).astype(jnp.float32)
    b = b_ref[...].astype(FP8_DTYPE[b_fmt]).astype(jnp.float32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


def fp8_gemm_pallas(a: jnp.ndarray, b: jnp.ndarray,
                    a_fmt: str = "e4m3", b_fmt: str = "e4m3",
                    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                    bk: int = DEFAULT_BK) -> jnp.ndarray:
    """FP8 GEMM: quantize a (M,K) and b (K,N) to FP8, multiply, f32 accum.

    Per-tensor scales are computed in f32 outside the kernel and re-applied
    to the product (scale_a * scale_b), matching `ref.fp8_gemm_ref`.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)

    # Per-tensor symmetric scaling into the FP8 representable range.
    sa = jnp.maximum(jnp.max(jnp.abs(a)), 1e-12) / FP8_MAX[a_fmt]
    sb = jnp.maximum(jnp.max(jnp.abs(b)), 1e-12) / FP8_MAX[b_fmt]

    nk = k // bk
    kernel = functools.partial(_fp8_gemm_kernel, nk=nk, a_fmt=a_fmt,
                               b_fmt=b_fmt)
    out = pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a / sa, b / sb)
    return out * (sa * sb)


def gemm_pallas(a: jnp.ndarray, b: jnp.ndarray, dtype=jnp.float32,
                bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK) -> jnp.ndarray:
    """Dense GEMM at operand precision `dtype` with f32 accumulation.

    The per-precision analogue of fp8_gemm_pallas used by the FP16/BF16/
    FP32 microbenchmark entry points (paper Fig 2's non-FP8 curves).
    """
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = pick_block(m, bm), pick_block(n, bn), pick_block(k, bk)

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        av = a_ref[...].astype(dtype).astype(jnp.float32)
        bv = b_ref[...].astype(dtype).astype(jnp.float32)
        o_ref[...] += jnp.dot(av, bv, preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
