# Pure-jnp correctness oracles for the Pallas kernels (L1).
#
# Every Pallas kernel in this package has an oracle here implementing the
# same mathematical contract with plain jax.numpy ops. pytest (and the
# hypothesis sweeps in python/tests/) assert allclose between kernel and
# oracle; these oracles are also the source of the golden outputs the Rust
# runtime integration tests compare against.
#
# The FP8 path mirrors CDNA3 MFMA semantics: FP8xFP8 operands with FP32
# accumulation (paper §2 "FP8 Matrix Cores"). Quantization is per-tensor
# symmetric scaling into the representable range of the target format.

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Max finite magnitudes of the two OCP FP8 formats the paper exercises
# (E4M3 aka fp8, E5M2 aka bf8). See OCP OFP8 spec (paper ref [1]).
FP8_MAX = {
    "e4m3": 448.0,
    "e5m2": 57344.0,
}

FP8_DTYPE = {
    "e4m3": jnp.float8_e4m3fn,
    "e5m2": jnp.float8_e5m2,
}


def fp8_scale(x: jnp.ndarray, fmt: str = "e4m3") -> jnp.ndarray:
    """Per-tensor symmetric scale mapping x into the FP8 representable range."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    return amax / FP8_MAX[fmt]


def quantize_fp8(x: jnp.ndarray, fmt: str = "e4m3") -> jnp.ndarray:
    """Quantize-dequantize x through the given FP8 format (values only).

    Returns an f32 tensor holding exactly the values an FP8 register file
    would hold (scaled), i.e. the dequantized operand the MFMA consumes.
    """
    scale = fp8_scale(x, fmt)
    q = (x / scale).astype(FP8_DTYPE[fmt])
    return q.astype(jnp.float32) * scale


def fp8_gemm_ref(a: jnp.ndarray, b: jnp.ndarray,
                 a_fmt: str = "e4m3", b_fmt: str = "e4m3") -> jnp.ndarray:
    """FP8xFP8 GEMM with FP32 accumulation (the MFMA contract)."""
    aq = quantize_fp8(a, a_fmt)
    bq = quantize_fp8(b, b_fmt)
    return jnp.dot(aq, bq, preferred_element_type=jnp.float32)


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Dense GEMM at an arbitrary operand precision with FP32 accumulation."""
    return jnp.dot(a.astype(dtype), b.astype(dtype),
                   preferred_element_type=jnp.float32).astype(jnp.float32)


# ---------------------------------------------------------------------------
# 2:4 structured sparsity (paper §7)
# ---------------------------------------------------------------------------

def prune_2_4_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Zero the 2 smallest-|x| elements of every consecutive group of 4.

    Operates along the last axis, which must be divisible by 4. Mirrors the
    magnitude-based 2:4 pruning rule used by CDNA3/Ampere sparse tensor
    pipelines (paper refs [13, 22]).
    """
    *lead, k = a.shape
    assert k % 4 == 0, f"last dim {k} not divisible by 4"
    g = a.reshape(*lead, k // 4, 4)
    # Rank within each group by |x| descending; keep the top 2.
    order = jnp.argsort(-jnp.abs(g), axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    mask = ranks < 2
    return (g * mask).reshape(a.shape)


def compress_2_4_ref(a: jnp.ndarray):
    """Compress a 2:4-pruned matrix into (values, indices).

    values: (..., k/2) — the two surviving elements per group, in ascending
            position order (matches the metadata layout of sparse MFMA).
    indices: (..., k/2) int32 in [0, 4) — position within the group.
    """
    *lead, k = a.shape
    g = a.reshape(*lead, k // 4, 4)
    nz = jnp.abs(g) > 0
    # Positions sorted so that surviving lanes come first, stable by index.
    # key = (zero?, position) ascending -> nonzeros first, in order.
    pos = jnp.broadcast_to(jnp.arange(4), g.shape)
    key = jnp.where(nz, pos, pos + 4)
    order = jnp.argsort(key, axis=-1)[..., :2]
    vals = jnp.take_along_axis(g, order, axis=-1)
    idx = order.astype(jnp.int32)
    return (vals.reshape(*lead, k // 2), idx.reshape(*lead, k // 2))


def decompress_2_4_ref(vals: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Inverse of compress_2_4_ref: scatter (values, indices) back to dense."""
    *lead, khalf = vals.shape
    k = khalf * 2
    vg = vals.reshape(*lead, khalf // 2, 2)
    ig = idx.reshape(*lead, khalf // 2, 2)
    dense = jnp.sum(
        vg[..., None] * (ig[..., None] == jnp.arange(4)), axis=-2)
    return dense.reshape(*lead, k)


def sparse_gemm_ref(a_vals: jnp.ndarray, a_idx: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """2:4 sparse (LHS) x dense GEMM with FP32 accumulation."""
    a = decompress_2_4_ref(a_vals, a_idx)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Attention / transformer (paper §8.1 case study)
# ---------------------------------------------------------------------------

def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Scaled dot-product attention per head. Shapes: (heads, seq, d_head)."""
    d = q.shape[-1]
    logits = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(d).astype(np.float32)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", w, v)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu_ref(x: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * x * (1.0 + jnp.tanh(
        np.sqrt(2.0 / np.pi).astype(np.float32)
        * (x + 0.044715 * x ** 3)))


def transformer_block_ref(x, wqkv, wproj, w1, w2, ln1_g, ln1_b, ln2_g, ln2_b,
                          n_heads: int) -> jnp.ndarray:
    """Pre-LN transformer block with FP8-quantized GEMMs (the paper's
    'transformer-style FP8 inference kernel': a chain of FP8 GEMMs with
    attention in between).

    x: (seq, d_model); wqkv: (d_model, 3*d_model); wproj: (d_model, d_model);
    w1: (d_model, d_ff); w2: (d_ff, d_model).
    """
    seq, d_model = x.shape
    d_head = d_model // n_heads

    h = layernorm_ref(x, ln1_g, ln1_b)
    qkv = fp8_gemm_ref(h, wqkv)                      # (seq, 3*d_model)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(seq, n_heads, d_head).transpose(1, 0, 2)

    attn = attention_ref(heads(q), heads(k), heads(v))
    attn = attn.transpose(1, 0, 2).reshape(seq, d_model)
    x = x + fp8_gemm_ref(attn, wproj)

    h = layernorm_ref(x, ln2_g, ln2_b)
    h = gelu_ref(fp8_gemm_ref(h, w1))
    return x + fp8_gemm_ref(h, w2)


def mixed_chain_ref(x, w32, w16, w8) -> jnp.ndarray:
    """Mixed-precision operation chain (paper §8.3): FP32 -> FP16 -> FP8."""
    h = gemm_ref(x, w32, jnp.float32)
    h = gemm_ref(h, w16, jnp.float16)
    return fp8_gemm_ref(h, w8)
