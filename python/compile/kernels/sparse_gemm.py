# L1 Pallas kernel: 2:4 structured-sparse GEMM (sparse LHS x dense RHS).
#
# CDNA3's sparse MFMA consumes a compressed LHS (half the K elements) plus
# 2-bit position metadata, expanding lanes inside the matrix engine (paper
# §2 "Structured Sparsity", §7). The TPU re-expression keeps the identical
# operand contract — values (M, K/2) + indices (M, K/2) in [0,4) — and
# performs the metadata expansion as an in-VMEM one-hot contraction before
# the MXU-shaped dot, which is where the hardware's lane-expansion sits.
#
# The kernel therefore does 50% of the dense FLOPs on the A-side fetch and
# exercises the exact decompress-and-multiply semantics the paper's
# rocSPARSE path triggers; the *timing* consequences (constant API
# overhead, contention relief) are modelled in rust/src/sparsity/.

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fp8_gemm import pick_block

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 64  # dense-K per step; compressed-K per step is BK/2


def _sparse_gemm_kernel(av_ref, ai_ref, b_ref, o_ref, *, nk: int):
    """One (bm, bn) tile; expands (vals, idx) to the dense (bm, bk) block."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = av_ref[...]                     # (bm, bk/2)
    idx = ai_ref[...]                      # (bm, bk/2) int32 in [0,4)
    bm, khalf = vals.shape
    # Metadata expansion: each group of 4 dense lanes receives its two
    # surviving values at positions idx. one-hot over the 4 lanes, then
    # fold the 2 survivors: dense (bm, bk/4, 4) -> (bm, bk).
    vg = vals.reshape(bm, khalf // 2, 2)
    ig = idx.reshape(bm, khalf // 2, 2)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 4), 3)
    dense = jnp.sum(vg[..., None] * (ig[..., None] == lanes), axis=-2)
    a = dense.reshape(bm, khalf * 2)

    o_ref[...] += jnp.dot(a, b_ref[...], preferred_element_type=jnp.float32)


def sparse_gemm_pallas(a_vals: jnp.ndarray, a_idx: jnp.ndarray,
                       b: jnp.ndarray,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       bk: int = DEFAULT_BK) -> jnp.ndarray:
    """2:4-sparse LHS (vals (M,K/2) f32, idx (M,K/2) i32) x dense b (K,N)."""
    m, khalf = a_vals.shape
    k = khalf * 2
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert a_idx.shape == a_vals.shape
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk, multiple=4)  # cover whole 2:4 groups
    assert bk % 4 == 0, "dense-K block must cover whole 2:4 groups"

    nk = k // bk
    kernel = functools.partial(_sparse_gemm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk // 2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a_vals, a_idx, b)
