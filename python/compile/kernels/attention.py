# L1 Pallas kernel: per-head scaled dot-product attention.
#
# The transformer-style case study (paper §8.1) is a chain of FP8 GEMMs
# with attention between QKV and the output projection. The attention tile
# itself runs at higher precision (f32 softmax) — matching mixed-precision
# practice where only the GEMMs drop to FP8.
#
# Grid: one program per head; q/k/v blocks live in VMEM for the whole head
# (seq x d_head tiles are small at the AOT'd sizes). interpret=True as
# everywhere (see fp8_gemm.py header).

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]                                   # (seq, d_head)
    k = k_ref[0]
    v = v_ref[0]
    scale = 1.0 / np.sqrt(q.shape[-1]).astype(np.float32)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(w, v, preferred_element_type=jnp.float32)


def attention_pallas(q: jnp.ndarray, k: jnp.ndarray,
                     v: jnp.ndarray) -> jnp.ndarray:
    """Multi-head attention. q, k, v: (heads, seq, d_head) f32."""
    heads, seq, d_head = q.shape
    assert k.shape == q.shape and v.shape == q.shape
    spec = pl.BlockSpec((1, seq, d_head), lambda h: (h, 0, 0))
    return pl.pallas_call(
        _attention_kernel,
        grid=(heads,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((heads, seq, d_head), jnp.float32),
        interpret=True,
    )(q, k, v)
