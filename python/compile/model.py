# L2: JAX compute graphs for the paper's workloads, calling the L1 Pallas
# kernels. These are the functions aot.py lowers to HLO text; the Rust
# coordinator executes the resulting artifacts via PJRT on its hot path.
#
# Entry points mirror the paper's three case-study kernels (§8):
#   * per-precision GEMMs      — the microbenchmark compute (Figs 2-3)
#   * sparse (2:4) GEMM        — §7's sparse path
#   * transformer_block        — §8.1 transformer-style FP8 inference
#   * mixed_chain              — §8.3 FP32 -> FP16 -> FP8 pipeline

from __future__ import annotations

import jax.numpy as jnp

from .kernels import (attention_pallas, fp8_gemm_pallas, gemm_pallas,
                      sparse_gemm_pallas)
from .kernels.ref import layernorm_ref as layernorm
from .kernels.ref import gelu_ref as gelu

# ---------------------------------------------------------------------------
# GEMM entry points (one per precision the paper sweeps)
# ---------------------------------------------------------------------------


def gemm_fp8(a, b):
    """FP8xFP8 GEMM, f32 accumulation (E4M3 operands)."""
    return (fp8_gemm_pallas(a, b, "e4m3", "e4m3"),)


def gemm_bf8(a, b):
    """BF8xBF8 (E5M2) GEMM, f32 accumulation."""
    return (fp8_gemm_pallas(a, b, "e5m2", "e5m2"),)


def gemm_fp8_bf8(a, b):
    """Mixed FP8xBF8 operands — the paper's Table 3 covers all 4 combos."""
    return (fp8_gemm_pallas(a, b, "e4m3", "e5m2"),)


def gemm_f16(a, b):
    return (gemm_pallas(a, b, jnp.float16),)


def gemm_bf16(a, b):
    return (gemm_pallas(a, b, jnp.bfloat16),)


def gemm_f32(a, b):
    return (gemm_pallas(a, b, jnp.float32),)


def gemm_sparse24(a_vals, a_idx, b):
    """2:4 structured-sparse LHS x dense RHS."""
    return (sparse_gemm_pallas(a_vals, a_idx.astype(jnp.int32), b),)


# ---------------------------------------------------------------------------
# Transformer-style FP8 inference kernel (paper §8.1)
# ---------------------------------------------------------------------------


def transformer_block(x, wqkv, wproj, w1, w2, ln1_g, ln1_b, ln2_g, ln2_b,
                      n_heads: int = 4):
    """Pre-LN transformer block; every GEMM is an FP8 Pallas kernel.

    x: (seq, d_model). Weight shapes as in ref.transformer_block_ref.
    """
    seq, d_model = x.shape
    d_head = d_model // n_heads

    h = layernorm(x, ln1_g, ln1_b)
    qkv = fp8_gemm_pallas(h, wqkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(seq, n_heads, d_head).transpose(1, 0, 2)

    attn = attention_pallas(heads(q), heads(k), heads(v))
    attn = attn.transpose(1, 0, 2).reshape(seq, d_model)
    x = x + fp8_gemm_pallas(attn, wproj)

    h = layernorm(x, ln2_g, ln2_b)
    h = gelu(fp8_gemm_pallas(h, w1))
    return (x + fp8_gemm_pallas(h, w2),)


# ---------------------------------------------------------------------------
# Mixed-precision chain (paper §8.3)
# ---------------------------------------------------------------------------


def mixed_chain(x, w32, w16, w8):
    """FP32 GEMM -> FP16 GEMM -> FP8 GEMM, matching ref.mixed_chain_ref."""
    h = gemm_pallas(x, w32, jnp.float32)
    h = gemm_pallas(h, w16, jnp.float16)
    return (fp8_gemm_pallas(h, w8),)
