# pytest: AOT pipeline — every entry lowers to parseable HLO text, the
# manifest round-trips, and golden outputs for the Rust integration tests
# are generated deterministically.

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def small_entries():
    # Keep test-time lowering fast: the smallest representative of each
    # entry family.
    return ["gemm_fp8_128", "gemm_sparse24_256", "mixed_chain_256",
            "transformer_block_128x256"]


class TestLowering:
    def test_all_entries_have_specs_matching_arity(self):
        import inspect
        for name, (fn, specs) in aot.ENTRIES.items():
            target = fn.func if hasattr(fn, "func") else fn
            params = [p for p in
                      inspect.signature(target).parameters.values()
                      if p.default is inspect.Parameter.empty]
            assert len(specs) == len(params), name

    @pytest.mark.parametrize("name", ["gemm_fp8_128", "gemm_sparse24_256"])
    def test_lower_produces_hlo_text(self, name):
        text, specs, outs = aot.lower_entry(name)
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text
        assert len(outs) == 1

    def test_hlo_is_deterministic(self):
        t1, _, _ = aot.lower_entry("gemm_fp8_128")
        t2, _, _ = aot.lower_entry("gemm_fp8_128")
        assert t1 == t2

    def test_fp8_entry_contains_fp8_converts(self):
        # The FP8 cast must survive lowering — otherwise the artifact is
        # silently running full-precision GEMM.
        text, _, _ = aot.lower_entry("gemm_fp8_128")
        assert "f8e4m3fn" in text

    def test_lowered_entry_executes_and_matches_ref(self):
        # Execute the lowered module via jax and compare to the oracle:
        # this is exactly the computation the Rust PJRT client will run.
        rng = np.random.default_rng(11)
        a = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(128, 128)), jnp.float32)
        fn, _ = aot.ENTRIES["gemm_fp8_128"]
        (got,) = jax.jit(fn)(a, b)
        want = ref.fp8_gemm_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


class TestManifest:
    def test_manifest_written(self, tmp_path, small_entries):
        import sys
        argv = sys.argv
        sys.argv = ["aot", "--out-dir", str(tmp_path),
                    "--only", *small_entries[:1]]
        try:
            aot.main()
        finally:
            sys.argv = argv
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["format"] == "hlo-text"
        (entry,) = manifest["entries"]
        assert entry["name"] == small_entries[0]
        assert (tmp_path / entry["path"]).exists()
        assert entry["inputs"][0]["dtype"] == "float32"

    def test_existing_artifacts_match_manifest(self):
        # If `make artifacts` has run, every listed file must exist and
        # hash-match (guards against stale artifacts dir).
        art = os.path.join(os.path.dirname(__file__), "../../artifacts")
        mpath = os.path.join(art, "manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built")
        import hashlib
        manifest = json.loads(open(mpath).read())
        for entry in manifest["entries"]:
            p = os.path.join(art, entry["path"])
            assert os.path.exists(p), entry["name"]
            text = open(p).read()
            assert hashlib.sha256(
                text.encode()).hexdigest() == entry["sha256"], entry["name"]


class TestGoldens:
    """Golden outputs consumed by rust/tests/runtime_golden.rs.

    Inputs are deterministic (iota-derived, exactly representable), so the
    Rust side can regenerate them without reading .npy files.
    """

    def test_write_goldens(self, tmp_path):
        art = os.path.join(os.path.dirname(__file__), "../../artifacts")
        if not os.path.exists(os.path.join(art, "manifest.json")):
            pytest.skip("artifacts not built")
        m, n, k = 128, 128, 128
        # Same deterministic inputs as rust/tests/runtime_golden.rs.
        a = (jnp.arange(m * k, dtype=jnp.float32).reshape(m, k) % 13 - 6) / 3
        b = (jnp.arange(k * n, dtype=jnp.float32).reshape(k, n) % 7 - 3) / 2
        want = ref.fp8_gemm_ref(a, b)
        golden = {
            "entry": "gemm_fp8_128",
            "checksum": float(jnp.sum(want)),
            "corner": [float(want[0, 0]), float(want[0, -1]),
                       float(want[-1, 0]), float(want[-1, -1])],
        }
        out = os.path.join(art, "golden_gemm_fp8_128.json")
        with open(out, "w") as f:
            json.dump(golden, f)
        assert os.path.exists(out)
