# pytest: Pallas kernels vs pure-jnp oracles — the CORE L1 correctness
# signal. hypothesis sweeps shapes/dtypes/formats; every property asserts
# allclose against ref.py.

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (attention_pallas, fp8_gemm_pallas, gemm_pallas,
                             sparse_gemm_pallas)
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# dims chosen to exercise block-edge cases: below/at/above the default
# block shapes (128, 128, 64) while keeping interpret-mode runtimes sane.
dims = st.sampled_from([32, 64, 128, 256])
fp8_fmt = st.sampled_from(["e4m3", "e5m2"])


class TestFp8Gemm:
    @settings(**SETTINGS)
    @given(m=dims, n=dims, k=dims, a_fmt=fp8_fmt, b_fmt=fp8_fmt,
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, n, k, a_fmt, b_fmt, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, m, k), _rand(rng, k, n)
        out = fp8_gemm_pallas(a, b, a_fmt, b_fmt)
        want = ref.fp8_gemm_ref(a, b, a_fmt, b_fmt)
        assert_allclose(out, want, rtol=1e-4, atol=1e-3)

    def test_fp8_quantization_actually_applied(self):
        # FP8 GEMM must differ from exact f32 GEMM on generic data —
        # otherwise the cast was optimized away.
        rng = np.random.default_rng(7)
        a, b = _rand(rng, 64, 64), _rand(rng, 64, 64)
        fp8 = fp8_gemm_pallas(a, b)
        exact = jnp.dot(a, b)
        assert float(jnp.max(jnp.abs(fp8 - exact))) > 1e-3

    def test_exact_on_fp8_grid(self):
        # Powers of two within E4M3 range are exactly representable:
        # quantization must be lossless and the result exact.
        a = jnp.full((32, 32), 2.0, jnp.float32)
        b = jnp.eye(32, dtype=jnp.float32) * 4.0
        out = fp8_gemm_pallas(a, b)
        assert_allclose(out, jnp.full((32, 32), 8.0), rtol=1e-6)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**16))
    def test_block_shape_invariance(self, seed):
        # Result must not depend on the BlockSpec tiling.
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, 128, 128), _rand(rng, 128, 128)
        o1 = fp8_gemm_pallas(a, b, bm=128, bn=128, bk=128)
        o2 = fp8_gemm_pallas(a, b, bm=32, bn=64, bk=32)
        assert_allclose(o1, o2, rtol=1e-5, atol=1e-4)


class TestDenseGemm:
    @settings(**SETTINGS)
    @given(m=dims, n=dims, k=dims,
           dtype=st.sampled_from([jnp.float32, jnp.float16, jnp.bfloat16]),
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, n, k, dtype, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, m, k), _rand(rng, k, n)
        out = gemm_pallas(a, b, dtype)
        want = ref.gemm_ref(a, b, dtype)
        # Blocked k-accumulation reorders the f32 sum vs the oracle's
        # single dot; allow a few ULP of headroom on top of dtype error.
        assert_allclose(out, want, rtol=1e-4, atol=1e-3)

    def test_f32_identity(self):
        a = jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64) / 100.0
        out = gemm_pallas(a, jnp.eye(64, dtype=jnp.float32))
        assert_allclose(out, a, rtol=1e-6)


class TestSparse24:
    @settings(**SETTINGS)
    @given(m=dims, n=dims, k=dims, seed=st.integers(0, 2**16))
    def test_kernel_matches_ref(self, m, n, k, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, m, k), _rand(rng, k, n)
        pruned = ref.prune_2_4_ref(a)
        vals, idx = ref.compress_2_4_ref(pruned)
        out = sparse_gemm_pallas(vals, idx, b)
        want = ref.sparse_gemm_ref(vals, idx, b)
        assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @settings(**SETTINGS)
    @given(m=dims, k=dims, seed=st.integers(0, 2**16))
    def test_prune_is_2_of_4(self, m, k, seed):
        # Property: every consecutive group of 4 has <= 2 nonzeros and
        # the survivors are the 2 largest magnitudes.
        rng = np.random.default_rng(seed)
        a = _rand(rng, m, k)
        pruned = np.asarray(ref.prune_2_4_ref(a))
        groups = pruned.reshape(m, k // 4, 4)
        nnz = (np.abs(groups) > 0).sum(axis=-1)
        assert (nnz <= 2).all()
        # Survivor magnitudes >= dropped magnitudes within each group.
        orig = np.asarray(a).reshape(m, k // 4, 4)
        kept = np.abs(orig) * (np.abs(groups) > 0)
        dropped = np.abs(orig) * (np.abs(groups) == 0)
        assert (kept.min(axis=-1, where=kept > 0, initial=np.inf)
                >= dropped.max(axis=-1) - 1e-6).all()

    @settings(**SETTINGS)
    @given(m=dims, k=dims, seed=st.integers(0, 2**16))
    def test_compress_decompress_roundtrip(self, m, k, seed):
        rng = np.random.default_rng(seed)
        pruned = ref.prune_2_4_ref(_rand(rng, m, k))
        vals, idx = ref.compress_2_4_ref(pruned)
        assert vals.shape == (m, k // 2) and idx.shape == (m, k // 2)
        assert int(jnp.min(idx)) >= 0 and int(jnp.max(idx)) < 4
        back = ref.decompress_2_4_ref(vals, idx)
        assert_allclose(back, pruned, rtol=0, atol=0)

    def test_sparse_halves_flops_exactly(self):
        # The compressed representation is exactly K/2 values per row.
        a = ref.prune_2_4_ref(jnp.ones((8, 16), jnp.float32)
                              * jnp.arange(16, dtype=jnp.float32))
        vals, _ = ref.compress_2_4_ref(a)
        assert vals.size == a.size // 2


class TestAttention:
    @settings(**SETTINGS)
    @given(heads=st.sampled_from([1, 2, 4, 8]),
           seq=st.sampled_from([16, 32, 64, 128]),
           d_head=st.sampled_from([16, 32, 64]),
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, heads, seq, d_head, seed):
        rng = np.random.default_rng(seed)
        q = _rand(rng, heads, seq, d_head)
        k = _rand(rng, heads, seq, d_head)
        v = _rand(rng, heads, seq, d_head)
        assert_allclose(attention_pallas(q, k, v),
                        ref.attention_ref(q, k, v), rtol=1e-5, atol=1e-5)

    def test_softmax_rows_average_values(self):
        # With identical K rows, attention weights are uniform, so the
        # output is the mean of V rows.
        heads, seq, d = 2, 8, 16
        q = jnp.ones((heads, seq, d), jnp.float32)
        k = jnp.ones((heads, seq, d), jnp.float32)
        v = jnp.asarray(np.random.default_rng(3).normal(
            size=(heads, seq, d)), jnp.float32)
        out = attention_pallas(q, k, v)
        assert_allclose(out, jnp.broadcast_to(
            v.mean(axis=1, keepdims=True), v.shape), rtol=1e-5, atol=1e-6)
