# pytest: L2 model graphs vs oracles — shapes, numerics, composition.

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def _params(seq=64, d_model=128, d_ff=256, seed=0):
    rng = np.random.default_rng(seed)

    def r(*shape, scale=0.1):
        return jnp.asarray(rng.normal(scale=scale, size=shape), jnp.float32)

    return dict(
        x=r(seq, d_model, scale=1.0),
        wqkv=r(d_model, 3 * d_model),
        wproj=r(d_model, d_model),
        w1=r(d_model, d_ff),
        w2=r(d_ff, d_model),
        ln1_g=jnp.ones((d_model,), jnp.float32),
        ln1_b=jnp.zeros((d_model,), jnp.float32),
        ln2_g=jnp.ones((d_model,), jnp.float32),
        ln2_b=jnp.zeros((d_model,), jnp.float32),
    )


class TestTransformerBlock:
    def test_matches_ref(self):
        p = _params()
        (out,) = model.transformer_block(n_heads=4, **p)
        want = ref.transformer_block_ref(n_heads=4, **p)
        assert_allclose(out, want, rtol=1e-4, atol=1e-4)

    def test_output_shape_and_dtype(self):
        p = _params(seq=32, d_model=64, d_ff=128)
        (out,) = model.transformer_block(n_heads=2, **p)
        assert out.shape == (32, 64)
        assert out.dtype == jnp.float32

    def test_residual_path(self):
        # With zero weights the block must be the identity (residuals only).
        p = _params()
        for k in ("wqkv", "wproj", "w1", "w2"):
            p[k] = jnp.zeros_like(p[k])
        (out,) = model.transformer_block(n_heads=4, **p)
        assert_allclose(out, p["x"], rtol=1e-6)

    @pytest.mark.parametrize("n_heads", [1, 2, 4])
    def test_head_count_sweep(self, n_heads):
        p = _params(seq=32, d_model=64, d_ff=128)
        (out,) = model.transformer_block(n_heads=n_heads, **p)
        want = ref.transformer_block_ref(n_heads=n_heads, **p)
        assert_allclose(out, want, rtol=1e-4, atol=1e-4)


class TestMixedChain:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)

        def r(*s):
            return jnp.asarray(rng.normal(scale=0.1, size=s), jnp.float32)

        x, w32, w16, w8 = r(64, 64), r(64, 64), r(64, 64), r(64, 64)
        (out,) = model.mixed_chain(x, w32, w16, w8)
        want = ref.mixed_chain_ref(x, w32, w16, w8)
        assert_allclose(out, want, rtol=1e-4, atol=1e-4)

    def test_precision_ladder_degrades(self):
        # The chain's error vs an all-f32 chain must be dominated by the
        # FP8 stage (the coarsest format), not the FP16 stage.
        rng = np.random.default_rng(2)

        def r(*s):
            return jnp.asarray(rng.normal(scale=0.5, size=s), jnp.float32)

        x, w32, w16, w8 = r(64, 64), r(64, 64), r(64, 64), r(64, 64)
        exact = x @ w32 @ w16 @ w8
        (mixed,) = model.mixed_chain(x, w32, w16, w8)
        # FP16-only chain for comparison.
        f16 = ref.gemm_ref(ref.gemm_ref(x, w32, jnp.float16), w16,
                           jnp.float16) @ w8
        err_mixed = float(jnp.max(jnp.abs(mixed - exact)))
        err_f16 = float(jnp.max(jnp.abs(f16 - exact)))
        assert err_mixed > err_f16 * 0.5  # FP8 stage dominates


class TestGemmEntries:
    @pytest.mark.parametrize("fn,oracle", [
        (model.gemm_fp8, lambda a, b: ref.fp8_gemm_ref(a, b)),
        (model.gemm_bf8, lambda a, b: ref.fp8_gemm_ref(a, b, "e5m2", "e5m2")),
        (model.gemm_fp8_bf8,
         lambda a, b: ref.fp8_gemm_ref(a, b, "e4m3", "e5m2")),
        (model.gemm_f16, lambda a, b: ref.gemm_ref(a, b, jnp.float16)),
        (model.gemm_bf16, lambda a, b: ref.gemm_ref(a, b, jnp.bfloat16)),
        (model.gemm_f32, lambda a, b: ref.gemm_ref(a, b, jnp.float32)),
    ])
    def test_entry_matches_oracle(self, fn, oracle):
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        (out,) = fn(a, b)
        assert_allclose(out, oracle(a, b), rtol=1e-4, atol=1e-3)

    def test_sparse_entry(self):
        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        vals, idx = ref.compress_2_4_ref(ref.prune_2_4_ref(a))
        (out,) = model.gemm_sparse24(vals, idx, b)
        assert_allclose(out, ref.sparse_gemm_ref(vals, idx, b),
                        rtol=1e-5, atol=1e-5)
