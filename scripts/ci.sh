#!/usr/bin/env bash
# Tier-1 verification, a formatting gate, a rustdoc gate (warnings are
# errors), a relative-link check over the docs/ guidebook, a bench
# smoke pass so the `cargo bench` targets (and their BENCH_*.json
# emitters) cannot bit-rot, and a client-vs-serve smoke over the
# versioned wire protocol (DESIGN.md §6) including a batch +
# cache-stats request.
#
# Usage: scripts/ci.sh
#
# Environment:
#   MI300A_BENCH_OUT    where BENCH_*.json baselines land (default: rust/)
#   MI300A_CHAR_THREADS worker count for parallel sweeps (default: nproc)
#   MI300A_FMT_STRICT   1 = fail on rustfmt drift (default: warn only,
#                       until the pre-gate tree is formatted)
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== rustfmt: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    if ! cargo fmt --all -- --check; then
        if [ "${MI300A_FMT_STRICT:-0}" = "1" ]; then
            echo "rustfmt drift (MI300A_FMT_STRICT=1)" >&2
            exit 1
        fi
        echo "warning: rustfmt drift (set MI300A_FMT_STRICT=1 to enforce)"
    fi
else
    echo "rustfmt not installed; skipping format check"
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== rustdoc: cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== docs: relative-link check (README.md + docs/*.md) =="
link_fail=0
for f in ../README.md ../docs/*.md; do
    # Extract relative markdown link targets: ](path) minus URLs and
    # in-page anchors; strip any #fragment before testing existence.
    links=$(grep -oE '\]\([^)]+\)' "$f" 2>/dev/null \
        | sed -E 's/^\]\(//; s/\)$//; s/#.*$//' \
        | grep -v -E '^(https?|mailto):' | grep -v '^$' || true)
    for link in $links; do
        if [ ! -e "$(dirname "$f")/$link" ]; then
            echo "broken relative link in $f: $link" >&2
            link_fail=1
        fi
    done
done
if [ "$link_fail" != 0 ]; then
    exit 1
fi
echo "docs links ok"

echo "== client-vs-serve smoke (ephemeral port, JSON + batch/stats) =="
bin=target/release/mi300a-char
serve_log=$(mktemp)
"$bin" serve --addr 127.0.0.1:0 --max-conns 2 >"$serve_log" &
serve_pid=$!
trap 'kill "$serve_pid" 2>/dev/null || true' EXIT
addr=""
for _ in $(seq 1 100); do
    addr=$(sed -n 's/^serving on //p' "$serve_log" | head -n 1)
    [ -n "$addr" ] && break
    sleep 0.05
done
if [ -z "$addr" ]; then
    echo "serve did not print its bound address" >&2
    exit 1
fi
resp=$("$bin" client --addr "$addr" \
    '{"v":1,"type":"sim","n":256,"precision":"fp8","streams":2}')
echo "client response: $resp"
for needle in '"v":1' '"type":"sim"' '"speedup_vs_serial"'; do
    if ! printf '%s' "$resp" | grep -qF "$needle"; then
        echo "smoke response missing $needle" >&2
        exit 1
    fi
done
# Second connection: a batch repeating the sim (a cache hit) plus a
# stats item proving the cache answered it (hits >= 1).
batch=$("$bin" client --addr "$addr" \
    '{"v":1,"type":"batch","items":[{"type":"sim","n":256,"precision":"fp8","streams":2},{"type":"stats"}]}')
wait "$serve_pid"
trap - EXIT
echo "batch response: $batch"
for needle in '"type":"batch"' '"cache_hits":1' '"engine_runs":1'; do
    if ! printf '%s' "$batch" | grep -qF "$needle"; then
        echo "batch smoke response missing $needle" >&2
        exit 1
    fi
done
rm -f "$serve_log"

echo "== bench smoke (1 warmup / 1 iter, full targets) =="
MI300A_BENCH_WARMUP=1 MI300A_BENCH_ITERS=1 cargo bench

echo "== bench baselines =="
out_dir="${MI300A_BENCH_OUT:-.}"
for name in hotpath ablations paper_experiments; do
    f="$out_dir/BENCH_$name.json"
    if [ ! -s "$f" ]; then
        echo "missing bench baseline: $f" >&2
        exit 1
    fi
    echo "ok: $f"
done

echo "ci.sh: all green"
