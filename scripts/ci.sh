#!/usr/bin/env bash
# Tier-1 verification plus a bench smoke pass so the `cargo bench`
# targets (and their BENCH_*.json emitters) cannot bit-rot.
#
# Usage: scripts/ci.sh
#
# Environment:
#   MI300A_BENCH_OUT   where BENCH_*.json baselines land (default: rust/)
#   MI300A_CHAR_THREADS worker count for parallel sweeps (default: nproc)
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== bench smoke (1 warmup / 1 iter, full targets) =="
MI300A_BENCH_WARMUP=1 MI300A_BENCH_ITERS=1 cargo bench

echo "== bench baselines =="
out_dir="${MI300A_BENCH_OUT:-.}"
for name in hotpath ablations paper_experiments; do
    f="$out_dir/BENCH_$name.json"
    if [ ! -s "$f" ]; then
        echo "missing bench baseline: $f" >&2
        exit 1
    fi
    echo "ok: $f"
done

echo "ci.sh: all green"
