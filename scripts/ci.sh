#!/usr/bin/env bash
# Tier-1 verification, a strict formatting gate, a rustdoc gate
# (warnings are errors), a relative-link check over the docs/
# guidebook, a bench smoke pass so the `cargo bench` targets (and
# their BENCH_*.json emitters) cannot bit-rot, a client-vs-serve smoke
# over the versioned wire protocol (DESIGN.md §6) including a batch +
# cache-stats request, a job-API smoke (submit a sweep, poll it to
# done, fetch the result, observe >=1 pushed progress frame), and a
# backend-matrix smoke (DESIGN.md §6.8: one sim per registered
# backend, per-backend stats counters, docs/backends.md drift, typed
# unknown_backend on an unregistered id) plus an auto-routing smoke
# (DESIGN.md §6.10: a budgeted `--backend auto` sweep must stream at
# least one refinement frame and split its cold runs across both
# concrete engines while engine_runs_auto stays 0), a multi-APU smoke
# (docs/multi_apu.md, DESIGN.md §6.11: a 4-APU data_parallel device
# sweep over the wire on every available io model — transfer_ms on
# every devices>1 point and never on devices=1, per-backend counters
# splitting des vs analytic, and a typed bad_range probe on devices=5),
# a trace-replay smoke (docs/replay.md, DESIGN.md §6.12: a transform
# sweep over an inline trace through serve on every available io model
# with per-point span counts, a typed unsupported_by_backend refusal
# from `replay --backend analytic`, and a Chrome-trace export with one
# X event per recorded launch), a loadgen smoke (a short
# self-hosted load-generator run per available io model, writing the
# BENCH_serve.json baseline and failing on typed errors or zero
# throughput), and a cluster smoke (2 workers + a coordinator on
# ephemeral ports: a 64-point sweep must split across both workers,
# and a sweep after killing one worker must still complete on the
# survivor — docs/cluster.md, DESIGN.md §6.9).
#
# Usage: scripts/ci.sh
#
# Environment:
#   MI300A_BENCH_OUT    where BENCH_*.json baselines land (default: rust/)
#   MI300A_CHAR_THREADS worker count for parallel sweeps (default: nproc)
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== rustfmt: cargo fmt --check (strict) =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt not installed; skipping format check"
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== rustdoc: cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== docs: relative-link check (README.md + docs/*.md) =="
link_fail=0
for f in ../README.md ../docs/*.md; do
    # Extract relative markdown link targets: ](path) minus URLs and
    # in-page anchors; strip any #fragment before testing existence.
    links=$(grep -oE '\]\([^)]+\)' "$f" 2>/dev/null \
        | sed -E 's/^\]\(//; s/\)$//; s/#.*$//' \
        | grep -v -E '^(https?|mailto):' | grep -v '^$' || true)
    for link in $links; do
        if [ ! -e "$(dirname "$f")/$link" ]; then
            echo "broken relative link in $f: $link" >&2
            link_fail=1
        fi
    done
done
if [ "$link_fail" != 0 ]; then
    exit 1
fi
echo "docs links ok"

echo "== client-vs-serve smoke (ephemeral port, JSON + batch/stats) =="
bin=target/release/mi300a-char
serve_log=$(mktemp)
"$bin" serve --addr 127.0.0.1:0 --max-conns 2 >"$serve_log" &
serve_pid=$!
trap 'kill "$serve_pid" 2>/dev/null || true' EXIT
addr=""
for _ in $(seq 1 100); do
    addr=$(sed -n 's/^serving on //p' "$serve_log" | head -n 1)
    [ -n "$addr" ] && break
    sleep 0.05
done
if [ -z "$addr" ]; then
    echo "serve did not print its bound address" >&2
    exit 1
fi
resp=$("$bin" client --addr "$addr" \
    '{"v":1,"type":"sim","n":256,"precision":"fp8","streams":2}')
echo "client response: $resp"
for needle in '"v":1' '"type":"sim"' '"speedup_vs_serial"'; do
    if ! printf '%s' "$resp" | grep -qF "$needle"; then
        echo "smoke response missing $needle" >&2
        exit 1
    fi
done
# Second connection: a batch repeating the sim (a cache hit) plus a
# stats item proving the cache answered it (hits >= 1).
batch=$("$bin" client --addr "$addr" \
    '{"v":1,"type":"batch","items":[{"type":"sim","n":256,"precision":"fp8","streams":2},{"type":"stats"}]}')
wait "$serve_pid"
trap - EXIT
echo "batch response: $batch"
for needle in '"type":"batch"' '"cache_hits":1' '"engine_runs":1'; do
    if ! printf '%s' "$batch" | grep -qF "$needle"; then
        echo "batch smoke response missing $needle" >&2
        exit 1
    fi
done
rm -f "$serve_log"

echo "== job-API smoke (submit -> poll -> result, progress frames) =="
job_log=$(mktemp)
# No --max-conns: the status-poll loop uses one connection per poll, so
# a cap could exhaust mid-smoke on a slow machine; the trap kills it.
"$bin" serve --addr 127.0.0.1:0 >"$job_log" &
job_pid=$!
trap 'kill "$job_pid" 2>/dev/null || true' EXIT
jaddr=""
for _ in $(seq 1 100); do
    jaddr=$(sed -n 's/^serving on //p' "$job_log" | head -n 1)
    [ -n "$jaddr" ] && break
    sleep 0.05
done
if [ -z "$jaddr" ]; then
    echo "job-smoke serve did not print its bound address" >&2
    exit 1
fi
sub=$("$bin" client --addr "$jaddr" \
    '{"v":1,"type":"submit","spec":{"n":256,"sweep":{"streams":[1,2]}}}')
echo "submit response: $sub"
job=$(printf '%s' "$sub" | sed -n 's/.*"job":\([0-9]*\).*/\1/p')
if [ -z "$job" ]; then
    echo "submit did not return a job id" >&2
    exit 1
fi
state=""
for _ in $(seq 1 200); do
    st=$("$bin" client --addr "$jaddr" \
        "{\"v\":1,\"type\":\"job_status\",\"job\":$job}")
    case "$st" in
        *'"state":"done"'*) state=done; break ;;
        *'"state":"failed"'*|*'"state":"cancelled"'*)
            echo "job $job ended badly: $st" >&2; exit 1 ;;
    esac
    sleep 0.05
done
if [ "$state" != done ]; then
    echo "job $job did not finish" >&2
    exit 1
fi
res=$("$bin" client --addr "$jaddr" \
    "{\"v\":1,\"type\":\"job_result\",\"job\":$job}")
echo "job result: $res"
for needle in '"type":"scenario"' '"points"' '"speedup_vs_serial"'; do
    if ! printf '%s' "$res" | grep -qF "$needle"; then
        echo "job result missing $needle" >&2
        exit 1
    fi
done
# The scenario subcommand submits with progress push and prints one
# "progress k/N" line per frame — at least one must arrive.
watch=$("$bin" scenario --addr "$jaddr" --size 256 --sweep-streams 1,2)
echo "$watch" | head -n 5
if ! printf '%s\n' "$watch" | grep -q '^progress '; then
    echo "no progress frame observed by the scenario watcher" >&2
    exit 1
fi
kill "$job_pid" 2>/dev/null || true
wait "$job_pid" 2>/dev/null || true
trap - EXIT
rm -f "$job_log"

echo "== backend-matrix smoke (one sim per registered backend, docs drift) =="
bk_log=$(mktemp)
"$bin" serve --addr 127.0.0.1:0 >"$bk_log" &
bk_pid=$!
trap 'kill "$bk_pid" 2>/dev/null || true' EXIT
baddr=""
for _ in $(seq 1 100); do
    baddr=$(sed -n 's/^serving on //p' "$bk_log" | head -n 1)
    [ -n "$baddr" ] && break
    sleep 0.05
done
if [ -z "$baddr" ]; then
    echo "backend-smoke serve did not print its bound address" >&2
    exit 1
fi
# Live registry from the wire; each id must answer a sim point and be
# documented in docs/backends.md (REGISTRY <-> docs drift fails here).
discovery=$("$bin" client --addr "$baddr" '{"v":1,"type":"backends"}')
echo "backends: $discovery"
ids=$(printf '%s' "$discovery" | grep -oE '"id":"[a-z_]+"' \
    | sed 's/"id":"//; s/"//')
if [ -z "$ids" ]; then
    echo "backends discovery returned no ids" >&2
    exit 1
fi
for id in $ids; do
    resp=$("$bin" client --addr "$baddr" \
        "{\"v\":1,\"backend\":\"$id\",\"type\":\"sim\",\"n\":256,\"precision\":\"fp8\",\"streams\":2}")
    if ! printf '%s' "$resp" | grep -qF '"speedup_vs_serial"'; then
        echo "backend $id failed the sim smoke: $resp" >&2
        exit 1
    fi
    if ! grep -qF "\`$id\`" ../docs/backends.md; then
        echo "backend $id missing from docs/backends.md" >&2
        exit 1
    fi
done
# Per-backend counters cover every id, and an unregistered id is the
# typed unknown_backend error (registry <-> error-path drift).
stats=$("$bin" client --addr "$baddr" '{"v":1,"type":"stats"}')
for id in $ids; do
    if ! printf '%s' "$stats" | grep -qF "\"engine_runs_$id\""; then
        echo "stats missing engine_runs_$id: $stats" >&2
        exit 1
    fi
done
# (The client decodes locally, so the typed rejection — the same
# protocol path the server runs — lands on stderr with exit 2.)
if bad=$("$bin" client --addr "$baddr" \
    '{"v":1,"backend":"no_such_backend","type":"sim","n":256,"precision":"fp8","streams":2}' 2>&1); then
    echo "unregistered backend did not fail the client: $bad" >&2
    exit 1
else
    echo "unknown-backend probe: $bad"
fi
if ! printf '%s' "$bad" | grep -qF 'unknown_backend'; then
    echo "expected unknown_backend, got: $bad" >&2
    exit 1
fi
# Auto-routing smoke (DESIGN.md §6.10, docs/auto_backend.md): a
# budgeted auto sweep crosses the trust boundary (streams 12 routes to
# the DES, 1 and 4 stay analytic), the budget arms the refinement pass
# (streams 4 re-runs on the DES, streaming a `refined` progress
# frame), and the per-engine counters split while the router's own
# counter stays at zero.
auto_watch=$("$bin" scenario --addr "$baddr" --backend auto \
    --max-error 0.45 --size 512 --sweep-streams 1,4,12)
echo "$auto_watch" | head -n 8
if ! printf '%s\n' "$auto_watch" | grep '^progress ' | grep -q 'refined'; then
    echo "budgeted auto sweep streamed no refinement frame" >&2
    exit 1
fi
auto_stats=$("$bin" client --addr "$baddr" '{"v":1,"type":"stats"}')
echo "auto-smoke stats: $auto_stats"
for eng in des analytic; do
    n=$(printf '%s' "$auto_stats" \
        | sed -n "s/.*\"engine_runs_$eng\":\([0-9]*\).*/\1/p")
    if [ -z "$n" ] || [ "$n" -eq 0 ]; then
        echo "auto smoke: engine_runs_$eng=$n (want > 0)" >&2
        exit 1
    fi
done
n=$(printf '%s' "$auto_stats" \
    | sed -n 's/.*"engine_runs_auto":\([0-9]*\).*/\1/p')
if [ "$n" != 0 ]; then
    echo "auto smoke: engine_runs_auto=$n (must stay 0 by design)" >&2
    exit 1
fi
echo "auto smoke ok (refinement streamed, runs split across engines)"
kill "$bk_pid" 2>/dev/null || true
wait "$bk_pid" 2>/dev/null || true
trap - EXIT
rm -f "$bk_log"

echo "== multi-APU smoke (4-APU data_parallel sweep, both io models) =="
fab_models="threads"
if [ "$(uname -s)" = Linux ]; then
    fab_models="epoll threads"
fi
for model in $fab_models; do
    echo "-- multi-APU --io-model $model --"
    fab_log=$(mktemp)
    "$bin" serve --addr 127.0.0.1:0 --io-model "$model" >"$fab_log" &
    fab_pid=$!
    trap 'kill "$fab_pid" 2>/dev/null || true' EXIT
    faddr=""
    for _ in $(seq 1 100); do
        faddr=$(sed -n 's/^serving on //p' "$fab_log" | head -n 1)
        [ -n "$faddr" ] && break
        sleep 0.05
    done
    if [ -z "$faddr" ]; then
        echo "multi-APU smoke serve did not print its bound address" >&2
        exit 1
    fi
    # The scaling sweep from docs/scenarios.md recipe 5, on the DES:
    # the devices=1 anchor must stay fabric-free while every devices>1
    # point pays a transfer_ms share.
    fresp=$("$bin" client --addr "$faddr" \
        '{"v":1,"type":"scenario","n":256,"shape":"data_parallel","sweep":{"devices":[1,2,4]}}')
    echo "multi-APU sweep ($model): $fresp"
    for needle in '"points"' '"devices":4' '"transfer_ms"'; do
        if ! printf '%s' "$fresp" | grep -qF "$needle"; then
            echo "multi-APU sweep missing $needle" >&2
            exit 1
        fi
    done
    nfab=$(printf '%s' "$fresp" | grep -o '"transfer_ms"' | wc -l)
    if [ "$nfab" -ne 2 ]; then
        echo "want transfer_ms on exactly the 2 devices>1 points, got $nfab" >&2
        exit 1
    fi
    # The same sweep through the analytic closed forms: counters must
    # attribute 3 cold points to each engine (separate cache keys).
    "$bin" client --addr "$faddr" \
        '{"v":1,"type":"scenario","backend":"analytic","n":256,"shape":"data_parallel","sweep":{"devices":[1,2,4]}}' \
        >/dev/null
    fstats=$("$bin" client --addr "$faddr" '{"v":1,"type":"stats"}')
    echo "multi-APU stats ($model): $fstats"
    for needle in '"engine_runs_des":3' '"engine_runs_analytic":3'; do
        if ! printf '%s' "$fstats" | grep -qF "$needle"; then
            echo "multi-APU stats missing $needle" >&2
            exit 1
        fi
    done
    # Typed rejection: a fifth APU does not exist on an MI300A node.
    if fbad=$("$bin" client --addr "$faddr" \
        '{"v":1,"type":"scenario","n":256,"shape":"data_parallel","device_set":{"devices":5}}' 2>&1); then
        echo "devices=5 did not fail the client: $fbad" >&2
        exit 1
    else
        echo "bad-range probe: $fbad"
    fi
    if ! printf '%s' "$fbad" | grep -qF 'bad_range'; then
        echo "expected bad_range, got: $fbad" >&2
        exit 1
    fi
    kill "$fab_pid" 2>/dev/null || true
    wait "$fab_pid" 2>/dev/null || true
    trap - EXIT
    rm -f "$fab_log"
done
echo "multi-APU smoke ok (fabric on the wire, counters split, typed range)"

echo "== trace-replay smoke (transform sweep on the wire, both io models) =="
rp_models="threads"
if [ "$(uname -s)" = Linux ]; then
    rp_models="epoll threads"
fi
rp_trace='[{"n":512,"precision":"fp16","stream":0,"issue_ns":0},{"n":512,"precision":"fp16","stream":1,"issue_ns":1000},{"n":256,"precision":"fp16","stream":0,"issue_ns":400000}]'
for model in $rp_models; do
    echo "-- replay --io-model $model --"
    rp_log=$(mktemp)
    "$bin" serve --addr 127.0.0.1:0 --io-model "$model" >"$rp_log" &
    rp_pid=$!
    trap 'kill "$rp_pid" 2>/dev/null || true' EXIT
    raddr=""
    for _ in $(seq 1 100); do
        raddr=$(sed -n 's/^serving on //p' "$rp_log" | head -n 1)
        [ -n "$raddr" ] && break
        sleep 0.05
    done
    if [ -z "$raddr" ]; then
        echo "replay smoke serve did not print its bound address" >&2
        exit 1
    fi
    # The what-if comparison from docs/scenarios.md recipe 7: an inline
    # 3-launch fp16 trace swept across two transforms in one request.
    rresp=$("$bin" client --addr "$raddr" \
        "{\"v\":1,\"type\":\"scenario\",\"shape\":\"trace\",\"trace\":$rp_trace,\"sweep\":{\"transform\":[\"identity\",\"precision_rewrite:fp8\"]}}")
    echo "replay sweep ($model): $rresp"
    for needle in '"points"' '"transform":"precision_rewrite:fp8"'; do
        if ! printf '%s' "$rresp" | grep -qF "$needle"; then
            echo "replay sweep missing $needle" >&2
            exit 1
        fi
    done
    # Every replayed point reports one span per recorded launch.
    nspans=$(printf '%s' "$rresp" | grep -o '"spans":3' | wc -l)
    if [ "$nspans" -ne 2 ]; then
        echo "want \"spans\":3 on both transform points, got $nspans" >&2
        exit 1
    fi
    kill "$rp_pid" 2>/dev/null || true
    wait "$rp_pid" 2>/dev/null || true
    trap - EXIT
    rm -f "$rp_log"
done
# Typed capability refusal: traces are DES-only, end to end.
if rbad=$("$bin" replay --trace ../docs/traces/transformer.jsonl \
    --backend analytic 2>&1); then
    echo "replay --backend analytic did not fail: $rbad" >&2
    exit 1
else
    echo "analytic-refusal probe: $rbad"
fi
if ! printf '%s' "$rbad" | grep -qF 'unsupported_by_backend'; then
    echo "expected unsupported_by_backend, got: $rbad" >&2
    exit 1
fi
# Chrome-trace export: one X event per launch of the checked-in trace.
rp_chrome=$(mktemp)
"$bin" replay --trace ../docs/traces/transformer.jsonl \
    --chrome-trace "$rp_chrome" >/dev/null
if ! grep -qF '"traceEvents"' "$rp_chrome"; then
    echo "chrome-trace export has no traceEvents array" >&2
    exit 1
fi
nev=$(grep -o '"ph": "X"' "$rp_chrome" | wc -l)
if [ "$nev" -ne 12 ]; then
    echo "want 12 chrome-trace events (one per launch), got $nev" >&2
    exit 1
fi
rm -f "$rp_chrome"
echo "trace-replay smoke ok (sweep on the wire, typed refusal, export)"

echo "== loadgen smoke (self-hosted, ~1s per available io model) =="
# The load generator self-hosts an ephemeral server, drives a short
# mixed window, and exits nonzero on any unexpected typed error or a
# zero-request window; it also writes BENCH_serve.json (checked with
# the other baselines below). Exercise every io model this platform
# has: threads everywhere, epoll on Linux (where it is the default).
models="threads"
if [ "$(uname -s)" = Linux ]; then
    models="epoll threads"
fi
for model in $models; do
    echo "-- loadgen --io-model $model --"
    "$bin" loadgen --io-model "$model" --mix mixed \
        --connections 8 --warmup-ms 200 --duration-ms 1000
done

echo "== cluster smoke (2 workers + coordinator, sweep + worker kill) =="
w1_log=$(mktemp); w2_log=$(mktemp); co_log=$(mktemp)
"$bin" serve --addr 127.0.0.1:0 >"$w1_log" &
w1_pid=$!
"$bin" serve --addr 127.0.0.1:0 >"$w2_log" &
w2_pid=$!
trap 'kill "$w1_pid" "$w2_pid" 2>/dev/null || true' EXIT
w1_addr=""; w2_addr=""
for _ in $(seq 1 100); do
    w1_addr=$(sed -n 's/^serving on //p' "$w1_log" | head -n 1)
    w2_addr=$(sed -n 's/^serving on //p' "$w2_log" | head -n 1)
    [ -n "$w1_addr" ] && [ -n "$w2_addr" ] && break
    sleep 0.05
done
if [ -z "$w1_addr" ] || [ -z "$w2_addr" ]; then
    echo "cluster-smoke workers did not print their bound addresses" >&2
    exit 1
fi
"$bin" serve --addr 127.0.0.1:0 \
    --coordinator --workers "$w1_addr,$w2_addr" >"$co_log" &
co_pid=$!
trap 'kill "$w1_pid" "$w2_pid" "$co_pid" 2>/dev/null || true' EXIT
co_addr=""
for _ in $(seq 1 100); do
    co_addr=$(sed -n 's/^serving on //p' "$co_log" | head -n 1)
    [ -n "$co_addr" ] && break
    sleep 0.05
done
if [ -z "$co_addr" ]; then
    echo "cluster-smoke coordinator did not print its bound address" >&2
    exit 1
fi
# A 64-point sweep through the coordinator, via the unchanged client
# CLI (the watcher prints progress frames, then the merged result).
sweep=$("$bin" scenario --addr "$co_addr" --ask sparsity \
    --sweep-size 32,64,96,128,160,192,224,256 \
    --sweep-streams 1,2,3,4,5,6,7,8)
if ! printf '%s\n' "$sweep" | grep -q '"points"'; then
    echo "cluster sweep returned no points: $sweep" >&2
    exit 1
fi
# Both workers must have executed a share of the 64 points (their
# engine counters are read directly, off the coordinator's path).
for waddr in "$w1_addr" "$w2_addr"; do
    wruns=$("$bin" client --addr "$waddr" '{"v":1,"type":"stats"}' \
        | sed -n 's/.*"engine_runs":\([0-9]*\).*/\1/p')
    if [ -z "$wruns" ] || [ "$wruns" -eq 0 ]; then
        echo "worker $waddr executed no points (engine_runs=$wruns)" >&2
        exit 1
    fi
    echo "worker $waddr engine_runs=$wruns"
done
# Coordinator stats aggregate the fleet and carry the cluster_* block.
co_stats=$("$bin" client --addr "$co_addr" '{"v":1,"type":"stats"}')
echo "coordinator stats: $co_stats"
for needle in '"cluster_workers":2' '"cluster_points_routed":64' \
    '"cluster_point_failures":0'; do
    if ! printf '%s' "$co_stats" | grep -qF "$needle"; then
        echo "coordinator stats missing $needle" >&2
        exit 1
    fi
done
# Kill one worker; a fresh sweep (new points) must still complete on
# the survivor via the replica retry path.
kill "$w1_pid" 2>/dev/null || true
wait "$w1_pid" 2>/dev/null || true
sweep2=$("$bin" scenario --addr "$co_addr" --ask sparsity \
    --sweep-size 288,320,352,384 --sweep-streams 1,2,3,4)
if ! printf '%s\n' "$sweep2" | grep -q '"points"'; then
    echo "cluster sweep after worker kill failed: $sweep2" >&2
    exit 1
fi
if printf '%s\n' "$sweep2" | grep -qF '"code":"runtime"'; then
    echo "points failed after worker kill: $sweep2" >&2
    exit 1
fi
echo "cluster smoke ok (sweep split across workers, survived a kill)"
kill "$w2_pid" "$co_pid" 2>/dev/null || true
wait "$w2_pid" "$co_pid" 2>/dev/null || true
trap - EXIT
rm -f "$w1_log" "$w2_log" "$co_log"

echo "== bench smoke (1 warmup / 1 iter, full targets) =="
MI300A_BENCH_WARMUP=1 MI300A_BENCH_ITERS=1 cargo bench

echo "== bench baselines =="
out_dir="${MI300A_BENCH_OUT:-.}"
for name in hotpath ablations paper_experiments backends serve; do
    f="$out_dir/BENCH_$name.json"
    if [ ! -s "$f" ]; then
        echo "missing bench baseline: $f" >&2
        exit 1
    fi
    echo "ok: $f"
done

echo "ci.sh: all green"
