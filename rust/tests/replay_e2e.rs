//! Acceptance (ISSUE 10): the checked-in example traces under
//! `docs/traces/` replay to one byte-identical answer through every
//! path the service exposes — the `replay` CLI subcommand, a wire
//! `scenario` request, the same request inside a `batch` envelope, and
//! an async `submit` job — with exactly one cold DES execution across
//! all four (the shared result cache, proven via `engine_runs_des`).
//! The what-if contract rides along: `identity` answers byte-identically
//! to the untransformed trace, and `precision_rewrite:fp8` strictly
//! lowers the makespan of the fp16 transformer timeline.

use mi300a_char::api::{
    Client, ErrorCode, Request, RequestEnvelope, Response, ScenarioSpec,
    Service,
};
use mi300a_char::backend::BackendId;
use mi300a_char::config::Config;
use mi300a_char::replay::{parse_jsonl, Transform};
use mi300a_char::serve::serve;
use mi300a_char::util::json::Json;
use std::path::{Path, PathBuf};

fn trace_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/traces").join(name)
}

/// Decode a checked-in trace into a ready-to-run scenario spec.
fn checked_in_spec(name: &str) -> ScenarioSpec {
    let path = trace_path(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let records = parse_jsonl(&text)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    ScenarioSpec::trace_replay(records).unwrap()
}

fn free_port() -> u16 {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = probe.local_addr().unwrap().port();
    drop(probe);
    port
}

fn spawn_server(conns: usize) -> (u16, std::thread::JoinHandle<()>) {
    let port = free_port();
    let handle = std::thread::spawn(move || {
        serve(Config::mi300a(), &format!("127.0.0.1:{port}"), Some(conns))
            .unwrap();
    });
    (port, handle)
}

/// One canonical comparison form per response: the wire JSON without
/// the envelope id (compact encoding is canonical byte-for-byte).
fn canon(resp: &Response) -> String {
    resp.to_json(None).to_string()
}

#[test]
fn checked_in_trace_replays_identically_via_cli_wire_batch_and_job() {
    let spec = checked_in_spec("transformer.jsonl");
    // The transformer timeline: 12 launches over 3 streams, all fp16.
    assert_eq!(spec.trace.len(), 12);
    assert_eq!(spec.streams, 3);

    let (port, handle) = spawn_server(1);
    let mut client =
        Client::connect_retry(format!("127.0.0.1:{port}").as_str(), 200)
            .unwrap();

    // Path 1 — wire scenario request (the cold run).
    let wire = client
        .request(&Request::Scenario { spec: spec.clone() })
        .unwrap();
    let wire_bytes = canon(&wire);
    match &wire {
        Response::Scenario { points } => assert_eq!(points.len(), 1),
        other => panic!("unexpected response: {other:?}"),
    }
    // Per-launch spans surface through the sim answer.
    assert!(
        wire_bytes.contains("\"spans\":12"),
        "one span per recorded launch: {wire_bytes}"
    );

    // Path 2 — the same request inside a batch envelope.
    let batch = client
        .batch(&[Request::Scenario { spec: spec.clone() }])
        .unwrap();
    assert_eq!(canon(&batch[0]), wire_bytes, "batch path diverged");

    // Path 3 — async job submit/wait.
    let via_job = client.submit_and_wait(&spec, |_| {}).unwrap();
    assert_eq!(canon(&via_job), wire_bytes, "job path diverged");

    // All three paths shared one cache entry: exactly one cold DES run.
    let (stats, _) = client
        .request_json_env(&Request::Stats, &RequestEnvelope::default())
        .unwrap();
    assert_eq!(
        stats.get("engine_runs_des"),
        Some(&Json::Num(1.0)),
        "wire/batch/job must share the cache: {stats}"
    );

    client.raw_line("QUIT").ok();
    drop(client);
    handle.join().unwrap();

    // Path 4 — the CLI subcommand (its own process, cache disabled;
    // determinism makes it byte-identical anyway).
    let path = trace_path("transformer.jsonl");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_mi300a-char"))
        .args(["replay", "--trace", path.to_str().unwrap(), "--json"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "replay CLI failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let cli = Json::parse(
        std::str::from_utf8(&out.stdout).unwrap().trim(),
    )
    .unwrap();
    assert_eq!(cli.to_string(), wire_bytes, "CLI path diverged");

    // --chrome-trace exports one X event per launch, valid JSON.
    let chrome = std::env::temp_dir()
        .join(format!("replay_e2e_{}.json", std::process::id()));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_mi300a-char"))
        .args([
            "replay",
            "--trace",
            path.to_str().unwrap(),
            "--chrome-trace",
            chrome.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "chrome-trace export failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let exported =
        Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
    assert_eq!(
        exported.get("traceEvents").unwrap().as_arr().unwrap().len(),
        12
    );
    std::fs::remove_file(&chrome).ok();
}

#[test]
fn identity_is_byte_identical_and_fp8_rewrite_strictly_faster() {
    let svc = Service::new(Config::mi300a());
    let spec = checked_in_spec("transformer.jsonl");

    let plain = svc.handle(&Request::Scenario { spec: spec.clone() });
    let makespan = |resp: &Response| -> f64 {
        match resp {
            Response::Scenario { points } => points[0]
                .result
                .to_item_json()
                .get("makespan_ms")
                .unwrap()
                .as_f64()
                .unwrap(),
            other => panic!("unexpected response: {other:?}"),
        }
    };
    let baseline = makespan(&plain);

    // The explicit identity transform answers byte-identically to the
    // untransformed trace (identity stays off the wire and off the
    // cache key).
    let mut identity = spec.clone();
    identity.transform = Transform::parse("identity").unwrap();
    let via_identity = svc.handle(&Request::Scenario { spec: identity });
    assert_eq!(
        canon(&via_identity),
        canon(&plain),
        "identity must be a no-op"
    );

    // The fp8 what-if strictly beats the recorded fp16 timeline.
    let mut fp8 = spec.clone();
    fp8.transform = Transform::parse("precision_rewrite:fp8").unwrap();
    let rewritten = makespan(&svc.handle(&Request::Scenario { spec: fp8 }));
    assert!(
        rewritten < baseline,
        "precision_rewrite:fp8 {rewritten} !< fp16 original {baseline}"
    );

    // The mixed trace exercises spmm + sparsity records end to end.
    let mixed = checked_in_spec("mixed_precision.jsonl");
    let resp = svc.handle(&Request::Scenario { spec: mixed.clone() });
    assert!(canon(&resp).contains("\"spans\":8"), "{}", canon(&resp));

    // Analytic refusal is typed, end to end.
    let mut analytic = mixed;
    analytic.backend = Some(BackendId::Analytic);
    match svc.handle(&Request::Scenario { spec: analytic }) {
        Response::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnsupportedByBackend);
            assert!(message.contains("trace"), "{message}");
        }
        other => panic!("expected typed refusal, got {other:?}"),
    }
}
