//! Concurrency stress for the sharded result cache behind a real
//! [`mi300a_char::api::Service`] (ISSUE 6 satellite): many threads
//! hammering one hot key while others churn a cold keyspace must
//! produce byte-identical responses and *exact* hit/miss/eviction
//! accounting — the shard split may not lose or double-count anything,
//! and `engine_runs` must equal the number of distinct cold points
//! (each cold execution happens exactly once; concurrent identical
//! requests after the prewarm are all hits).

use mi300a_char::api::{CachePolicy, Request, Response, Service};
use mi300a_char::config::Config;
use std::thread;

const THREADS: usize = 8;

fn response_bytes(svc: &Service, req: &Request) -> String {
    svc.handle(req).to_json(None).to_string()
}

fn assert_not_error(line: &str) {
    assert!(
        !line.contains("\"type\":\"error\""),
        "unexpected error response: {line}"
    );
}

/// Hot-key contention: one prewarmed key read 50x by each of 8 threads
/// while each thread also inserts 25 distinct cold keys. Large caps, so
/// nothing evicts and every counter is exactly predictable.
#[test]
fn hot_key_and_cold_churn_account_exactly() {
    let svc = Service::with_cache_policy(
        Config::mi300a(),
        CachePolicy {
            enabled: true,
            max_entries: 4096,
            max_bytes: 256 << 20,
            shards: 8,
        },
    );
    let hot = Request::Sparsity { n: 512, streams: 4 };
    // Prewarm single-threaded: 1 miss, 1 cold execution, and the
    // reference bytes every concurrent hit must reproduce.
    let expected = response_bytes(&svc, &hot);
    assert_not_error(&expected);
    assert_eq!(svc.engine_runs(), 1);

    thread::scope(|s| {
        for t in 0..THREADS {
            let svc = &svc;
            let hot = &hot;
            let expected = &expected;
            s.spawn(move || {
                for i in 0..50 {
                    // Interleave so hot reads race the cold inserts.
                    if i < 25 {
                        let cold = Request::Sparsity {
                            n: 1000 + t * 25 + i,
                            streams: 3,
                        };
                        assert_not_error(&response_bytes(svc, &cold));
                    }
                    assert_eq!(
                        &response_bytes(svc, hot),
                        expected,
                        "hot hit diverged on thread {t} iteration {i}"
                    );
                }
            });
        }
    });

    let stats = svc.cache_stats();
    assert_eq!(stats.hits, (THREADS * 50) as u64, "{stats:?}");
    assert_eq!(stats.misses, 1 + (THREADS * 25) as u64, "{stats:?}");
    assert_eq!(stats.evictions, 0, "{stats:?}");
    assert_eq!(stats.entries, 1 + (THREADS * 25) as u64, "{stats:?}");
    // Every distinct point executed exactly once; hits re-ran nothing.
    assert_eq!(svc.engine_runs(), 1 + (THREADS * 25) as u64);
}

/// Eviction churn: a tiny entry cap under concurrent inserts of
/// all-distinct keys. Every insert must land (its response is computed
/// either way), so evictions are exactly inserts minus the cap, and
/// the global LRU bound holds at the end.
#[test]
fn concurrent_churn_keeps_global_caps_and_exact_eviction_counts() {
    const CAP: usize = 8;
    const PER_THREAD: usize = 16;
    let svc = Service::with_cache_policy(
        Config::mi300a(),
        CachePolicy {
            enabled: true,
            max_entries: CAP,
            max_bytes: 64 << 20,
            shards: 4,
        },
    );
    thread::scope(|s| {
        for t in 0..THREADS {
            let svc = &svc;
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    let req = Request::Sparsity {
                        n: 1 + t * PER_THREAD + i,
                        streams: 7,
                    };
                    assert_not_error(&response_bytes(svc, &req));
                }
            });
        }
    });
    let stats = svc.cache_stats();
    let inserts = (THREADS * PER_THREAD) as u64;
    assert_eq!(stats.hits, 0, "{stats:?}");
    assert_eq!(stats.misses, inserts, "{stats:?}");
    assert_eq!(stats.entries, CAP as u64, "{stats:?}");
    assert_eq!(stats.evictions, inserts - CAP as u64, "{stats:?}");
    assert_eq!(svc.engine_runs(), inserts);
    // Re-request every key once, single-threaded. Which keys survived
    // the race is order-dependent, but the accounting identities are
    // not: every lookup is a hit or a miss, every miss re-executes and
    // re-inserts, and the cap forces one eviction per insert.
    let before_runs = svc.engine_runs();
    for t in 0..THREADS {
        for i in 0..PER_THREAD {
            let req = Request::Sparsity { n: 1 + t * PER_THREAD + i, streams: 7 };
            assert_not_error(&response_bytes(&svc, &req));
        }
    }
    let after = svc.cache_stats();
    let hits_delta = after.hits - stats.hits;
    let misses_delta = after.misses - stats.misses;
    assert!(hits_delta <= CAP as u64, "{after:?}");
    assert_eq!(hits_delta + misses_delta, inserts, "{after:?}");
    assert_eq!(after.entries, CAP as u64, "{after:?}");
    assert_eq!(after.evictions - stats.evictions, misses_delta, "{after:?}");
    assert_eq!(svc.engine_runs() - before_runs, misses_delta);
}
