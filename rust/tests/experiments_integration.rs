//! Integration: every experiment driver end-to-end, plus calibration
//! assertions against the paper's headline numbers (DESIGN.md §5 lists
//! the targets; EXPERIMENTS.md records the full comparison).

use mi300a_char::config::Config;
use mi300a_char::experiments::{run, REGISTRY};

fn get(j: &mi300a_char::util::json::Json, path: &[&str]) -> f64 {
    let mut cur = j;
    for p in path {
        cur = cur.get(p).unwrap_or_else(|| panic!("missing {p}"));
    }
    cur.as_f64().unwrap()
}

#[test]
fn all_experiments_produce_reports_and_json() {
    let cfg = Config::mi300a();
    for spec in REGISTRY {
        let r = run(spec.id, &cfg).unwrap();
        assert_eq!(r.id, spec.id);
        assert_eq!(r.title, spec.title, "{}: registry title drifted", spec.id);
        let text = r.render();
        assert!(text.len() > 40, "{}: report too small", spec.id);
    }
}

#[test]
fn fig2_calibration_anchors() {
    // Paper: FP8 13.7%, FP64 12.1%, FP32 10.4% at 256 wavefronts.
    let cfg = Config::mi300a();
    let r = run("fig2", &cfg).unwrap();
    let rows = r.json.as_arr().unwrap();
    let at256 = rows
        .iter()
        .find(|x| x.get("waves").unwrap().as_f64() == Some(256.0))
        .unwrap();
    let close = |name: &str, want: f64, tol: f64| {
        let got = get(at256, &[name]);
        assert!(
            (got - want).abs() < tol,
            "{name}@256: {got:.4} vs paper {want:.4}"
        );
    };
    close("FP8", 0.137, 0.012);
    close("FP64", 0.121, 0.012);
    close("FP32", 0.104, 0.012);
    // FP8 at 128 waves ~7%.
    let at128 = rows
        .iter()
        .find(|x| x.get("waves").unwrap().as_f64() == Some(128.0))
        .unwrap();
    let fp8_128 = get(at128, &["FP8"]);
    assert!((fp8_128 - 0.07).abs() < 0.012, "FP8@128 = {fp8_128:.4}");
}

#[test]
fn fig4_speedup_bands() {
    // Paper: 1.78-1.83x at 4 streams, 2.79-2.87x at 8.
    let cfg = Config::mi300a();
    let r = run("fig4", &cfg).unwrap();
    let rows = r.json.as_arr().unwrap();
    for p in ["FP32", "FP16", "FP8"] {
        let at = |s: f64| {
            rows.iter()
                .find(|x| x.get("streams").unwrap().as_f64() == Some(s))
                .map(|x| get(x, &[p]))
                .unwrap()
        };
        let s4 = at(4.0);
        let s8 = at(8.0);
        assert!((1.55..=2.1).contains(&s4), "{p}@4: {s4:.2} (paper 1.78-1.83)");
        assert!((2.2..=3.2).contains(&s8), "{p}@8: {s8:.2} (paper 2.79-2.87)");
    }
}

#[test]
fn fig6_l2_anchors() {
    let cfg = Config::mi300a();
    let r = run("fig6", &cfg).unwrap();
    let rows = r.json.as_arr().unwrap();
    let miss = |idx: usize, stream: usize| {
        rows[idx].get("miss").unwrap().as_arr().unwrap()[stream]
            .as_f64()
            .unwrap()
    };
    // thin 5->~6%, medium 15->~19%, thick 35->~43%.
    assert!((miss(0, 0) - 0.05).abs() < 0.005);
    assert!((miss(1, 0) - 0.15).abs() < 0.015);
    assert!((miss(2, 0) - 0.35).abs() < 0.03);
    assert!(miss(0, 3) > miss(0, 0));
    assert!((miss(2, 3) - 0.43).abs() < 0.06);
}

#[test]
fn fig9_paper_trio() {
    // Paper: 4:1 -> large ~2.4x, small ~0.63x, fairness 0.93-0.99.
    let cfg = Config::mi300a();
    let r = run("fig9", &cfg).unwrap();
    let rows = r.json.as_arr().unwrap();
    let four = rows
        .iter()
        .find(|x| x.get("ratio").unwrap().as_str() == Some("4:1"))
        .unwrap();
    let large = get(four, &["speedup_large"]);
    let small = get(four, &["speedup_small"]);
    let fair = get(four, &["fairness"]);
    assert!((2.0..=2.8).contains(&large), "large {large:.2}");
    assert!((0.5..=0.8).contains(&small), "small {small:.2}");
    assert!(fair >= 0.9, "fairness {fair:.2}");
}

#[test]
fn fig10_overhead_bands() {
    let cfg = Config::mi300a();
    let r = run("fig10", &cfg).unwrap();
    for row in r.json.as_arr().unwrap() {
        let lhs = get(row, &["lhs"]);
        let both = get(row, &["both"]);
        assert!((3.3..=4.1).contains(&lhs), "lhs {lhs:.2} µs");
        assert!((5.1..=6.0).contains(&both), "both {both:.2} µs");
    }
}

#[test]
fn fig13_crossover_and_fairness() {
    let cfg = Config::mi300a();
    let r = run("fig13", &cfg).unwrap();
    let rows = r.json.get("scaling").unwrap().as_arr().unwrap();
    let at = |s: f64, name: &str, field: &str| {
        rows.iter()
            .find(|x| x.get("streams").unwrap().as_f64() == Some(s))
            .map(|x| get(x, &[name, field]))
            .unwrap()
    };
    // Solo: dense wins (paper 59.98 vs 52.1).
    assert!(at(1.0, "dense", "gflops") > at(1.0, "sparse", "gflops"));
    // 4 streams: sparse overtakes (paper 234.2 vs 213.93) and is fairer
    // (paper 0.98 vs 0.91).
    assert!(at(4.0, "sparse", "gflops") > at(4.0, "dense", "gflops"));
    assert!(at(4.0, "sparse", "fairness") > at(4.0, "dense", "fairness"));
    // Solo dense absolute in the paper's ballpark (59.98 GFLOPS).
    let solo = at(1.0, "dense", "gflops");
    assert!((45.0..=75.0).contains(&solo), "dense solo {solo:.1} GFLOPS");
}

#[test]
fn reports_write_to_out_dir() {
    let cfg = Config::mi300a();
    let dir = std::env::temp_dir().join("mi300a_reports_test");
    let _ = std::fs::create_dir_all(&dir);
    let r = run("table3", &cfg).unwrap();
    std::fs::write(dir.join("table3.json"), r.json.to_string_pretty()).unwrap();
    let back = mi300a_char::util::json::Json::parse(
        &std::fs::read_to_string(dir.join("table3.json")).unwrap(),
    )
    .unwrap();
    assert_eq!(back.as_arr().unwrap().len(), 25);
}
