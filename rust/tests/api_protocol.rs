//! Protocol conformance: every `Request`/`Response` variant
//! encodes→decodes byte-identically, unknown fields/versions are typed
//! errors, and the legacy text shim desugars to the same typed requests
//! (DESIGN.md §6 is the prose spec these tests enforce).

use mi300a_char::api::{
    parse_legacy, ApiError, ErrorCode, ExperimentInfo, LegacyCommand,
    PlanGroup, Request, Response, PROTOCOL_VERSION,
};
use mi300a_char::coordinator::Objective;
use mi300a_char::isa::Precision;
use mi300a_char::util::json::Json;

/// Encode with an id, serialize, reparse, decode: the value and the
/// serialized bytes must both survive unchanged.
fn roundtrip_request(req: Request) {
    for id in [None, Some(42u64)] {
        let encoded = req.to_json(id);
        let wire = encoded.to_string();
        let reparsed = Json::parse(&wire).unwrap();
        let (decoded, got_id) = Request::from_json(&reparsed)
            .unwrap_or_else(|(e, _)| panic!("decode {wire}: {e}"));
        assert_eq!(decoded, req, "value drift over the wire: {wire}");
        assert_eq!(got_id, id, "id drift over the wire: {wire}");
        assert_eq!(
            decoded.to_json(got_id).to_string(),
            wire,
            "bytes drift over the wire"
        );
    }
}

fn roundtrip_response(resp: Response) {
    for id in [None, Some(7u64)] {
        let encoded = resp.to_json(id);
        let wire = encoded.to_string();
        let reparsed = Json::parse(&wire).unwrap();
        let (decoded, got_id) = Response::from_json(&reparsed)
            .unwrap_or_else(|e| panic!("decode {wire}: {e}"));
        assert_eq!(decoded, resp, "value drift over the wire: {wire}");
        assert_eq!(got_id, id, "id drift over the wire: {wire}");
        assert_eq!(
            decoded.to_json(got_id).to_string(),
            wire,
            "bytes drift over the wire"
        );
    }
}

#[test]
fn every_request_variant_roundtrips() {
    roundtrip_request(Request::Sim {
        n: 512,
        precision: Precision::Fp8,
        streams: 4,
    });
    roundtrip_request(Request::Plan {
        objective: Objective::ThroughputOriented,
        streams: 8,
        n: 512,
        precision: Precision::Bf16,
    });
    roundtrip_request(Request::Sparsity { n: 1024, streams: 2 });
    roundtrip_request(Request::Run { entry: "gemm_fp8_128".into() });
    roundtrip_request(Request::Repro { experiment: "fig4".into() });
    roundtrip_request(Request::ListExperiments);
    roundtrip_request(Request::Config);
}

#[test]
fn every_precision_and_objective_roundtrips_in_requests() {
    for p in [
        Precision::F64,
        Precision::F32,
        Precision::F16,
        Precision::Bf16,
        Precision::Fp8,
        Precision::Bf8,
    ] {
        roundtrip_request(Request::Sim { n: 128, precision: p, streams: 1 });
    }
    for o in [
        Objective::LatencySensitive,
        Objective::ThroughputOriented,
        Objective::StrictIsolation,
    ] {
        roundtrip_request(Request::Plan {
            objective: o,
            streams: 4,
            n: 256,
            precision: Precision::Fp8,
        });
    }
}

#[test]
fn every_response_variant_roundtrips() {
    roundtrip_response(Response::Sim {
        makespan_ms: 12.375,
        speedup_vs_serial: 2.5,
        overlap_efficiency: 0.875,
        fairness: 0.51,
        l2_miss: 0.1875,
        lds_util: 0.625,
    });
    roundtrip_response(Response::Plan {
        objective: "throughput".into(),
        sparse: true,
        groups: vec![
            PlanGroup {
                kernels: vec!["gemm512".into(), "gemm512s".into()],
                streams: 2,
                expected_fairness: 0.51,
                process_isolation: false,
            },
            PlanGroup {
                kernels: vec![],
                streams: 1,
                expected_fairness: 1.0,
                process_isolation: true,
            },
        ],
    });
    roundtrip_response(Response::Sparsity {
        enable: true,
        reason: "ConcurrentContext".into(),
        isolated_speedup: 1.0,
        concurrent_speedup: 1.3125,
    });
    roundtrip_response(Response::Run {
        entry: "gemm_fp8_128".into(),
        outputs: 16384,
        checksum: -12.5,
        exec_ms: 3.25,
    });
    roundtrip_response(Response::Repro {
        experiment: "fig4".into(),
        title: "ACE concurrency scaling".into(),
        report: Json::parse(r#"{"rows":[{"streams":4,"speedup":2.5}]}"#)
            .unwrap(),
        rendered: "### fig4\nline two\n".into(),
    });
    roundtrip_response(Response::Experiments {
        experiments: vec![ExperimentInfo {
            id: "table1".into(),
            title: "System configuration".into(),
            section: "§4".into(),
        }],
    });
    roundtrip_response(Response::Config {
        config: Json::parse(r#"{"hw":{"n_aces":4},"seed":2026}"#).unwrap(),
    });
    for code in ErrorCode::ALL {
        roundtrip_response(Response::Error {
            code,
            message: format!("demo message for {}", code.as_str()),
        });
    }
}

#[test]
fn unknown_fields_are_rejected_per_variant() {
    // Inject an extra key into each encoded request; decode must fail
    // with unknown_field naming it.
    let requests = [
        Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        Request::Plan {
            objective: Objective::LatencySensitive,
            streams: 4,
            n: 512,
            precision: Precision::Fp8,
        },
        Request::Sparsity { n: 512, streams: 4 },
        Request::Run { entry: "x".into() },
        Request::Repro { experiment: "fig4".into() },
        Request::ListExperiments,
        Request::Config,
    ];
    for req in requests {
        let mut v = req.to_json(None);
        if let Json::Obj(m) = &mut v {
            m.insert("zz_extra".into(), Json::Num(1.0));
        }
        let (err, _) = Request::from_json(&v).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownField, "{req:?}");
        assert!(err.message.contains("zz_extra"), "{}", err.message);
    }
}

#[test]
fn foreign_versions_are_rejected_with_salvaged_id() {
    let line = r#"{"v":99,"id":13,"type":"config"}"#;
    let (err, id) = Request::from_json(&Json::parse(line).unwrap())
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadVersion);
    assert!(err.message.contains("99"), "{}", err.message);
    assert!(
        err.message.contains(&PROTOCOL_VERSION.to_string()),
        "{}",
        err.message
    );
    assert_eq!(id, Some(13));

    let (err, _) =
        Request::from_json(&Json::parse(r#"{"type":"config"}"#).unwrap())
            .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadVersion);
}

#[test]
fn malformed_envelopes_are_typed_errors() {
    for (line, want) in [
        (r#"[1,2,3]"#, ErrorCode::BadRequest),
        (r#"{"v":1}"#, ErrorCode::BadRequest), // missing type
        (r#"{"v":1,"type":"frobnicate"}"#, ErrorCode::UnknownType),
        (r#"{"v":1,"id":-3,"type":"config"}"#, ErrorCode::BadRequest),
        (r#"{"v":1,"id":1.5,"type":"config"}"#, ErrorCode::BadRequest),
        (r#"{"v":1,"type":"sim","precision":"fp8","streams":4}"#,
         ErrorCode::BadRequest), // missing n
        (r#"{"v":1,"type":"sim","n":"big","precision":"fp8","streams":4}"#,
         ErrorCode::BadRequest),
        (r#"{"v":1,"type":"sim","n":512,"precision":"int4","streams":4}"#,
         ErrorCode::BadRequest),
    ] {
        let (err, _) = Request::from_json(&Json::parse(line).unwrap())
            .unwrap_err();
        assert_eq!(err.code, want, "{line} -> {err}");
    }
}

#[test]
fn legacy_shim_matches_typed_requests() {
    let cases: [(&str, Request); 4] = [
        (
            "SIM 512 fp8 4",
            Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        ),
        (
            "PLAN throughput 8 512",
            Request::Plan {
                objective: Objective::ThroughputOriented,
                streams: 8,
                n: 512,
                precision: Precision::Fp8,
            },
        ),
        ("SPARSITY 512 4", Request::Sparsity { n: 512, streams: 4 }),
        ("RUN gemm_fp8_128", Request::Run { entry: "gemm_fp8_128".into() }),
    ];
    for (line, want) in cases {
        assert_eq!(
            parse_legacy(line).unwrap(),
            LegacyCommand::Request(want),
            "{line}"
        );
    }
    assert_eq!(parse_legacy("QUIT").unwrap(), LegacyCommand::Quit);
    assert_eq!(
        parse_legacy("LIST").unwrap(),
        LegacyCommand::Request(Request::ListExperiments)
    );
    assert_eq!(
        parse_legacy("CONFIG").unwrap(),
        LegacyCommand::Request(Request::Config)
    );

    // Legacy parse failures carry the same typed codes the JSON path
    // uses.
    let err: ApiError = parse_legacy("SIM abc fp8 4").unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    let err = parse_legacy("PLAN sideways 8 512").unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    let err = parse_legacy("FROBNICATE").unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownType);
}

#[test]
fn error_code_wire_spellings_are_stable() {
    // The wire spellings are part of the v1 contract (DESIGN.md §6.3):
    // renaming one is a protocol version bump, so pin them.
    let want = [
        "bad_version",
        "bad_request",
        "unknown_type",
        "unknown_field",
        "bad_range",
        "unknown_experiment",
        "unknown_entry",
        "runtime",
    ];
    assert_eq!(ErrorCode::ALL.len(), want.len());
    for (c, w) in ErrorCode::ALL.iter().zip(want) {
        assert_eq!(c.as_str(), w);
        assert_eq!(ErrorCode::parse(w), Some(*c));
    }
}
