//! Protocol conformance: every `Request`/`Response` variant
//! encodes→decodes byte-identically, unknown fields/versions are typed
//! errors, and the legacy text shim desugars to the same typed requests
//! (DESIGN.md §6 is the prose spec these tests enforce).

use mi300a_char::api::{
    parse_legacy, ApiError, Ask, BackendInfo, CachePolicy, CacheStats,
    ClusterStats, ErrorCode, ExperimentInfo, JobLimits, JobState, JobView,
    LegacyCommand, PlanGroup, Point, PointResult, Request, RequestEnvelope,
    Response, ScenarioSpec, Service, MAX_SWEEP_POINTS, PROTOCOL_VERSION,
};
use mi300a_char::backend::BackendId;
use mi300a_char::config::Config;
use mi300a_char::coordinator::Objective;
use mi300a_char::isa::Precision;
use mi300a_char::replay::Transform;
use mi300a_char::util::json::Json;

/// Encode with an id, serialize, reparse, decode: the value and the
/// serialized bytes must both survive unchanged.
fn roundtrip_request(req: Request) {
    for id in [None, Some(42u64)] {
        let encoded = req.to_json(id);
        let wire = encoded.to_string();
        let reparsed = Json::parse(&wire).unwrap();
        let (decoded, got_id) = Request::from_json(&reparsed)
            .unwrap_or_else(|(e, _)| panic!("decode {wire}: {e}"));
        assert_eq!(decoded, req, "value drift over the wire: {wire}");
        assert_eq!(got_id, id, "id drift over the wire: {wire}");
        assert_eq!(
            decoded.to_json(got_id).to_string(),
            wire,
            "bytes drift over the wire"
        );
    }
}

fn roundtrip_response(resp: Response) {
    for id in [None, Some(7u64)] {
        let encoded = resp.to_json(id);
        let wire = encoded.to_string();
        let reparsed = Json::parse(&wire).unwrap();
        let (decoded, got_id) = Response::from_json(&reparsed)
            .unwrap_or_else(|e| panic!("decode {wire}: {e}"));
        assert_eq!(decoded, resp, "value drift over the wire: {wire}");
        assert_eq!(got_id, id, "id drift over the wire: {wire}");
        assert_eq!(
            decoded.to_json(got_id).to_string(),
            wire,
            "bytes drift over the wire"
        );
    }
}

#[test]
fn every_request_variant_roundtrips() {
    roundtrip_request(Request::Sim {
        n: 512,
        precision: Precision::Fp8,
        streams: 4,
    });
    roundtrip_request(Request::Plan {
        objective: Objective::ThroughputOriented,
        streams: 8,
        n: 512,
        precision: Precision::Bf16,
    });
    roundtrip_request(Request::Sparsity { n: 1024, streams: 2 });
    roundtrip_request(Request::Run { entry: "gemm_fp8_128".into() });
    roundtrip_request(Request::Repro { experiment: "fig4".into() });
    roundtrip_request(Request::ListExperiments);
    roundtrip_request(Request::Config);
    roundtrip_request(Request::Stats);
    roundtrip_request(Request::Batch {
        items: vec![
            Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
            Request::Sparsity { n: 1024, streams: 2 },
            Request::Repro { experiment: "fig4".into() },
            Request::Stats,
        ],
    });
    // Scenario / job surface (DESIGN.md §6.6-§6.7).
    let mut swept = ScenarioSpec::sim(512, Precision::Fp8, 4);
    swept.sweep.streams = vec![1, 2, 4, 8];
    swept.sweep.precision = vec![Precision::Fp8, Precision::F16];
    roundtrip_request(Request::Scenario { spec: swept.clone() });
    roundtrip_request(Request::Scenario {
        spec: ScenarioSpec::plan(
            Objective::ThroughputOriented,
            8,
            512,
            Precision::Bf16,
        ),
    });
    roundtrip_request(Request::Submit { spec: swept.clone(), progress: false });
    roundtrip_request(Request::Submit { spec: swept, progress: true });
    roundtrip_request(Request::JobStatus { job: 3 });
    roundtrip_request(Request::JobResult { job: 3 });
    roundtrip_request(Request::JobCancel { job: 3 });
    // Backend surface (DESIGN.md §6.8).
    roundtrip_request(Request::Backends);
    let mut analytic = ScenarioSpec::sim(512, Precision::Fp8, 4);
    analytic.backend = Some(BackendId::Analytic);
    roundtrip_request(Request::Scenario { spec: analytic.clone() });
    roundtrip_request(Request::Submit {
        spec: analytic.clone(),
        progress: false,
    });
    // A scenario *batch item* carries its spec-level backend as a
    // payload field (the one exception to the envelope-keys-on-items
    // rule), so per-item backend selection round-trips inside batches.
    roundtrip_request(Request::Batch {
        items: vec![Request::Scenario { spec: analytic }, Request::Stats],
    });
}

#[test]
fn cache_envelope_flag_roundtrips_on_every_variant() {
    for req in [
        Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        Request::Repro { experiment: "fig4".into() },
        Request::Config,
    ] {
        let wire = req.to_json_opts(Some(5), false).to_string();
        assert!(wire.contains(r#""cache":false"#), "{wire}");
        let (back, env) =
            Request::decode(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, req);
        assert_eq!(
            env,
            RequestEnvelope { id: Some(5), cache: false, backend: None }
        );
        assert_eq!(
            back.to_json_opts(env.id, env.cache).to_string(),
            wire,
            "bytes drift over the wire"
        );
        // The default (cache: true) is omitted, keeping the canonical
        // form identical to the pre-cache wire encoding.
        let (_, env) =
            Request::decode(&req.to_json(Some(5))).unwrap();
        assert!(env.cache);
    }
}

#[test]
fn every_precision_and_objective_roundtrips_in_requests() {
    for p in [
        Precision::F64,
        Precision::F32,
        Precision::F16,
        Precision::Bf16,
        Precision::Fp8,
        Precision::Bf8,
    ] {
        roundtrip_request(Request::Sim { n: 128, precision: p, streams: 1 });
    }
    for o in Objective::ALL {
        roundtrip_request(Request::Plan {
            objective: o,
            streams: 4,
            n: 256,
            precision: Precision::Fp8,
        });
    }
}

#[test]
fn every_response_variant_roundtrips() {
    roundtrip_response(Response::Sim {
        makespan_ms: 12.375,
        speedup_vs_serial: 2.5,
        overlap_efficiency: 0.875,
        fairness: 0.51,
        l2_miss: 0.1875,
        lds_util: 0.625,
        transfer_ms: 0.0,
        spans: 0,
    });
    // Multi-device sim answers carry their exposed fabric time.
    roundtrip_response(Response::Sim {
        makespan_ms: 12.375,
        speedup_vs_serial: 2.5,
        overlap_efficiency: 0.875,
        fairness: 0.51,
        l2_miss: 0.1875,
        lds_util: 0.625,
        transfer_ms: 1.5,
        spans: 0,
    });
    // Trace-replay answers carry their per-launch span count.
    roundtrip_response(Response::Sim {
        makespan_ms: 12.375,
        speedup_vs_serial: 2.5,
        overlap_efficiency: 0.875,
        fairness: 0.51,
        l2_miss: 0.1875,
        lds_util: 0.625,
        transfer_ms: 0.0,
        spans: 12,
    });
    roundtrip_response(Response::Plan {
        objective: "throughput".into(),
        sparse: true,
        groups: vec![
            PlanGroup {
                kernels: vec!["gemm512".into(), "gemm512s".into()],
                streams: 2,
                expected_fairness: 0.51,
                process_isolation: false,
            },
            PlanGroup {
                kernels: vec![],
                streams: 1,
                expected_fairness: 1.0,
                process_isolation: true,
            },
        ],
    });
    roundtrip_response(Response::Sparsity {
        enable: true,
        reason: "ConcurrentContext".into(),
        isolated_speedup: 1.0,
        concurrent_speedup: 1.3125,
    });
    roundtrip_response(Response::Run {
        entry: "gemm_fp8_128".into(),
        outputs: 16384,
        checksum: -12.5,
        exec_ms: 3.25,
    });
    roundtrip_response(Response::Repro {
        experiment: "fig4".into(),
        title: "ACE concurrency scaling".into(),
        report: Json::parse(r#"{"rows":[{"streams":4,"speedup":2.5}]}"#)
            .unwrap(),
        rendered: "### fig4\nline two\n".into(),
    });
    roundtrip_response(Response::Experiments {
        experiments: vec![ExperimentInfo {
            id: "table1".into(),
            title: "System configuration".into(),
            section: "§4".into(),
            deterministic: true,
        }],
    });
    roundtrip_response(Response::Backends {
        backends: vec![BackendInfo {
            id: "des".into(),
            description: "discrete-event replay".into(),
            asks: vec!["sim".into(), "plan".into(), "sparsity".into()],
            sim_shapes: vec!["homogeneous".into()],
            deterministic: true,
            default: true,
        }],
    });
    roundtrip_response(Response::Config {
        config: Json::parse(r#"{"hw":{"n_aces":4},"seed":2026}"#).unwrap(),
    });
    roundtrip_response(Response::Stats {
        cache: CacheStats {
            hits: 12,
            misses: 3,
            evictions: 1,
            entries: 2,
            bytes: 4096,
            max_entries: 1024,
            max_bytes: 64 << 20,
            enabled: true,
        },
        engine_runs: 3,
        backend_runs: vec![2, 1, 0],
        cluster: None,
    });
    // The coordinator variant: the same payload plus the all-or-
    // nothing cluster_* block (DESIGN.md §6.9).
    roundtrip_response(Response::Stats {
        cache: CacheStats::default(),
        engine_runs: 9,
        backend_runs: vec![6, 3, 0],
        cluster: Some(ClusterStats {
            workers: 2,
            points_routed: 256,
            proxied: 3,
            retries: 5,
            point_failures: 1,
        }),
    });
    roundtrip_response(Response::Batch {
        items: vec![
            Response::Sparsity {
                enable: true,
                reason: "ConcurrentContext".into(),
                isolated_speedup: 1.0,
                concurrent_speedup: 1.3125,
            },
            Response::Error {
                code: ErrorCode::BadRange,
                message: "streams must be in 1..=16 (got 32)".into(),
            },
        ],
    });
    roundtrip_response(Response::Scenario {
        points: vec![
            PointResult {
                point: Point {
                    n: 512,
                    precision: Precision::Fp8,
                    streams: 4,
                    iters: 50,
                    devices: 1,
                    transform: Transform::Identity,
                },
                result: Box::new(Response::Sim {
                    makespan_ms: 12.375,
                    speedup_vs_serial: 2.5,
                    overlap_efficiency: 0.875,
                    fairness: 0.51,
                    l2_miss: 0.1875,
                    lds_util: 0.625,
                    transfer_ms: 0.0,
                    spans: 0,
                }),
            },
            PointResult {
                point: Point {
                    n: 1024,
                    precision: Precision::F16,
                    streams: 2,
                    iters: 100,
                    devices: 1,
                    transform: Transform::Identity,
                },
                result: Box::new(Response::Sparsity {
                    enable: false,
                    reason: "IsolatedBreakEven".into(),
                    isolated_speedup: 1.0,
                    concurrent_speedup: 1.3125,
                }),
            },
        ],
    });
    for state in JobState::ALL {
        roundtrip_response(Response::Job(JobView {
            job: 7,
            state,
            completed: 3,
            refined: 0,
            total: 8,
        }));
        roundtrip_response(Response::Progress(JobView {
            job: 7,
            state,
            completed: 3,
            refined: 0,
            total: 8,
        }));
        // Refinement frames (budgeted auto jobs, DESIGN.md §6.10)
        // carry the extra counter.
        roundtrip_response(Response::Progress(JobView {
            job: 7,
            state,
            completed: 8,
            refined: 2,
            total: 8,
        }));
    }
    for code in ErrorCode::ALL {
        roundtrip_response(Response::Error {
            code,
            message: format!("demo message for {}", code.as_str()),
        });
    }
}

#[test]
fn unknown_fields_are_rejected_per_variant() {
    // Inject an extra key into each encoded request; decode must fail
    // with unknown_field naming it.
    let requests = [
        Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        Request::Plan {
            objective: Objective::LatencySensitive,
            streams: 4,
            n: 512,
            precision: Precision::Fp8,
        },
        Request::Sparsity { n: 512, streams: 4 },
        Request::Run { entry: "x".into() },
        Request::Repro { experiment: "fig4".into() },
        Request::ListExperiments,
        Request::Config,
        Request::Stats,
        Request::Backends,
        Request::Batch { items: vec![Request::Stats] },
        Request::Scenario {
            spec: ScenarioSpec::sim(512, Precision::Fp8, 4),
        },
        Request::Submit {
            spec: ScenarioSpec::sim(512, Precision::Fp8, 4),
            progress: true,
        },
        Request::JobStatus { job: 1 },
        Request::JobResult { job: 1 },
        Request::JobCancel { job: 1 },
    ];
    for req in requests {
        let mut v = req.to_json(None);
        if let Json::Obj(m) = &mut v {
            m.insert("zz_extra".into(), Json::Num(1.0));
        }
        let (err, _) = Request::from_json(&v).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownField, "{req:?}");
        assert!(err.message.contains("zz_extra"), "{}", err.message);
    }
}

#[test]
fn foreign_versions_are_rejected_with_salvaged_id() {
    let line = r#"{"v":99,"id":13,"type":"config"}"#;
    let (err, id) = Request::from_json(&Json::parse(line).unwrap())
        .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadVersion);
    assert!(err.message.contains("99"), "{}", err.message);
    assert!(
        err.message.contains(&PROTOCOL_VERSION.to_string()),
        "{}",
        err.message
    );
    assert_eq!(id, Some(13));

    let (err, _) =
        Request::from_json(&Json::parse(r#"{"type":"config"}"#).unwrap())
            .unwrap_err();
    assert_eq!(err.code, ErrorCode::BadVersion);
}

#[test]
fn malformed_envelopes_are_typed_errors() {
    for (line, want) in [
        (r#"[1,2,3]"#, ErrorCode::BadRequest),
        (r#"{"v":1}"#, ErrorCode::BadRequest), // missing type
        (r#"{"v":1,"type":"frobnicate"}"#, ErrorCode::UnknownType),
        (r#"{"v":1,"id":-3,"type":"config"}"#, ErrorCode::BadRequest),
        (r#"{"v":1,"id":1.5,"type":"config"}"#, ErrorCode::BadRequest),
        (r#"{"v":1,"type":"sim","precision":"fp8","streams":4}"#,
         ErrorCode::BadRequest), // missing n
        (r#"{"v":1,"type":"sim","n":"big","precision":"fp8","streams":4}"#,
         ErrorCode::BadRequest),
        (r#"{"v":1,"type":"sim","n":512,"precision":"int4","streams":4}"#,
         ErrorCode::BadRequest),
    ] {
        let (err, _) = Request::from_json(&Json::parse(line).unwrap())
            .unwrap_err();
        assert_eq!(err.code, want, "{line} -> {err}");
    }
}

#[test]
fn legacy_shim_matches_typed_requests() {
    let cases: [(&str, Request); 4] = [
        (
            "SIM 512 fp8 4",
            Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
        ),
        (
            "PLAN throughput 8 512",
            Request::Plan {
                objective: Objective::ThroughputOriented,
                streams: 8,
                n: 512,
                precision: Precision::Fp8,
            },
        ),
        ("SPARSITY 512 4", Request::Sparsity { n: 512, streams: 4 }),
        ("RUN gemm_fp8_128", Request::Run { entry: "gemm_fp8_128".into() }),
    ];
    for (line, want) in cases {
        assert_eq!(
            parse_legacy(line).unwrap(),
            LegacyCommand::Request(want),
            "{line}"
        );
    }
    assert_eq!(parse_legacy("QUIT").unwrap(), LegacyCommand::Quit);
    assert_eq!(
        parse_legacy("LIST").unwrap(),
        LegacyCommand::Request(Request::ListExperiments)
    );
    assert_eq!(
        parse_legacy("CONFIG").unwrap(),
        LegacyCommand::Request(Request::Config)
    );

    // Legacy parse failures carry the same typed codes the JSON path
    // uses.
    let err: ApiError = parse_legacy("SIM abc fp8 4").unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    let err = parse_legacy("PLAN sideways 8 512").unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    let err = parse_legacy("FROBNICATE").unwrap_err();
    assert_eq!(err.code, ErrorCode::UnknownType);
}

// ---------------------------------------------------------------------
// Service-level cache semantics (the wire-level counterparts live in
// tests/serve_integration.rs).
// ---------------------------------------------------------------------

/// A repeated `repro` through the service returns a byte-identical
/// response with zero DES/driver re-execution, proven by the
/// engine-invocation counter staying put on the second call.
#[test]
fn repeated_repro_is_byte_identical_without_reexecution() {
    let svc = Service::new(Config::mi300a());
    let req = Request::Repro { experiment: "table1".into() };
    let cold = svc.handle(&req);
    assert!(
        !matches!(cold, Response::Error { .. }),
        "cold repro failed: {cold:?}"
    );
    let runs_after_cold = svc.engine_runs();
    assert_eq!(runs_after_cold, 1);
    let warm = svc.handle(&req);
    assert_eq!(
        svc.engine_runs(),
        runs_after_cold,
        "second call must not re-run the driver"
    );
    assert_eq!(
        cold.to_json(Some(9)).to_string(),
        warm.to_json(Some(9)).to_string(),
        "cached repro must re-serialize byte-identically"
    );
}

/// Identical items inside one batch share the cache: N copies cost one
/// cold execution, and the trailing stats item observes the hits.
#[test]
fn batch_items_share_the_cache_within_one_call() {
    let svc = Service::new(Config::mi300a());
    let sim = Request::Sparsity { n: 512, streams: 4 };
    let resp = svc.handle(&Request::Batch {
        items: vec![sim.clone(), sim.clone(), sim.clone(), Request::Stats],
    });
    let items = match resp {
        Response::Batch { items } => items,
        other => panic!("unexpected response: {other:?}"),
    };
    assert_eq!(items.len(), 4);
    assert_eq!(items[0], items[1]);
    assert_eq!(items[1], items[2]);
    assert_eq!(svc.engine_runs(), 1, "three copies, one cold run");
    match &items[3] {
        Response::Stats { cache, engine_runs, backend_runs, cluster } => {
            assert_eq!(*engine_runs, 1);
            assert_eq!(cache.hits, 2);
            assert_eq!(cache.misses, 1);
            assert_eq!(cache.entries, 1);
            // All executions ran on the default `des` backend.
            assert_eq!(backend_runs, &vec![1, 0, 0]);
            assert!(cluster.is_none(), "standalone stats carry no cluster");
        }
        other => panic!("unexpected stats item: {other:?}"),
    }
}

/// The entry cap holds under the service: the least-recently-used
/// response is evicted and a repeat of it runs cold again.
#[test]
fn service_cache_evicts_lru_at_the_entry_cap() {
    let svc = Service::with_cache_policy(
        Config::mi300a(),
        CachePolicy {
            enabled: true,
            max_entries: 2,
            max_bytes: 1 << 20,
            ..CachePolicy::default()
        },
    );
    let reqs: Vec<Request> = (1..=3)
        .map(|streams| Request::Sparsity { n: 512, streams })
        .collect();
    svc.handle(&reqs[0]);
    svc.handle(&reqs[1]);
    svc.handle(&reqs[0]); // refresh: reqs[1] is now LRU
    svc.handle(&reqs[2]); // evicts reqs[1]
    assert_eq!(svc.engine_runs(), 3);
    let stats = svc.cache_stats();
    assert_eq!(stats.evictions, 1);
    assert_eq!(stats.entries, 2);
    svc.handle(&reqs[0]); // still cached
    assert_eq!(svc.engine_runs(), 3);
    svc.handle(&reqs[1]); // evicted -> cold again
    assert_eq!(svc.engine_runs(), 4);
}

/// The `stats` request reports exactly what the counters say, and is
/// itself never cached.
#[test]
fn stats_request_mirrors_the_service_counters() {
    let svc = Service::new(Config::mi300a());
    let sp = Request::Sparsity { n: 512, streams: 4 };
    svc.handle(&sp);
    svc.handle(&sp);
    svc.handle(&sp);
    match svc.handle(&Request::Stats) {
        Response::Stats { cache, engine_runs, backend_runs, cluster } => {
            assert_eq!(engine_runs, 1);
            assert_eq!(cache, svc.cache_stats());
            assert_eq!((cache.hits, cache.misses), (2, 1));
            assert!(cache.enabled);
            assert!(cache.bytes > 0);
            assert_eq!(backend_runs, svc.backend_runs());
            assert!(cluster.is_none(), "standalone stats carry no cluster");
        }
        other => panic!("unexpected response: {other:?}"),
    }
    // A second stats read sees its own unchanged counters (stats is
    // not cached, so it reflects live state).
    svc.handle(&sp);
    match svc.handle(&Request::Stats) {
        Response::Stats { cache, .. } => assert_eq!(cache.hits, 3),
        other => panic!("unexpected response: {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Scenario canonicalization (DESIGN.md §6.6): decode→encode→decode is a
// fixpoint, defaults fill in, spellings normalize, and structural
// errors (unknown fields, sweep cap) are typed at decode time.
// ---------------------------------------------------------------------

/// A minimal wire scenario decodes with every default filled, encodes
/// into the full canonical form, and that form is a fixpoint.
#[test]
fn scenario_wire_canonicalization_is_a_fixpoint() {
    let minimal = r#"{"v":1,"type":"scenario","n":512}"#;
    let (req, _) =
        Request::from_json(&Json::parse(minimal).unwrap()).unwrap();
    let canonical = req.to_json(None).to_string();
    assert_eq!(
        canonical,
        r#"{"ask":"sim","iters":50,"n":512,"precision":"fp8","shape":"homogeneous","sparsity":"dense","streams":4,"type":"scenario","v":1}"#
    );
    let (again, _) =
        Request::from_json(&Json::parse(&canonical).unwrap()).unwrap();
    assert_eq!(again, req);
    assert_eq!(again.to_json(None).to_string(), canonical, "fixpoint");

    // Alias spellings normalize into the same canonical bytes (and
    // therefore the same cache key).
    let aliased = r#"{"v":1,"type":"scenario","n":512,"precision":"f8"}"#;
    let (aliased_req, _) =
        Request::from_json(&Json::parse(aliased).unwrap()).unwrap();
    assert_eq!(aliased_req.to_json(None).to_string(), canonical);
    assert_eq!(aliased_req.cache_key(), req.cache_key());
}

/// Property-style grid over the extended spec surface (ISSUE 8): every
/// combination of `backend` selection (including `"auto"`) and the
/// optional `max_error`/`max_time_ms` budgets canonicalizes to a
/// decode→encode→decode fixpoint with a stable cache key, budget
/// presence is mirrored exactly in the canonical bytes, and the
/// cache-form points (`at`) of a budgeted sweep stay byte-identical to
/// the unbudgeted ones. Sizes/streams come from a seeded LCG so the
/// grid covers varied shapes deterministically.
#[test]
fn scenario_budget_grid_canonicalization_is_a_fixpoint() {
    let mut state: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut next = move |m: usize| -> usize {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (state >> 33) as usize % m
    };
    for backend in [None, Some("des"), Some("analytic"), Some("auto")] {
        for me in [None, Some(0.25), Some(0.45)] {
            for mt in [None, Some(1500.0)] {
                let n = 128 << next(4);
                let streams = 1 + next(8);
                let mut line = format!(
                    r#"{{"v":1,"type":"scenario","n":{n},"streams":{streams}"#
                );
                if let Some(b) = backend {
                    line += &format!(r#","backend":"{b}""#);
                }
                if let Some(e) = me {
                    line += &format!(r#","max_error":{e}"#);
                }
                if let Some(t) = mt {
                    line += &format!(r#","max_time_ms":{t}"#);
                }
                line += "}";
                let (req, _) =
                    Request::from_json(&Json::parse(&line).unwrap())
                        .unwrap();
                let canonical = req.to_json(None).to_string();
                let (again, _) =
                    Request::from_json(&Json::parse(&canonical).unwrap())
                        .unwrap();
                assert_eq!(again, req, "{line}");
                assert_eq!(
                    again.to_json(None).to_string(),
                    canonical,
                    "fixpoint: {line}"
                );
                assert_eq!(
                    again.cache_key(),
                    req.cache_key(),
                    "cache key must be stable: {line}"
                );
                // The canonical bytes carry a budget key iff the
                // request did — absent budgets add zero wire surface,
                // keeping pre-budget requests byte-identical.
                assert_eq!(
                    canonical.contains("max_error"),
                    me.is_some(),
                    "{canonical}"
                );
                assert_eq!(
                    canonical.contains("max_time_ms"),
                    mt.is_some(),
                    "{canonical}"
                );
                assert_eq!(
                    canonical.contains("backend"),
                    backend.is_some(),
                    "{canonical}"
                );
                // Budgets are job-level concerns: the cache-form
                // single-point spec strips them, so budgeted and
                // unbudgeted sweeps share per-point cache entries.
                let spec = match &req {
                    Request::Scenario { spec } => spec.clone(),
                    other => panic!("unexpected request: {other:?}"),
                };
                let p = spec.expand()[0];
                let single = spec.at(&p);
                assert_eq!(single.max_error, None, "{line}");
                assert_eq!(single.max_time_ms, None, "{line}");
                let mut bare = spec.clone();
                bare.max_error = None;
                bare.max_time_ms = None;
                assert_eq!(
                    Request::Scenario { spec: single }
                        .to_json(None)
                        .to_string(),
                    Request::Scenario { spec: bare.at(&p) }
                        .to_json(None)
                        .to_string(),
                    "cache-form points must not see budgets: {line}"
                );
            }
        }
    }
}

#[test]
fn scenario_sweeps_roundtrip_and_order_is_preserved() {
    let line = r#"{"v":1,"type":"scenario","n":512,"sweep":{"streams":[8,1,4],"precision":["fp16","fp8"]}}"#;
    let (req, _) = Request::from_json(&Json::parse(line).unwrap()).unwrap();
    let spec = match &req {
        Request::Scenario { spec } => spec.clone(),
        other => panic!("unexpected request: {other:?}"),
    };
    // Axis value order is meaningful (it fixes point order) and must
    // survive the canonical encoding.
    assert_eq!(spec.sweep.streams, vec![8, 1, 4]);
    assert_eq!(
        spec.sweep.precision,
        vec![Precision::F16, Precision::Fp8]
    );
    let wire = req.to_json(None).to_string();
    assert!(wire.contains(r#""streams":[8,1,4]"#), "{wire}");
    let (back, _) = Request::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, req);
    let points = spec.expand();
    assert_eq!(points.len(), 6);
    assert_eq!(
        (points[0].precision, points[0].streams),
        (Precision::F16, 8)
    );
}

/// The multi-APU `device_set` dimension (DESIGN.md §6.11) keeps the
/// canonical-form contract: both subfields always encode, defaults stay
/// off the wire, a `devices` sweep axis survives with its order, and
/// the whole surface is a decode→encode→decode fixpoint.
#[test]
fn scenario_device_set_canonicalization_is_a_fixpoint() {
    let line = r#"{"v":1,"type":"scenario","n":512,"shape":"data_parallel","device_set":{"devices":4,"topology":"ring"},"sweep":{"devices":[4,1,2]}}"#;
    let (req, _) = Request::from_json(&Json::parse(line).unwrap()).unwrap();
    let canonical = req.to_json(None).to_string();
    assert!(
        canonical.contains(
            r#""device_set":{"devices":4,"topology":"ring"}"#
        ),
        "{canonical}"
    );
    assert!(
        canonical.contains(r#""sweep":{"devices":[4,1,2]}"#),
        "axis order is meaningful: {canonical}"
    );
    let (again, _) =
        Request::from_json(&Json::parse(&canonical).unwrap()).unwrap();
    assert_eq!(again, req);
    assert_eq!(again.to_json(None).to_string(), canonical, "fixpoint");
    // Omitted topology defaults to fully_connected and then always
    // encodes (canonical form fills every subfield).
    let line = r#"{"v":1,"type":"scenario","n":512,"shape":"halo","device_set":{"devices":2}}"#;
    let (req, _) = Request::from_json(&Json::parse(line).unwrap()).unwrap();
    let canonical = req.to_json(None).to_string();
    assert!(
        canonical.contains(
            r#""device_set":{"devices":2,"topology":"fully_connected"}"#
        ),
        "{canonical}"
    );
    // A default device set adds zero wire surface: the canonical bytes
    // of a plain spec are exactly the pre-fabric ones.
    let minimal = r#"{"v":1,"type":"scenario","n":512}"#;
    let (req, _) =
        Request::from_json(&Json::parse(minimal).unwrap()).unwrap();
    assert_eq!(
        req.to_json(None).to_string(),
        r#"{"ask":"sim","iters":50,"n":512,"precision":"fp8","shape":"homogeneous","sparsity":"dense","streams":4,"type":"scenario","v":1}"#
    );
}

/// Single-device requests answer byte-identically to the pre-fabric
/// service — through the live service, on both the plain shape and the
/// `devices=1` scaling anchor of a multi-device shape — and the
/// multi-device answer grows exactly the `transfer_ms` field.
#[test]
fn single_device_answers_keep_their_pre_fabric_bytes() {
    let svc = Service::new(Config::mi300a());
    let v1 = Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 };
    let v1_bytes = svc.handle(&v1).to_item_json().to_string();
    assert!(
        !v1_bytes.contains("transfer_ms"),
        "single-device sim answers must not grow fields: {v1_bytes}"
    );
    // The devices=1 anchor of data_parallel is the same replica set, so
    // the answer bytes are identical.
    let line = r#"{"v":1,"type":"scenario","n":512,"shape":"data_parallel"}"#;
    let (req, _) = Request::from_json(&Json::parse(line).unwrap()).unwrap();
    match svc.handle(&req) {
        Response::Scenario { points } => {
            assert_eq!(points.len(), 1);
            assert_eq!(
                points[0].result.to_item_json().to_string(),
                v1_bytes
            );
        }
        other => panic!("unexpected response: {other:?}"),
    }
    // Four devices: same surface plus transfer_ms, and the point wire
    // form leads with its devices coordinate.
    let line = r#"{"v":1,"type":"scenario","n":512,"shape":"data_parallel","device_set":{"devices":4}}"#;
    let (req, _) = Request::from_json(&Json::parse(line).unwrap()).unwrap();
    match svc.handle(&req) {
        Response::Scenario { points } => {
            let wire = Response::Scenario { points: points.clone() }
                .to_json(None)
                .to_string();
            assert!(wire.contains(r#""devices":4"#), "{wire}");
            assert!(wire.contains("transfer_ms"), "{wire}");
        }
        other => panic!("unexpected response: {other:?}"),
    }
}

#[test]
fn scenario_decode_rejects_unknown_fields_and_oversized_sweeps() {
    for (line, want) in [
        (
            r#"{"v":1,"type":"scenario","n":512,"bogus":1}"#,
            ErrorCode::UnknownField,
        ),
        (
            r#"{"v":1,"type":"scenario","n":512,"sweep":{"bogus":[1]}}"#,
            ErrorCode::UnknownField,
        ),
        (
            r#"{"v":1,"type":"scenario","n":512,"sweep":{"streams":[]}}"#,
            ErrorCode::BadRequest,
        ),
        (
            r#"{"v":1,"type":"scenario","n":512,"objective":"latency"}"#,
            ErrorCode::BadRequest,
        ),
        (
            r#"{"v":1,"type":"submit","spec":{"n":512,"bogus":1}}"#,
            ErrorCode::UnknownField,
        ),
        (
            r#"{"v":1,"type":"submit","spec":{"n":512},"progress":1}"#,
            ErrorCode::BadRequest,
        ),
    ] {
        let (err, _) =
            Request::from_json(&Json::parse(line).unwrap()).unwrap_err();
        assert_eq!(err.code, want, "{line} -> {err}");
    }
    // The sweep cap is enforced before any work: 17 x 16 = 272 > 256.
    let ns: Vec<String> = (1..=17).map(|i| (64 * i).to_string()).collect();
    let ss: Vec<String> = (1..=16).map(|i| i.to_string()).collect();
    let line = format!(
        r#"{{"v":1,"type":"scenario","n":512,"sweep":{{"n":[{}],"streams":[{}]}}}}"#,
        ns.join(","),
        ss.join(",")
    );
    let (err, _) =
        Request::from_json(&Json::parse(&line).unwrap()).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRange);
    assert!(
        err.message.contains(&MAX_SWEEP_POINTS.to_string()),
        "{err}"
    );
}

/// The desugared v1 trio and their single-point scenario spellings
/// collide on one cache key, through the service.
#[test]
fn v1_requests_and_single_point_scenarios_share_cache_entries() {
    let svc = Service::new(Config::mi300a());
    let v1 = Request::Sparsity { n: 512, streams: 4 };
    let cold = svc.handle(&v1);
    assert_eq!(svc.engine_runs(), 1);
    let spec = ScenarioSpec::sparsity_question(512, 4);
    match svc.handle(&Request::Scenario { spec }) {
        Response::Scenario { points } => {
            assert_eq!(points.len(), 1);
            assert_eq!(
                points[0].result.to_item_json().to_string(),
                cold.to_item_json().to_string()
            );
        }
        other => panic!("unexpected response: {other:?}"),
    }
    assert_eq!(
        svc.engine_runs(),
        1,
        "the scenario point must hit the v1 request's cache entry"
    );
}

/// Submit → status → result through the in-process service; the job's
/// result serializes byte-identically to the synchronous sweep.
#[test]
fn job_lifecycle_through_the_service() {
    let svc = Service::new(Config::mi300a());
    let mut spec = ScenarioSpec::new(Ask::Sparsity);
    spec.n = 256;
    spec.sweep.streams = vec![1, 2];
    let view = match svc.handle(&Request::Submit {
        spec: spec.clone(),
        progress: false,
    }) {
        Response::Job(v) => v,
        other => panic!("unexpected submit response: {other:?}"),
    };
    assert_eq!(view.state, JobState::Queued);
    assert_eq!(view.total, 2);
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        match svc.handle(&Request::JobStatus { job: view.job }) {
            Response::Job(v) if v.state.terminal() => {
                assert_eq!(v.state, JobState::Done);
                assert_eq!((v.completed, v.total), (2, 2));
                break;
            }
            Response::Job(_) => {}
            other => panic!("unexpected status: {other:?}"),
        }
        assert!(std::time::Instant::now() < deadline, "job never finished");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let via_job = svc.handle(&Request::JobResult { job: view.job });
    let sync = svc.handle(&Request::Scenario { spec });
    assert_eq!(
        via_job.to_json(Some(1)).to_string(),
        sync.to_json(Some(1)).to_string()
    );
}

/// Drive a submitted job to its terminal state through `job_status`
/// polling; panics if it never finishes.
fn wait_terminal(svc: &Service, job: u64) -> JobView {
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        match svc.handle(&Request::JobStatus { job }) {
            Response::Job(v) if v.state.terminal() => return v,
            Response::Job(_) => {}
            other => panic!("unexpected status: {other:?}"),
        }
        assert!(std::time::Instant::now() < deadline, "job never finished");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Submit a cheap single-point job and return its accepted view.
fn submit_one_point(svc: &Service, n: usize) -> JobView {
    let spec = ScenarioSpec::sparsity_question(n, 2);
    match svc.handle(&Request::Submit { spec, progress: false }) {
        Response::Job(v) => v,
        other => panic!("unexpected submit response: {other:?}"),
    }
}

/// `job_result` on a job evicted past the retention window answers the
/// typed `unknown_job` error, not a hang or a stale result.
#[test]
fn job_result_after_eviction_is_a_typed_unknown_job() {
    // max_finished 1: finishing a second job evicts the first.
    let svc = Service::with_job_limits(
        Config::mi300a(),
        JobLimits { max_running: 1, max_queued: 16, max_finished: 1 },
    );
    let first = submit_one_point(&svc, 256);
    assert_eq!(wait_terminal(&svc, first.job).state, JobState::Done);
    assert!(matches!(
        svc.handle(&Request::JobResult { job: first.job }),
        Response::Scenario { .. }
    ));
    let second = submit_one_point(&svc, 512);
    assert_eq!(wait_terminal(&svc, second.job).state, JobState::Done);
    match svc.handle(&Request::JobResult { job: first.job }) {
        Response::Error { code, message } => {
            assert_eq!(code, ErrorCode::UnknownJob);
            assert!(message.contains("evicted"), "{message}");
        }
        other => panic!("unexpected evicted-job response: {other:?}"),
    }
    // The survivor still answers.
    assert!(matches!(
        svc.handle(&Request::JobResult { job: second.job }),
        Response::Scenario { .. }
    ));
}

/// `job_cancel` on an already-done job is a no-op: the terminal state
/// is preserved (not rewritten to cancelled) and the result survives.
#[test]
fn job_cancel_on_a_done_job_is_a_noop() {
    let svc = Service::new(Config::mi300a());
    let view = submit_one_point(&svc, 256);
    assert_eq!(wait_terminal(&svc, view.job).state, JobState::Done);
    match svc.handle(&Request::JobCancel { job: view.job }) {
        Response::Job(v) => {
            assert_eq!(v.state, JobState::Done, "cancel rewrote a terminal");
            assert_eq!((v.completed, v.total), (1, 1));
        }
        other => panic!("unexpected cancel response: {other:?}"),
    }
    // The no-op cancel leaves the stored result fetchable.
    assert!(matches!(
        svc.handle(&Request::JobResult { job: view.job }),
        Response::Scenario { .. }
    ));
}

#[test]
fn error_code_wire_spellings_are_stable() {
    // The wire spellings are part of the v1 contract (DESIGN.md §6.3):
    // renaming one is a protocol version bump, so pin them.
    let want = [
        "bad_version",
        "bad_request",
        "unknown_type",
        "unknown_field",
        "bad_range",
        "unknown_experiment",
        "unknown_entry",
        "runtime",
        "overloaded",
        "unknown_job",
        "not_ready",
        "unknown_backend",
        "unsupported_by_backend",
    ];
    assert_eq!(ErrorCode::ALL.len(), want.len());
    for (c, w) in ErrorCode::ALL.iter().zip(want) {
        assert_eq!(c.as_str(), w);
        assert_eq!(ErrorCode::parse(w), Some(*c));
    }
}

// ---------------------------------------------------------------------
// Backend surface (DESIGN.md §6.8).
// ---------------------------------------------------------------------

/// The per-backend cold-execution counters are flattened onto `stats`
/// under pinned names, one per registry id.
#[test]
fn stats_wire_pins_the_per_backend_counter_fields() {
    let resp = Response::Stats {
        cache: CacheStats::default(),
        engine_runs: 7,
        backend_runs: vec![4, 3, 0],
        cluster: None,
    };
    let wire = resp.to_json(None).to_string();
    assert!(wire.contains(r#""engine_runs":7"#), "{wire}");
    assert!(wire.contains(r#""engine_runs_des":4"#), "{wire}");
    assert!(wire.contains(r#""engine_runs_analytic":3"#), "{wire}");
    // The router's slot is present but permanently zero: auto resolves
    // to a concrete engine before counting (DESIGN.md §6.10).
    assert!(wire.contains(r#""engine_runs_auto":0"#), "{wire}");
    // The cluster amendment (DESIGN.md §6.9) must not leak into a
    // standalone stats line: no cluster_* key when `cluster` is None.
    assert!(!wire.contains("cluster"), "{wire}");
    let (back, _) =
        Response::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, resp);
}

/// Coordinator stats flatten the `cluster_*` block under pinned names;
/// the block is all-or-nothing on decode (a stray subset is typed
/// `bad_request`, keyed on `cluster_workers`).
#[test]
fn stats_wire_pins_the_cluster_counter_fields() {
    let resp = Response::Stats {
        cache: CacheStats::default(),
        engine_runs: 7,
        backend_runs: vec![4, 3, 0],
        cluster: Some(ClusterStats {
            workers: 2,
            points_routed: 64,
            proxied: 1,
            retries: 9,
            point_failures: 0,
        }),
    };
    let wire = resp.to_json(None).to_string();
    for needle in [
        r#""cluster_workers":2"#,
        r#""cluster_points_routed":64"#,
        r#""cluster_proxied":1"#,
        r#""cluster_retries":9"#,
        r#""cluster_point_failures":0"#,
    ] {
        assert!(wire.contains(needle), "missing {needle} in {wire}");
    }
    let (back, _) =
        Response::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, resp);
    // A lone cluster counter without `cluster_workers` is rejected.
    let partial = wire
        .replace(r#""cluster_workers":2,"#, "")
        .replace(r#""cluster_points_routed":64,"#, "");
    let err =
        Response::from_json(&Json::parse(&partial).unwrap()).unwrap_err();
    assert_eq!(err.code, ErrorCode::BadRequest);
    assert!(err.message.contains("cluster_workers"), "{}", err.message);
    // And a full block missing one member is a typed missing-field
    // error rather than a silent zero.
    let hole = wire.replace(r#""cluster_retries":9,"#, "");
    let err =
        Response::from_json(&Json::parse(&hole).unwrap()).unwrap_err();
    assert!(err.message.contains("cluster_retries"), "{}", err.message);
}

/// Satellite: `list_experiments` surfaces each spec's `deterministic`
/// flag (added in PR 3, never on the wire until now), round-tripping
/// through the strict client-side decoder.
#[test]
fn list_experiments_surfaces_the_deterministic_flag_on_the_wire() {
    let svc = Service::new(Config::mi300a());
    let resp = svc.handle(&Request::ListExperiments);
    let wire = resp.to_json(None).to_string();
    assert!(wire.contains(r#""deterministic":true"#), "{wire}");
    let (back, _) =
        Response::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, resp);
    match back {
        Response::Experiments { experiments } => {
            for (info, spec) in
                experiments.iter().zip(mi300a_char::experiments::REGISTRY)
            {
                assert_eq!(info.deterministic, spec.deterministic, "{}",
                           spec.id);
            }
        }
        other => panic!("unexpected response: {other:?}"),
    }
}

/// On batch items, `"backend"` stays an envelope-only key *except* on
/// `scenario` items, where it is the spec's own payload field.
#[test]
fn batch_items_reject_backend_except_as_a_scenario_spec_field() {
    let ok = r#"{"v":1,"type":"batch","items":[{"type":"scenario","backend":"analytic","n":512}]}"#;
    let (req, _) = Request::from_json(&Json::parse(ok).unwrap()).unwrap();
    match &req {
        Request::Batch { items } => match &items[0] {
            Request::Scenario { spec } => {
                assert_eq!(spec.backend, Some(BackendId::Analytic))
            }
            other => panic!("unexpected item: {other:?}"),
        },
        other => panic!("unexpected request: {other:?}"),
    }
    // ...and the bytes the encoder produces for that value decode back.
    let wire = req.to_json(None).to_string();
    let (back, _) = Request::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(back, req);

    let bad = r#"{"v":1,"type":"batch","items":[{"type":"sim","backend":"analytic","n":512,"precision":"fp8","streams":4}]}"#;
    let (err, _) =
        Request::from_json(&Json::parse(bad).unwrap()).unwrap_err();
    assert!(err.message.contains("batch envelope"), "{err}");
}

/// `backends` discovery lists the registry in order and round-trips.
#[test]
fn backends_discovery_round_trips_and_names_the_registry() {
    let svc = Service::new(Config::mi300a());
    let resp = svc.handle(&Request::Backends);
    let wire = resp.to_json(Some(4)).to_string();
    let (back, id) =
        Response::from_json(&Json::parse(&wire).unwrap()).unwrap();
    assert_eq!(id, Some(4));
    assert_eq!(back, resp);
    match back {
        Response::Backends { backends } => {
            let ids: Vec<&str> =
                backends.iter().map(|b| b.id.as_str()).collect();
            let want: Vec<&str> =
                BackendId::ALL.iter().map(|b| b.as_str()).collect();
            assert_eq!(ids, want);
            assert!(backends[0].default, "des is the default");
        }
        other => panic!("unexpected response: {other:?}"),
    }
}
