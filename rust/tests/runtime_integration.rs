//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! vacuously with a note) when the artifacts directory is absent so
//! `cargo test` stays green on a fresh checkout.

use mi300a_char::runtime::{Executor, Input, Manifest};
use mi300a_char::sparsity::{compress_2_4, prune_2_4};
use mi300a_char::util::json::Json;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Deterministic inputs shared with python/tests/test_aot.py::TestGoldens.
fn golden_inputs(n: usize) -> (Vec<f32>, Vec<f32>) {
    let a: Vec<f32> =
        (0..n * n).map(|i| ((i % 13) as f32 - 6.0) / 3.0).collect();
    let b: Vec<f32> =
        (0..n * n).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
    (a, b)
}

#[test]
fn fp8_gemm_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let golden_path = dir.join("golden_gemm_fp8_128.json");
    if !golden_path.exists() {
        eprintln!("skipping: golden file not generated (run pytest)");
        return;
    }
    let golden =
        Json::parse(&std::fs::read_to_string(&golden_path).unwrap()).unwrap();

    let mut exec = Executor::new(&dir).unwrap();
    let (a, b) = golden_inputs(128);
    let out = exec.run_f32("gemm_fp8_128", &[a, b]).unwrap();
    assert_eq!(out.len(), 128 * 128);

    let checksum: f64 = out.iter().map(|&v| v as f64).sum();
    let want = golden.get("checksum").unwrap().as_f64().unwrap();
    let rel = (checksum - want).abs() / want.abs().max(1.0);
    assert!(
        rel < 1e-3,
        "checksum {checksum} vs python golden {want} (rel {rel:.2e})"
    );

    let corners = golden.get("corner").unwrap().as_arr().unwrap();
    let got = [
        out[0],
        out[127],
        out[127 * 128],
        out[128 * 128 - 1],
    ];
    for (g, w) in got.iter().zip(corners) {
        let w = w.as_f64().unwrap() as f32;
        assert!(
            (g - w).abs() < 1e-2 + 1e-3 * w.abs(),
            "corner {g} vs {w}"
        );
    }
}

#[test]
fn every_manifest_entry_loads_and_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let mut exec = Executor::new(&dir).unwrap();
    for entry in manifest.entries.clone() {
        // The 512x2048x1024 rectangular GEMM is large; keep it but give
        // it small deterministic values like the rest.
        let inputs: Vec<Input> = entry
            .inputs
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let n = t.elements();
                match t.dtype {
                    mi300a_char::runtime::DType::F32 => Input::F32(
                        (0..n)
                            .map(|j| (((j + i) % 11) as f32 - 5.0) / 7.0)
                            .collect(),
                    ),
                    mi300a_char::runtime::DType::I32 => {
                        // 2:4 indices: ascending pairs within each group.
                        Input::I32(
                            (0..n)
                                .map(|j| if j % 2 == 0 { 0 } else { 3 })
                                .collect(),
                        )
                    }
                }
            })
            .collect();
        let loaded = exec.load(&entry.name).unwrap();
        let out = loaded
            .run(&inputs)
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        let want: usize = entry.outputs[0].shape.iter().product();
        assert_eq!(out.len(), want, "{} output size", entry.name);
        assert!(
            out.iter().all(|v| v.is_finite()),
            "{} produced non-finite values",
            entry.name
        );
    }
}

#[test]
fn sparse_artifact_agrees_with_rust_reference_matmul() {
    let Some(dir) = artifacts_dir() else { return };
    let mut exec = Executor::new(&dir).unwrap();
    let n = 256;
    let a: Vec<f32> = (0..n * n)
        .map(|i| (((i * 7 + 3) % 23) as f32 - 11.0) / 11.0)
        .collect();
    let b: Vec<f32> = (0..n * n)
        .map(|i| (((i * 5 + 1) % 17) as f32 - 8.0) / 16.0)
        .collect();
    let pruned = prune_2_4(&a, n, n);
    let c = compress_2_4(&pruned, n, n);
    let idx: Vec<i32> = c.indices.iter().map(|&i| i as i32).collect();

    let entry = exec.load("gemm_sparse24_256").unwrap();
    let out = entry
        .run(&[Input::F32(c.values.clone()), Input::I32(idx), Input::F32(b.clone())])
        .unwrap();

    // Rust-side reference: dense matmul of the pruned matrix.
    let mut want = vec![0.0f64; n * n];
    for i in 0..n {
        for k in 0..n {
            let av = pruned[i * n + k] as f64;
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                want[i * n + j] += av * b[k * n + j] as f64;
            }
        }
    }
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(o, w)| (*o as f64 - w).abs())
        .fold(0.0, f64::max);
    assert!(max_err < 1e-3, "sparse artifact max err {max_err}");
}
