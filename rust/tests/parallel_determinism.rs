//! Determinism regression: the parallel experiment sweep must produce
//! byte-identical output to the serial path for a fixed seed, across
//! 1/2/8 worker threads. Every driver derives its randomness from
//! (seed, item index) — never from scheduling — so the JSON and the
//! rendered reports must not move by a single byte when the worker
//! count changes.

use mi300a_char::config::Config;
use mi300a_char::experiments::{run_all, REGISTRY};

fn sweep_fingerprints(cfg: &Config, workers: usize) -> Vec<String> {
    run_all(cfg, workers)
        .iter()
        .map(|r| {
            format!("{}\n{}\n{}", r.id, r.json.to_string_pretty(), r.render())
        })
        .collect()
}

#[test]
fn parallel_sweep_bit_identical_across_worker_counts() {
    let cfg = Config::mi300a();
    let serial = sweep_fingerprints(&cfg, 1);
    assert_eq!(serial.len(), REGISTRY.len());
    let mut eight = None;
    for workers in [2usize, 8] {
        let parallel = sweep_fingerprints(&cfg, workers);
        assert_eq!(parallel.len(), serial.len(), "workers={workers}");
        for ((a, b), s) in parallel.iter().zip(&serial).zip(REGISTRY) {
            assert_eq!(
                a, b,
                "experiment {} diverged between workers=1 and workers={workers}",
                s.id
            );
        }
        if workers == 8 {
            eight = Some(parallel);
        }
    }
    // Repeat-stability at the same worker count (guards against any
    // scheduling-order leak): a second 8-worker sweep must match the
    // first. Reuses the sweeps above instead of running the full suite
    // extra times.
    let again = sweep_fingerprints(&cfg, 8);
    assert_eq!(again, eight.unwrap(), "8-worker sweep not repeatable");
}
