//! Integration: the TCP transport over the typed api::Service — legacy
//! text framing, versioned JSON framing, their byte-identical
//! equivalence on one socket, id pipelining, typed protocol errors,
//! batching, the result cache (repeat requests answered byte-identically
//! with zero engine re-execution, proven over the wire through `stats`),
//! and concurrent-client determinism. RUN is covered by
//! runtime_integration.rs; here the server stays on the simulator paths
//! so the tests are artifact-independent.

use mi300a_char::api::{
    Ask, Client, ErrorCode, Request, Response, ScenarioSpec, Service,
};
use mi300a_char::config::Config;
use mi300a_char::isa::Precision;
use mi300a_char::serve::{serve, serve_on, IoModel, MAX_LINE_BYTES};
use mi300a_char::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Connect to the server (retrying while the listener comes up).
fn connect(port: u16) -> TcpStream {
    for _ in 0..200 {
        if let Ok(c) = TcpStream::connect(("127.0.0.1", port)) {
            return c;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("server did not come up on port {port}");
}

/// Reserve an ephemeral port for the server to bind.
fn free_port() -> u16 {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = probe.local_addr().unwrap().port();
    drop(probe);
    port
}

/// Spawn a server for `conns` connections on a fresh port.
fn spawn_server(conns: usize) -> (u16, std::thread::JoinHandle<()>) {
    let port = free_port();
    let handle = std::thread::spawn(move || {
        serve(Config::mi300a(), &format!("127.0.0.1:{port}"), Some(conns))
            .unwrap();
    });
    (port, handle)
}

#[test]
fn legacy_sim_plan_sparsity_roundtrip() {
    let (port, handle) = spawn_server(1);

    let mut conn = connect(port);
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ask = |cmd: &str| -> Json {
        writeln!(conn, "{cmd}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // SIM: 4-way concurrent FP8 512^3. Responses carry the envelope.
    let sim = ask("SIM 512 fp8 4");
    assert_eq!(sim.get("v"), Some(&Json::Num(1.0)));
    assert_eq!(sim.get("type").unwrap().as_str(), Some("sim"));
    let speedup = sim.get("speedup_vs_serial").unwrap().as_f64().unwrap();
    assert!(speedup > 1.0 && speedup < 4.0, "speedup {speedup}");
    let fair = sim.get("fairness").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&fair));

    // PLAN: throughput objective; groups are structured objects now.
    let plan = ask("PLAN throughput 8 512");
    let groups = plan.get("groups").unwrap().as_arr().unwrap();
    assert!(!groups.is_empty());
    assert!(groups[0].get("streams").unwrap().as_usize().unwrap() >= 1);
    assert!(groups[0].get("kernels").unwrap().as_arr().is_some());
    assert_eq!(plan.get("sparse"), Some(&Json::Bool(true)));
    assert_eq!(plan.get("objective").unwrap().as_str(), Some("throughput"));

    // SPARSITY: isolated -> dense; concurrent decision context encoded.
    let sp = ask("SPARSITY 512 1");
    assert_eq!(sp.get("enable"), Some(&Json::Bool(false)));
    let sp4 = ask("SPARSITY 512 4");
    assert_eq!(sp4.get("enable"), Some(&Json::Bool(true)));
    let conc = sp4.get("concurrent_speedup").unwrap().as_f64().unwrap();
    assert!((1.2..1.4).contains(&conc), "~1.3x expected: {conc}");

    // Errors are structured with typed codes, not fatal.
    let bad = ask("SIM abc fp8 4");
    assert!(bad.get("error").is_some());
    assert_eq!(bad.get("code").unwrap().as_str(), Some("bad_request"));

    // Out-of-range streams: a typed range error naming the accepted
    // range — not the pre-API silent clamp to 16.
    let oor = ask("SIM 512 fp8 32");
    assert_eq!(oor.get("code").unwrap().as_str(), Some("bad_range"));
    let msg = oor.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains("1..=16") && msg.contains("32"), "{msg}");

    writeln!(conn, "QUIT").unwrap();
    drop(conn);
    handle.join().unwrap();
}

#[test]
fn json_and_legacy_framings_answer_byte_identically() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut ask_raw = |line: &str| -> String {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };

    // Same socket, alternating framings: the JSON form (without an id)
    // and the legacy text form must answer with identical bytes.
    let pairs = [
        (
            "SIM 512 fp8 4",
            r#"{"v":1,"type":"sim","n":512,"precision":"fp8","streams":4}"#,
        ),
        (
            "PLAN throughput 8 512",
            r#"{"v":1,"type":"plan","objective":"throughput","streams":8,"n":512,"precision":"fp8"}"#,
        ),
        (
            "SPARSITY 512 4",
            r#"{"v":1,"type":"sparsity","n":512,"streams":4}"#,
        ),
        ("LIST", r#"{"v":1,"type":"list_experiments"}"#),
        ("CONFIG", r#"{"v":1,"type":"config"}"#),
    ];
    for (legacy, json) in pairs {
        let a = ask_raw(legacy);
        let b = ask_raw(json);
        assert_eq!(a, b, "framings diverged for {legacy:?}");
        assert!(a.ends_with('\n'));
    }

    let err = ask_raw("SIM abc fp8 4"); // typed error, connection stays up
    assert!(err.contains("bad_request"), "{err}");
    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

#[test]
fn json_pipelining_echoes_request_ids() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);

    // Two requests written back-to-back before reading: responses come
    // back in order, each echoing its request id.
    write!(
        writer,
        "{}\n{}\n",
        r#"{"v":1,"id":7,"type":"sparsity","n":512,"streams":4}"#,
        r#"{"v":1,"id":8,"type":"config"}"#,
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let first = Json::parse(line.trim()).unwrap();
    assert_eq!(first.get("id"), Some(&Json::Num(7.0)));
    assert_eq!(first.get("type").unwrap().as_str(), Some("sparsity"));
    line.clear();
    reader.read_line(&mut line).unwrap();
    let second = Json::parse(line.trim()).unwrap();
    assert_eq!(second.get("id"), Some(&Json::Num(8.0)));
    assert_eq!(second.get("type").unwrap().as_str(), Some("config"));

    // A bad request still gets its id echoed (salvaged envelope).
    line.clear();
    writeln!(writer, r#"{{"v":99,"id":13,"type":"config"}}"#).unwrap();
    reader.read_line(&mut line).unwrap();
    let err = Json::parse(line.trim()).unwrap();
    assert_eq!(err.get("id"), Some(&Json::Num(13.0)));
    assert_eq!(err.get("code").unwrap().as_str(), Some("bad_version"));

    // Unknown fields are rejected, not ignored.
    line.clear();
    writeln!(
        writer,
        r#"{{"v":1,"id":14,"type":"config","bogus":true}}"#
    )
    .unwrap();
    reader.read_line(&mut line).unwrap();
    let err = Json::parse(line.trim()).unwrap();
    assert_eq!(err.get("id"), Some(&Json::Num(14.0)));
    assert_eq!(err.get("code").unwrap().as_str(), Some("unknown_field"));

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

#[test]
fn typed_client_speaks_the_versioned_protocol() {
    let (port, handle) = spawn_server(1);
    let mut client =
        Client::connect_retry(format!("127.0.0.1:{port}").as_str(), 200)
            .unwrap();

    match client
        .request(&Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
        })
        .unwrap()
    {
        Response::Sim { speedup_vs_serial, fairness, .. } => {
            assert!(speedup_vs_serial > 1.0 && speedup_vs_serial < 4.0);
            assert!((0.0..=1.0).contains(&fairness));
        }
        other => panic!("unexpected response: {other:?}"),
    }

    match client.request(&Request::ListExperiments).unwrap() {
        Response::Experiments { experiments } => {
            assert_eq!(
                experiments.len(),
                mi300a_char::experiments::REGISTRY.len()
            );
        }
        other => panic!("unexpected response: {other:?}"),
    }

    // Protocol-level failures surface as typed Response::Error.
    match client
        .request(&Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 0,
        })
        .unwrap()
    {
        Response::Error { code, message } => {
            assert_eq!(code, ErrorCode::BadRange);
            assert!(message.contains("1..=16"), "{message}");
        }
        other => panic!("unexpected response: {other:?}"),
    }

    // The same connection can still drop to the legacy framing.
    let legacy = client.raw_line("SPARSITY 512 4").unwrap();
    assert_eq!(legacy.get("enable"), Some(&Json::Bool(true)));

    client.raw_line("QUIT").ok();
    drop(client);
    handle.join().unwrap();
}

/// A batch of N mixed requests over one TCP connection answers exactly
/// like the N requests sent sequentially on that connection: item `k`
/// equals sequential response `k` minus the `"v"` envelope key.
#[test]
fn batch_over_one_connection_matches_sequential_requests() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut ask_raw = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    // Envelope-less item payloads; a standalone request line is the
    // same payload with `"v":1` prefixed.
    let items = [
        r#"{"type":"sim","n":512,"precision":"fp8","streams":4}"#,
        r#"{"type":"plan","objective":"throughput","streams":8,"n":512,"precision":"fp8"}"#,
        r#"{"type":"sparsity","n":512,"streams":4}"#,
        r#"{"type":"sparsity","n":512,"streams":4}"#, // repeat: cache hit
        r#"{"type":"config"}"#,
    ];
    let sequential: Vec<Json> = items
        .iter()
        .map(|payload| ask_raw(&format!(r#"{{"v":1,{}"#, &payload[1..])))
        .collect();

    let batch_line =
        format!(r#"{{"v":1,"type":"batch","items":[{}]}}"#, items.join(","));
    let batch = ask_raw(&batch_line);
    assert_eq!(batch.get("type").unwrap().as_str(), Some("batch"));
    let got = batch.get("items").unwrap().as_arr().unwrap();
    assert_eq!(got.len(), sequential.len());
    for (i, (item, seq)) in got.iter().zip(&sequential).enumerate() {
        let mut expect = seq.clone();
        if let Json::Obj(m) = &mut expect {
            m.remove("v");
        }
        assert_eq!(
            item.to_string(),
            expect.to_string(),
            "batch item {i} diverged from its sequential answer"
        );
    }

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

/// Repeat requests over the wire are answered byte-identically from the
/// cache with zero engine re-execution — proven by the `stats`
/// engine-runs counter staying put — while `"cache":false` forces a
/// cold run without touching the hit/miss counters.
#[test]
fn wire_repeats_hit_the_cache_and_cache_false_bypasses_it() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut ask_raw = |line: &str| -> String {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };
    let stats = |raw: &str| -> (f64, f64, f64) {
        let v = Json::parse(raw.trim()).unwrap();
        (
            v.get("engine_runs").unwrap().as_f64().unwrap(),
            v.get("cache_hits").unwrap().as_f64().unwrap(),
            v.get("cache_misses").unwrap().as_f64().unwrap(),
        )
    };

    let stats_req = r#"{"v":1,"type":"stats"}"#;
    assert_eq!(
        stats(&ask_raw(stats_req)),
        (0.0, 0.0, 0.0),
        "fresh server"
    );

    let sim = r#"{"v":1,"type":"sim","n":256,"precision":"fp8","streams":2}"#;
    let cold = ask_raw(sim);
    assert_eq!(stats(&ask_raw(stats_req)), (1.0, 0.0, 1.0));

    // Byte-identical repeat, engine-invocation counter unchanged.
    let warm = ask_raw(sim);
    assert_eq!(warm, cold, "cached response must be byte-identical");
    assert_eq!(
        stats(&ask_raw(stats_req)),
        (1.0, 1.0, 1.0),
        "repeat must not re-enter the engine"
    );

    // The escape hatch: cold run, no hit/miss accounting.
    let bypass = ask_raw(
        r#"{"v":1,"cache":false,"type":"sim","n":256,"precision":"fp8","streams":2}"#,
    );
    assert_eq!(bypass, cold, "cold runs stay deterministic");
    assert_eq!(stats(&ask_raw(stats_req)), (2.0, 1.0, 1.0));

    // Legacy framing shares the same cache (STATS desugars to stats).
    let legacy = ask_raw("STATS");
    assert_eq!(stats(&legacy), (2.0, 1.0, 1.0));

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

/// Acceptance (ISSUE 4): a `scenario` sweep over the wire answers each
/// point byte-identically to the equivalent sequence of v1 `sim`
/// requests on the same connection.
#[test]
fn scenario_sweep_matches_the_equivalent_v1_sim_sequence() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut ask_raw = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    // The v1 baseline, sequentially (these also warm the cache — the
    // sweep must answer identically either way).
    let streams = [1usize, 2, 4];
    let sequential: Vec<Json> = streams
        .iter()
        .map(|s| {
            ask_raw(&format!(
                r#"{{"v":1,"type":"sim","n":256,"precision":"fp8","streams":{s}}}"#
            ))
        })
        .collect();

    let sweep = ask_raw(
        r#"{"v":1,"type":"scenario","n":256,"precision":"fp8","sweep":{"streams":[1,2,4]}}"#,
    );
    assert_eq!(sweep.get("type").unwrap().as_str(), Some("scenario"));
    let points = sweep.get("points").unwrap().as_arr().unwrap();
    assert_eq!(points.len(), sequential.len());
    for (i, (point, seq)) in points.iter().zip(&sequential).enumerate() {
        let mut expect = seq.clone();
        if let Json::Obj(m) = &mut expect {
            m.remove("v");
        }
        assert_eq!(
            point.get("result").unwrap().to_string(),
            expect.to_string(),
            "sweep point {i} diverged from its v1 answer"
        );
        assert_eq!(
            point
                .get("point")
                .unwrap()
                .get("streams")
                .unwrap()
                .as_usize()
                .unwrap(),
            streams[i]
        );
    }

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

/// Acceptance (ISSUE 4): a submitted sweep completes asynchronously —
/// states observable via `job_status`, at least one pushed `progress`
/// frame, and the fetched result equals the synchronous sweep.
#[test]
fn job_lifecycle_over_the_wire_with_progress_push() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut progress_frames: Vec<Json> = Vec::new();

    // Read lines until a non-progress one arrives; frames (all tagged
    // with the submit id, 5) are collected on the side.
    let read_response = |reader: &mut BufReader<TcpStream>,
                         progress: &mut Vec<Json>|
     -> Json {
        loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let v = Json::parse(line.trim()).unwrap();
            if v.get("type").and_then(|t| t.as_str()) == Some("progress") {
                assert_eq!(
                    v.get("id"),
                    Some(&Json::Num(5.0)),
                    "frames must carry the submitting request's id"
                );
                progress.push(v);
                continue;
            }
            return v;
        }
    };

    writeln!(
        writer,
        r#"{{"v":1,"id":5,"type":"submit","progress":true,"spec":{{"n":256,"sweep":{{"streams":[1,2]}}}}}}"#
    )
    .unwrap();
    let submitted = read_response(&mut reader, &mut progress_frames);
    assert_eq!(submitted.get("type").unwrap().as_str(), Some("job"));
    assert_eq!(submitted.get("id"), Some(&Json::Num(5.0)));
    let job = submitted.get("job").unwrap().as_usize().unwrap();
    assert_eq!(submitted.get("total").unwrap().as_usize(), Some(2));

    // Poll status to done; queued/running/done are all legal sightings.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut reqid = 6u64;
    let mut seen_states = Vec::new();
    loop {
        writeln!(
            writer,
            r#"{{"v":1,"id":{reqid},"type":"job_status","job":{job}}}"#
        )
        .unwrap();
        reqid += 1;
        let st = read_response(&mut reader, &mut progress_frames);
        assert_eq!(st.get("type").unwrap().as_str(), Some("job"));
        let state = st.get("state").unwrap().as_str().unwrap().to_string();
        seen_states.push(state.clone());
        if state == "done" {
            assert_eq!(st.get("completed").unwrap().as_usize(), Some(2));
            break;
        }
        assert!(
            state == "queued" || state == "running",
            "unexpected state {state:?}"
        );
        assert!(Instant::now() < deadline, "job never finished");
        std::thread::sleep(Duration::from_millis(2));
    }

    // Fetch the result and compare to the synchronous sweep (cache
    // makes them byte-identical minus the envelope id).
    writeln!(
        writer,
        r#"{{"v":1,"id":90,"type":"job_result","job":{job}}}"#
    )
    .unwrap();
    let via_job = read_response(&mut reader, &mut progress_frames);
    assert_eq!(via_job.get("type").unwrap().as_str(), Some("scenario"));
    writeln!(
        writer,
        r#"{{"v":1,"id":91,"type":"scenario","n":256,"sweep":{{"streams":[1,2]}}}}"#
    )
    .unwrap();
    let sync = read_response(&mut reader, &mut progress_frames);
    let strip = |v: &Json| {
        let mut v = v.clone();
        if let Json::Obj(m) = &mut v {
            m.remove("id");
        }
        v.to_string()
    };
    assert_eq!(strip(&via_job), strip(&sync));

    // The progress contract: >= 1 frame (the registration snapshot is
    // guaranteed even for instant jobs), ending terminal. The pusher
    // thread writes frames asynchronously, so drain the wire until the
    // terminal frame arrives (no further requests are in flight, so
    // only frames remain).
    let is_done = |frames: &[Json]| {
        frames.last().and_then(|f| f.get("state")).and_then(Json::as_str)
            == Some("done")
    };
    while !is_done(&progress_frames) {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(
            v.get("type").and_then(|t| t.as_str()),
            Some("progress"),
            "only frames may remain on the wire: {line}"
        );
        progress_frames.push(v);
    }
    assert!(
        !progress_frames.is_empty(),
        "at least one progress frame must be pushed"
    );
    let last = progress_frames.last().unwrap();
    assert_eq!(last.get("state").unwrap().as_str(), Some("done"));
    assert_eq!(last.get("completed").unwrap().as_usize(), Some(2));

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

/// Acceptance (ISSUE 4): a job is cancellable mid-sweep; `job_result`
/// afterwards is a typed `not_ready` error.
#[test]
fn jobs_cancel_mid_sweep_over_the_wire() {
    let (port, handle) = spawn_server(1);
    let mut client =
        Client::connect_retry(format!("127.0.0.1:{port}").as_str(), 200)
            .unwrap();
    let mut spec = ScenarioSpec::new(Ask::Sim);
    spec.n = 2048;
    spec.streams = 8;
    // 128 heavy points so the immediate cancel lands mid-sweep.
    spec.sweep.iters = (1..=128).collect();
    let view = match client.submit(&spec, false).unwrap() {
        Response::Job(v) => v,
        other => panic!("unexpected submit response: {other:?}"),
    };
    match client.request(&Request::JobCancel { job: view.job }).unwrap() {
        Response::Job(_) => {}
        other => panic!("unexpected cancel response: {other:?}"),
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    let final_view = loop {
        match client.request(&Request::JobStatus { job: view.job }).unwrap()
        {
            Response::Job(v) if v.state.terminal() => break v,
            Response::Job(_) => {}
            other => panic!("unexpected status: {other:?}"),
        }
        assert!(Instant::now() < deadline, "cancel never landed");
        std::thread::sleep(Duration::from_millis(2));
    };
    assert_eq!(final_view.state, mi300a_char::api::JobState::Cancelled);
    assert!(
        final_view.completed < final_view.total,
        "cancel must land mid-sweep ({}/{})",
        final_view.completed,
        final_view.total
    );
    match client.request(&Request::JobResult { job: view.job }).unwrap() {
        Response::Error { code, .. } => {
            assert_eq!(code, ErrorCode::NotReady)
        }
        other => panic!("expected not_ready, got {other:?}"),
    }
    client.raw_line("QUIT").ok();
    drop(client);
    handle.join().unwrap();
}

/// The native client's progress-callback wait: every frame lands in the
/// callback (snapshot → per-point → terminal) and the result follows.
#[test]
fn native_client_submit_and_wait_streams_progress() {
    let (port, handle) = spawn_server(1);
    let mut client =
        Client::connect_retry(format!("127.0.0.1:{port}").as_str(), 200)
            .unwrap();
    let mut spec = ScenarioSpec::new(Ask::Sparsity);
    spec.n = 256;
    spec.sweep.streams = vec![1, 2, 4];
    let mut frames = Vec::new();
    let resp = client
        .submit_and_wait(&spec, |p| frames.push(*p))
        .unwrap();
    match resp {
        Response::Scenario { points } => assert_eq!(points.len(), 3),
        other => panic!("unexpected response: {other:?}"),
    }
    assert!(!frames.is_empty());
    let last = frames.last().unwrap();
    assert!(last.state.terminal());
    assert_eq!((last.completed, last.total), (3, 3));
    // The read timeout is restored after the wait.
    assert!(client.timeout().is_some());
    client.raw_line("QUIT").ok();
    drop(client);
    handle.join().unwrap();
}

/// Satellite (ISSUE 4): a dead-quiet server surfaces as a typed
/// timeout error on the client instead of a forever-hang.
#[test]
fn client_read_timeout_is_a_typed_error_not_a_hang() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Accept the connection but never answer anything.
    let silent = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        std::thread::sleep(Duration::from_secs(2));
        drop(stream);
    });
    let mut client = Client::connect(addr).unwrap();
    assert_eq!(client.timeout(), Some(mi300a_char::api::DEFAULT_TIMEOUT));
    client.set_timeout(Some(Duration::from_millis(50))).unwrap();
    let err = client.request(&Request::Stats).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert!(err.to_string().contains("set_timeout"), "{err}");
    drop(client);
    silent.join().unwrap();
}

/// Backend selection over the wire (DESIGN.md §6.8): the `"backend"`
/// envelope key routes a v1 `sim` to the analytic engine (zero DES
/// executions, proven via the per-backend `stats` counters), `backends`
/// discovery lists the registry, unknown ids are typed, and the
/// backend-less form answers byte-identically either way.
#[test]
fn backend_selection_and_discovery_over_the_wire() {
    let (port, handle) = spawn_server(1);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut ask_raw = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    // Discovery first: the registry over the wire.
    let discovery = ask_raw(r#"{"v":1,"type":"backends"}"#);
    assert_eq!(discovery.get("type").unwrap().as_str(), Some("backends"));
    let backends = discovery.get("backends").unwrap().as_arr().unwrap();
    let ids: Vec<&str> = backends
        .iter()
        .map(|b| b.get("id").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(ids, vec!["des", "analytic"]);
    assert_eq!(backends[0].get("default"), Some(&Json::Bool(true)));

    // An analytic sim answers the v1 shape without touching the DES.
    let analytic = ask_raw(
        r#"{"v":1,"backend":"analytic","type":"sim","n":512,"precision":"fp8","streams":4}"#,
    );
    assert_eq!(analytic.get("type").unwrap().as_str(), Some("sim"));
    let sp = analytic.get("speedup_vs_serial").unwrap().as_f64().unwrap();
    assert!(sp > 1.0 && sp < 4.0, "analytic speedup {sp}");
    let stats = ask_raw(r#"{"v":1,"type":"stats"}"#);
    assert_eq!(stats.get("engine_runs_analytic"), Some(&Json::Num(1.0)));
    assert_eq!(stats.get("engine_runs_des"), Some(&Json::Num(0.0)));

    // The backend-less form runs the DES and stays byte-identical to
    // the explicit des selection (modulo the cache: ask des twice, once
    // per spelling — the second is a cache hit of the first).
    let omitted = ask_raw(
        r#"{"v":1,"type":"sim","n":512,"precision":"fp8","streams":4}"#,
    );
    let explicit = ask_raw(
        r#"{"v":1,"backend":"des","type":"sim","n":512,"precision":"fp8","streams":4}"#,
    );
    assert_eq!(omitted.to_string(), explicit.to_string());
    let stats = ask_raw(r#"{"v":1,"type":"stats"}"#);
    assert_eq!(stats.get("engine_runs_des"), Some(&Json::Num(1.0)));

    // Typed errors: unknown id, and a selector on a non-scenario type.
    let unknown = ask_raw(
        r#"{"v":1,"id":3,"backend":"slide_rule","type":"stats"}"#,
    );
    assert_eq!(
        unknown.get("code").unwrap().as_str(),
        Some("unknown_backend")
    );
    assert_eq!(unknown.get("id"), Some(&Json::Num(3.0)));
    let misplaced = ask_raw(r#"{"v":1,"backend":"analytic","type":"config"}"#);
    assert_eq!(
        misplaced.get("code").unwrap().as_str(),
        Some("bad_request")
    );

    // The analytic capability gate over the wire.
    let unsupported = ask_raw(
        r#"{"v":1,"backend":"analytic","type":"scenario","ask":"sim","shape":"imbalanced_pair","n":2048,"streams":2}"#,
    );
    assert_eq!(
        unsupported.get("code").unwrap().as_str(),
        Some("unsupported_by_backend")
    );

    // Legacy BACKENDS desugars to the same discovery response (no id).
    let legacy = ask_raw("BACKENDS");
    assert_eq!(legacy.to_string(), discovery.to_string());

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();
}

/// The three simulator-path commands every client in the concurrency
/// test issues (legacy framing keeps exercising the shim under
/// concurrency).
const CLIENT_CMDS: [&str; 3] =
    ["SIM 512 fp8 4", "PLAN throughput 8 512", "SPARSITY 512 4"];

/// One full client session: issue the three commands, parse the three
/// responses, QUIT.
fn client_session(port: u16) -> Vec<Json> {
    let mut conn = connect(port);
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut responses = Vec::new();
    for cmd in CLIENT_CMDS {
        writeln!(conn, "{cmd}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap_or_else(|e| {
            panic!("unparseable response to {cmd:?}: {e} ({line:?})")
        });
        assert!(
            v.get("error").is_none(),
            "{cmd:?} errored: {line}"
        );
        responses.push(v);
    }
    writeln!(conn, "QUIT").unwrap();
    responses
}

/// Spawn a server with an explicit io model on a fresh ephemeral port
/// (the listener is bound here, so no stdout parsing is needed).
fn spawn_server_io(
    conns: usize,
    io: IoModel,
) -> (u16, std::thread::JoinHandle<()>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = listener.local_addr().unwrap().port();
    let svc = Arc::new(Service::new(Config::mi300a()));
    let handle = std::thread::spawn(move || {
        serve_on(listener, svc, Some(conns), io).unwrap();
    });
    (port, handle)
}

/// Satellite (ISSUE 6): a request line over the 1 MiB framing cap is
/// answered with a typed `bad_request` naming the cap, the oversized
/// bytes are discarded, and the connection keeps serving — under both
/// io models available on this platform.
#[test]
fn oversized_request_line_is_rejected_and_connection_survives() {
    for io in IoModel::ALL {
        if !io.available() {
            continue;
        }
        let (port, handle) = spawn_server_io(1, io);
        let conn = connect(port);
        let mut writer = conn.try_clone().unwrap();
        let mut reader = BufReader::new(conn);

        // One line of cap+1 content bytes. Written in chunks so the
        // test does not assume socket buffer sizes.
        let chunk = vec![b'A'; 64 << 10];
        let mut remaining = MAX_LINE_BYTES + 1;
        while remaining > 0 {
            let k = remaining.min(chunk.len());
            writer.write_all(&chunk[..k]).unwrap();
            remaining -= k;
        }
        writer.write_all(b"\n").unwrap();

        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let rejection = Json::parse(line.trim()).unwrap();
        assert_eq!(
            rejection.get("code").unwrap().as_str(),
            Some("bad_request"),
            "{io:?}: {line}"
        );
        let msg = rejection.get("error").unwrap().as_str().unwrap();
        assert!(
            msg.contains(&MAX_LINE_BYTES.to_string()),
            "{io:?}: rejection must name the cap: {msg}"
        );

        // The connection is still usable and framing re-aligned.
        line.clear();
        writeln!(writer, "SPARSITY 512 4").unwrap();
        reader.read_line(&mut line).unwrap();
        let after = Json::parse(line.trim()).unwrap();
        assert_eq!(
            after.get("enable"),
            Some(&Json::Bool(true)),
            "{io:?}: connection must survive the rejection: {line}"
        );

        writeln!(writer, "QUIT").unwrap();
        drop(writer);
        drop(reader);
        handle.join().unwrap();
    }
}

/// The explicit `threads` io model answers the same protocol bytes as
/// the platform default (which is the epoll reactor on Linux): JSON and
/// legacy framing agree, ids echo, the cache proves itself over `stats`,
/// and a watched submit streams progress frames to their terminal state.
#[test]
fn threads_io_model_speaks_the_same_protocol() {
    let (port, handle) = spawn_server_io(1, IoModel::Threads);
    let conn = connect(port);
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut ask_raw = |line: &str| -> String {
        writeln!(writer, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp
    };

    // Legacy and JSON framings agree byte for byte.
    let legacy = ask_raw("SIM 512 fp8 4");
    let json = ask_raw(
        r#"{"v":1,"type":"sim","n":512,"precision":"fp8","streams":4}"#,
    );
    assert_eq!(legacy, json);

    // Ids echo; the repeat above was a cache hit (one engine run).
    let stats = ask_raw(r#"{"v":1,"id":2,"type":"stats"}"#);
    let v = Json::parse(stats.trim()).unwrap();
    assert_eq!(v.get("id"), Some(&Json::Num(2.0)));
    assert_eq!(v.get("engine_runs"), Some(&Json::Num(1.0)));
    assert_eq!(v.get("cache_hits"), Some(&Json::Num(1.0)));

    writeln!(writer, "QUIT").unwrap();
    drop(writer);
    drop(reader);
    handle.join().unwrap();

    // The native client's watched-submit flow under threads io.
    let (port, handle) = spawn_server_io(1, IoModel::Threads);
    let mut client =
        Client::connect_retry(format!("127.0.0.1:{port}").as_str(), 200)
            .unwrap();
    let mut spec = ScenarioSpec::new(Ask::Sparsity);
    spec.n = 256;
    spec.sweep.streams = vec![1, 2];
    let mut frames = Vec::new();
    match client.submit_and_wait(&spec, |p| frames.push(*p)).unwrap() {
        Response::Scenario { points } => assert_eq!(points.len(), 2),
        other => panic!("unexpected response: {other:?}"),
    }
    assert!(!frames.is_empty());
    assert!(frames.last().unwrap().state.terminal());
    client.raw_line("QUIT").ok();
    drop(client);
    handle.join().unwrap();
}

#[test]
fn four_concurrent_clients_match_single_client() {
    let port = free_port();
    let server = std::thread::spawn(move || {
        // 1 baseline connection + 4 concurrent ones.
        serve(Config::mi300a(), &format!("127.0.0.1:{port}"), Some(5))
            .unwrap();
    });

    // Baseline: one client alone.
    let baseline = client_session(port);
    assert_eq!(baseline.len(), CLIENT_CMDS.len());
    assert!(baseline[0].get("speedup_vs_serial").is_some());
    assert!(baseline[1].get("groups").is_some());
    assert!(baseline[2].get("enable").is_some());

    // Four clients at once: every response must parse and be identical
    // to the single-client answers (requests are pure functions of the
    // shared immutable config).
    let clients: Vec<std::thread::JoinHandle<Vec<Json>>> = (0..4)
        .map(|_| std::thread::spawn(move || client_session(port)))
        .collect();
    for (i, c) in clients.into_iter().enumerate() {
        let responses = c.join().expect("client thread panicked");
        assert_eq!(
            responses, baseline,
            "concurrent client {i} diverged from the single-client run"
        );
    }
    server.join().unwrap();
}
