//! Integration: the TCP request loop (SIM / PLAN / SPARSITY commands).
//! RUN is covered by runtime_integration.rs; here we keep the server on
//! the simulator paths so the test is artifact-independent.

use mi300a_char::config::Config;
use mi300a_char::serve::serve;
use mi300a_char::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

#[test]
fn sim_plan_sparsity_roundtrip() {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = probe.local_addr().unwrap().port();
    drop(probe);
    let handle = std::thread::spawn(move || {
        serve(Config::mi300a(), &format!("127.0.0.1:{port}"), Some(1))
            .unwrap();
    });

    // Connect (retry while the listener comes up).
    let mut conn = None;
    for _ in 0..200 {
        match TcpStream::connect(("127.0.0.1", port)) {
            Ok(c) => {
                conn = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
        }
    }
    let mut conn = conn.expect("server came up");
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ask = |cmd: &str| -> Json {
        writeln!(conn, "{cmd}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // SIM: 4-way concurrent FP8 512^3.
    let sim = ask("SIM 512 fp8 4");
    let speedup = sim.get("speedup_vs_serial").unwrap().as_f64().unwrap();
    assert!(speedup > 1.0 && speedup < 4.0, "speedup {speedup}");
    let fair = sim.get("fairness").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&fair));

    // PLAN: throughput objective.
    let plan = ask("PLAN throughput 8 512");
    assert!(plan.get("groups").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(plan.get("sparse"), Some(&Json::Bool(true)));

    // SPARSITY: isolated -> dense; concurrent decision context encoded.
    let sp = ask("SPARSITY 512 1");
    assert_eq!(sp.get("enable"), Some(&Json::Bool(false)));
    let sp4 = ask("SPARSITY 512 4");
    assert_eq!(sp4.get("enable"), Some(&Json::Bool(true)));
    let conc = sp4.get("concurrent_speedup").unwrap().as_f64().unwrap();
    assert!((1.2..1.4).contains(&conc), "~1.3x expected: {conc}");

    // Errors are structured, not fatal.
    let bad = ask("SIM abc fp8 4");
    assert!(bad.get("error").is_some());

    writeln!(conn, "QUIT").unwrap();
    drop(conn);
    handle.join().unwrap();
}
