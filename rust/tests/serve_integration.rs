//! Integration: the TCP request loop (SIM / PLAN / SPARSITY commands),
//! single-client and concurrent-client. RUN is covered by
//! runtime_integration.rs; here we keep the server on the simulator
//! paths so the tests are artifact-independent.

use mi300a_char::config::Config;
use mi300a_char::serve::serve;
use mi300a_char::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Connect to the server (retrying while the listener comes up).
fn connect(port: u16) -> TcpStream {
    for _ in 0..200 {
        if let Ok(c) = TcpStream::connect(("127.0.0.1", port)) {
            return c;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("server did not come up on port {port}");
}

/// Reserve an ephemeral port for the server to bind.
fn free_port() -> u16 {
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let port = probe.local_addr().unwrap().port();
    drop(probe);
    port
}

#[test]
fn sim_plan_sparsity_roundtrip() {
    let port = free_port();
    let handle = std::thread::spawn(move || {
        serve(Config::mi300a(), &format!("127.0.0.1:{port}"), Some(1))
            .unwrap();
    });

    let mut conn = connect(port);
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ask = |cmd: &str| -> Json {
        writeln!(conn, "{cmd}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // SIM: 4-way concurrent FP8 512^3.
    let sim = ask("SIM 512 fp8 4");
    let speedup = sim.get("speedup_vs_serial").unwrap().as_f64().unwrap();
    assert!(speedup > 1.0 && speedup < 4.0, "speedup {speedup}");
    let fair = sim.get("fairness").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&fair));

    // PLAN: throughput objective.
    let plan = ask("PLAN throughput 8 512");
    assert!(plan.get("groups").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(plan.get("sparse"), Some(&Json::Bool(true)));

    // SPARSITY: isolated -> dense; concurrent decision context encoded.
    let sp = ask("SPARSITY 512 1");
    assert_eq!(sp.get("enable"), Some(&Json::Bool(false)));
    let sp4 = ask("SPARSITY 512 4");
    assert_eq!(sp4.get("enable"), Some(&Json::Bool(true)));
    let conc = sp4.get("concurrent_speedup").unwrap().as_f64().unwrap();
    assert!((1.2..1.4).contains(&conc), "~1.3x expected: {conc}");

    // Errors are structured, not fatal.
    let bad = ask("SIM abc fp8 4");
    assert!(bad.get("error").is_some());

    writeln!(conn, "QUIT").unwrap();
    drop(conn);
    handle.join().unwrap();
}

/// The three simulator-path commands every client in the concurrency
/// test issues.
const CLIENT_CMDS: [&str; 3] =
    ["SIM 512 fp8 4", "PLAN throughput 8 512", "SPARSITY 512 4"];

/// One full client session: issue the three commands, parse the three
/// responses, QUIT.
fn client_session(port: u16) -> Vec<Json> {
    let mut conn = connect(port);
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut responses = Vec::new();
    for cmd in CLIENT_CMDS {
        writeln!(conn, "{cmd}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).unwrap_or_else(|e| {
            panic!("unparseable response to {cmd:?}: {e} ({line:?})")
        });
        assert!(
            v.get("error").is_none(),
            "{cmd:?} errored: {line}"
        );
        responses.push(v);
    }
    writeln!(conn, "QUIT").unwrap();
    responses
}

#[test]
fn four_concurrent_clients_match_single_client() {
    let port = free_port();
    let server = std::thread::spawn(move || {
        // 1 baseline connection + 4 concurrent ones.
        serve(Config::mi300a(), &format!("127.0.0.1:{port}"), Some(5))
            .unwrap();
    });

    // Baseline: one client alone.
    let baseline = client_session(port);
    assert_eq!(baseline.len(), CLIENT_CMDS.len());
    assert!(baseline[0].get("speedup_vs_serial").is_some());
    assert!(baseline[1].get("groups").is_some());
    assert!(baseline[2].get("enable").is_some());

    // Four clients at once: every response must parse and be identical
    // to the single-client answers (requests are pure functions of the
    // shared immutable config).
    let clients: Vec<std::thread::JoinHandle<Vec<Json>>> = (0..4)
        .map(|_| std::thread::spawn(move || client_session(port)))
        .collect();
    for (i, c) in clients.into_iter().enumerate() {
        let responses = c.join().expect("client thread panicked");
        assert_eq!(
            responses, baseline,
            "concurrent client {i} diverged from the single-client run"
        );
    }
    server.join().unwrap();
}
