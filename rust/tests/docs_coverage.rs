//! The docs/ guidebook must track the code: `docs/experiments.md` rows
//! are diffed against `experiments::REGISTRY` (the acceptance gate for
//! the per-experiment document trail), and the serving guide must name
//! every request type the protocol speaks.

use mi300a_char::backend;
use mi300a_char::experiments::REGISTRY;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn docs_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs")
}

fn read(name: &str) -> String {
    let path = docs_dir().join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// Every table row in docs/experiments.md whose first cell is a
/// backticked id: `| \`fig4\` | ...`.
fn doc_ids(doc: &str) -> BTreeSet<String> {
    doc.lines()
        .filter_map(|l| {
            let rest = l.trim().strip_prefix("| `")?;
            let end = rest.find('`')?;
            Some(rest[..end].to_string())
        })
        .collect()
}

#[test]
fn experiments_doc_covers_the_registry_exactly() {
    let doc = read("experiments.md");
    let in_doc = doc_ids(&doc);
    let in_registry: BTreeSet<String> =
        REGISTRY.iter().map(|s| s.id.to_string()).collect();
    assert_eq!(
        in_doc, in_registry,
        "docs/experiments.md id rows must match experiments::REGISTRY \
         exactly (missing rows: {:?}; stale rows: {:?})",
        in_registry.difference(&in_doc).collect::<Vec<_>>(),
        in_doc.difference(&in_registry).collect::<Vec<_>>(),
    );
    // Each row must also carry a runnable repro invocation and the wire
    // form, so the doc stays a per-experiment command reference rather
    // than a bare list.
    for s in REGISTRY {
        assert!(
            doc.contains(&format!("repro {}", s.id)),
            "{}: no CLI invocation in docs/experiments.md",
            s.id
        );
        assert!(
            doc.contains(&format!(
                r#""type":"repro","experiment":"{}""#,
                s.id
            )),
            "{}: no wire request in docs/experiments.md",
            s.id
        );
        assert!(
            doc.contains(s.section),
            "{}: paper section {} missing from docs/experiments.md",
            s.id,
            s.section
        );
    }
}

#[test]
fn guidebook_pages_exist_and_serving_doc_names_every_request_type() {
    for page in [
        "README.md",
        "experiments.md",
        "serving.md",
        "architecture.md",
        "scenarios.md",
        "backends.md",
        "auto_backend.md",
        "multi_apu.md",
        "performance.md",
        "cluster.md",
        "replay.md",
    ] {
        assert!(
            docs_dir().join(page).is_file(),
            "docs/{page} missing from the guidebook"
        );
    }
    let serving = read("serving.md");
    for ty in [
        "sim",
        "plan",
        "sparsity",
        "run",
        "repro",
        "list_experiments",
        "config",
        "batch",
        "stats",
        "scenario",
        "submit",
        "job_status",
        "job_result",
        "job_cancel",
        "progress",
        "backends",
    ] {
        assert!(
            serving.contains(&format!("`{ty}`")),
            "docs/serving.md never mentions the `{ty}` request type"
        );
    }
    for needle in ["cache", "--no-cache", "\"cache\":false", "overloaded"] {
        assert!(
            serving.contains(needle),
            "docs/serving.md never documents {needle:?}"
        );
    }
    assert!(
        read("README.md").contains("scenarios.md"),
        "docs/README.md must index the scenario cookbook"
    );
    assert!(
        read("README.md").contains("backends.md"),
        "docs/README.md must index the backend guide"
    );
    assert!(
        read("README.md").contains("performance.md"),
        "docs/README.md must index the performance guide"
    );
    assert!(
        read("README.md").contains("cluster.md"),
        "docs/README.md must index the cluster guide"
    );
    assert!(
        read("README.md").contains("auto_backend.md"),
        "docs/README.md must index the auto-backend guide"
    );
    assert!(
        read("README.md").contains("multi_apu.md"),
        "docs/README.md must index the multi-APU guide"
    );
    assert!(
        read("README.md").contains("replay.md"),
        "docs/README.md must index the trace-replay guide"
    );
}

/// The replay guide must document the trace surface this repo ships:
/// the record fields and their bounds, every what-if transform, the
/// CLI spellings, the wire shape, the span read-out, and the backend
/// story — and both checked-in example traces must exist, parse, and
/// be referenced.
#[test]
fn replay_doc_covers_format_transforms_and_examples() {
    let doc = read("replay.md");
    for needle in [
        "\"shape\":\"trace\"",
        "issue_ns",
        "`kernel`",
        "`stream`",
        "`spmm`",
        "non-decreasing",
        "4096",
        "identity",
        "precision_rewrite",
        "sparsity_enable",
        "stream_remap",
        "dilate",
        "compress",
        "\"sweep\":{\"transform\"",
        "--trace",
        "--transform",
        "--sweep-transform",
        "--chrome-trace",
        "mi300a-char replay",
        "spans",
        "unsupported_by_backend",
        "bad_request",
        "bad_range",
        "engine_runs_des",
        "traces/transformer.jsonl",
        "traces/mixed_precision.jsonl",
        "scenarios.md",
        "backends.md",
    ] {
        assert!(
            doc.contains(needle),
            "docs/replay.md never documents {needle:?}"
        );
    }
    // The example traces the guide points at are present and valid.
    for name in ["transformer.jsonl", "mixed_precision.jsonl"] {
        let path = docs_dir().join("traces").join(name);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let records = mi300a_char::replay::parse_jsonl(&text)
            .unwrap_or_else(|e| panic!("docs/traces/{name}: {e}"));
        assert!(records.len() >= 8, "docs/traces/{name} is too small");
    }
}

/// The multi-APU guide must document the fabric surface this repo
/// ships: both topologies, all three multi-device shapes, the
/// `device_set` wire field and its CLI spellings, the `transfer_ms`
/// read-out, and the calibration anchors with their source — and the
/// backend guide must point readers at it.
#[test]
fn multi_apu_doc_covers_topologies_shapes_and_anchors() {
    let doc = read("multi_apu.md");
    for needle in [
        "\"device_set\"",
        "fully_connected",
        "ring",
        "data_parallel",
        "pipeline",
        "halo",
        "transfer_ms",
        "--devices",
        "--topology",
        "--sweep-devices",
        "\"sweep\":{\"devices\"",
        "allreduce",
        "LINK_BYTES_PER_NS",
        "LINK_LATENCY_NS",
        "48",
        "1.9",
        "2508.11298",
        "bad_range",
        "backends.md",
        "scenarios.md",
    ] {
        assert!(
            doc.contains(needle),
            "docs/multi_apu.md never documents {needle:?}"
        );
    }
    assert!(
        read("backends.md").contains("multi_apu.md"),
        "docs/backends.md never cross-links multi_apu.md"
    );
}

/// The cluster guide must document the coordinator surface this repo
/// ships: the CLI flags, point routing, the retry semantics, and every
/// `cluster_*` stats counter — and the serving/performance guides must
/// point at it.
#[test]
fn cluster_doc_covers_routing_retries_and_stats() {
    let doc = read("cluster.md");
    for needle in [
        "--coordinator",
        "--workers",
        "consistent-hash",
        "replica",
        "overloaded",
        "loadgen --addr",
        "byte-identi",
        "cluster_workers",
        "cluster_points_routed",
        "cluster_proxied",
        "cluster_retries",
        "cluster_point_failures",
    ] {
        assert!(
            doc.contains(needle),
            "docs/cluster.md never documents {needle:?}"
        );
    }
    // The neighbouring guides route readers to the cluster page.
    for page in ["serving.md", "performance.md"] {
        assert!(
            read(page).contains("cluster.md"),
            "docs/{page} never cross-links cluster.md"
        );
    }
}

/// The performance guide must document the serving-layer tuning
/// surface this repo actually ships: io-model selection, the sharded
/// cache, the load generator, and its bench baseline file.
#[test]
fn performance_doc_covers_io_models_cache_and_loadgen() {
    let doc = read("performance.md");
    for needle in [
        "--io-model",
        "`epoll`",
        "`threads`",
        "loadgen",
        "BENCH_serve.json",
        "shard",
        "req_per_sec",
        "p99_ns",
        "--no-cache",
        "overloaded",
    ] {
        assert!(
            doc.contains(needle),
            "docs/performance.md never documents {needle:?}"
        );
    }
    // Serving guide points at both io models too.
    let serving = read("serving.md");
    assert!(
        serving.contains("--io-model"),
        "docs/serving.md must document --io-model"
    );
}

/// The backend guide must track `backend::REGISTRY` exactly (the
/// acceptance gate the CI backend-matrix smoke double-checks over the
/// wire): one capability-table row per registered backend, no stale
/// rows, and the tolerance/selection machinery documented.
#[test]
fn backends_doc_covers_the_backend_registry_exactly() {
    let doc = read("backends.md");
    let in_doc = doc_ids(&doc);
    let in_registry: BTreeSet<String> = backend::BackendId::ALL
        .iter()
        .map(|b| b.as_str().to_string())
        .collect();
    assert_eq!(
        in_doc, in_registry,
        "docs/backends.md id rows must match backend::REGISTRY exactly \
         (missing rows: {:?}; stale rows: {:?})",
        in_registry.difference(&in_doc).collect::<Vec<_>>(),
        in_doc.difference(&in_registry).collect::<Vec<_>>(),
    );
    for b in backend::REGISTRY {
        let caps = b.capabilities();
        // Each backend's per-backend stats counter must be documented.
        assert!(
            doc.contains(caps.id.stat_field()),
            "{}: stats counter {} missing from docs/backends.md",
            caps.id.as_str(),
            caps.id.stat_field()
        );
    }
    for needle in [
        "\"backend\":\"analytic\"",
        "--backend",
        "\"type\":\"backends\"",
        "unknown_backend",
        "unsupported_by_backend",
        "tolerance",
    ] {
        assert!(
            doc.contains(needle),
            "docs/backends.md never documents {needle:?}"
        );
    }
}

/// The auto-backend guide must document the routing surface this repo
/// ships: the trust table and its boundaries, both budget fields (wire
/// and CLI spellings), the refinement frame, and the
/// accounting-by-resolution story — and the backend guide must point
/// readers at it.
#[test]
fn auto_backend_doc_covers_routing_budgets_and_refinement() {
    let doc = read("auto_backend.md");
    for needle in [
        "\"backend\":\"auto\"",
        "--backend auto",
        "trust",
        "max_error",
        "max_time_ms",
        "--max-error",
        "\"refined\"",
        "engine_runs_auto",
        "engine_runs_des",
        "engine_runs_analytic",
        "imbalanced_pair",
        "tests/trust_table.rs",
        "backends.md",
    ] {
        assert!(
            doc.contains(needle),
            "docs/auto_backend.md never documents {needle:?}"
        );
    }
    assert!(
        read("backends.md").contains("auto_backend.md"),
        "docs/backends.md never cross-links auto_backend.md"
    );
}

/// The scenario cookbook must stay a worked, runnable document: every
/// paper-style sweep present, each with both a CLI and a wire form.
#[test]
fn scenario_cookbook_covers_the_paper_sweeps() {
    let doc = read("scenarios.md");
    for sweep in [
        "occupancy threshold",
        "crossover",
        "break-even",
        "imbalanced-pair fairness",
        "data-parallel scaling",
        "pipeline split break-even",
        "trace what-if comparison",
    ] {
        assert!(
            doc.to_lowercase().contains(sweep),
            "docs/scenarios.md missing the {sweep:?} cookbook sweep"
        );
    }
    for needle in [
        "\"type\":\"scenario\"",
        "\"type\":\"submit\"",
        "\"sweep\"",
        "mi300a-char scenario",
        "job_status",
        "job_result",
        "job_cancel",
        "--sweep-devices",
        "multi_apu.md",
        "--sweep-transform",
        "replay.md",
    ] {
        assert!(
            doc.contains(needle),
            "docs/scenarios.md never shows {needle:?}"
        );
    }
}
