//! Malformed-request corpus (ISSUE 8 satellite): every fixture line in
//! `tests/fixtures/bad_requests/` is a syntactically valid JSON value
//! that must be *rejected at decode time* with exactly the typed error
//! code its file is named after (`<error_code>.jsonl`). One table test
//! drives the whole corpus, so adding a regression case is a one-line
//! fixture edit — no new test code.
//!
//! The corpus is hygiene-checked: file names must parse as wire error
//! codes, files must be non-empty, and the set must cover enough of
//! the decode-time surface to stay meaningful.

use mi300a_char::api::{ErrorCode, Request};
use mi300a_char::util::json::Json;
use std::path::Path;

fn fixtures_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bad_requests")
}

/// Every line of every fixture decodes to exactly the error code the
/// file advertises.
#[test]
fn every_fixture_line_rejects_with_its_files_error_code() {
    let dir = fixtures_dir();
    let mut files: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    files.sort();
    assert!(!files.is_empty(), "empty corpus at {}", dir.display());

    let mut codes_seen = Vec::new();
    let mut lines_seen = 0usize;
    for path in files {
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or_default();
        assert_eq!(
            path.extension().and_then(|s| s.to_str()),
            Some("jsonl"),
            "corpus files are .jsonl: {}",
            path.display()
        );
        let want = ErrorCode::parse(stem).unwrap_or_else(|| {
            panic!(
                "fixture file name {stem:?} is not a wire error code \
                 (see ErrorCode::ALL)"
            )
        });
        codes_seen.push(want);
        let body = std::fs::read_to_string(&path).unwrap();
        for (lineno, line) in body.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            lines_seen += 1;
            let ctx = format!("{stem}.jsonl:{}: {line}", lineno + 1);
            // Corpus lines are well-formed JSON — the *request* is
            // what's malformed, so the typed decoder owns the error.
            let v = Json::parse(line)
                .unwrap_or_else(|e| panic!("fixture not JSON at {ctx}: {e}"));
            match Request::from_json(&v) {
                Err((err, _)) => assert_eq!(
                    err.code, want,
                    "wrong code at {ctx}: got {:?} ({})",
                    err.code, err.message
                ),
                Ok((req, _)) => {
                    panic!("fixture decoded cleanly at {ctx}: {req:?}")
                }
            }
        }
    }
    // Hygiene floor: the corpus must exercise a meaningful slice of
    // the decode-time error surface.
    codes_seen.dedup();
    assert!(
        codes_seen.len() >= 6,
        "corpus covers only {} error codes",
        codes_seen.len()
    );
    assert!(lines_seen >= 20, "corpus has only {lines_seen} lines");
}
