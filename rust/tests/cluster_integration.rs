//! Cluster-mode integration: a coordinator sharding sweeps across real
//! served workers must answer byte-identically to a standalone
//! service, split the work across the worker set, and survive worker
//! death by retrying on the survivors (DESIGN.md §6.9,
//! docs/cluster.md).

use mi300a_char::api::{
    ApiError, Ask, Client, ErrorCode, JobState, OverloadedRetry, Request,
    Response, ScenarioSpec, Service,
};
use mi300a_char::backend::auto::{TrustTable, DEFAULT_MAX_ERROR};
use mi300a_char::backend::{self, BackendId};
use mi300a_char::cluster::{Coordinator, Ring};
use mi300a_char::config::Config;
use mi300a_char::isa::Precision;
use mi300a_char::serve::{serve_on, IoModel};
use mi300a_char::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Bind an ephemeral standalone worker and serve it from a background
/// thread; returns its address. `max_conns` bounds its life: after
/// that many accepted connections the worker exits and its port
/// refuses further connects (the deterministic "worker death" lever).
fn spawn_worker(max_conns: Option<usize>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let svc = Arc::new(Service::new(Config::mi300a()));
        serve_on(listener, svc, max_conns, IoModel::Threads)
    });
    addr
}

/// Bind an ephemeral coordinator over `workers` and serve it from a
/// background thread; returns its address.
fn spawn_coordinator(workers: Vec<String>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let coord = Arc::new(Coordinator::new(workers, backend::DEFAULT));
        serve_on(listener, coord, None, IoModel::Threads)
    });
    addr
}

/// A sparsity sweep of exactly `nv * sv` points (cheap per point, so a
/// full 256-point sweep stays test-sized).
fn sweep(nv: usize, sv: usize) -> ScenarioSpec {
    let mut spec = ScenarioSpec::new(Ask::Sparsity);
    spec.sweep.n = (1..=nv).map(|i| i * 32).collect();
    spec.sweep.streams = (1..=sv).collect();
    spec
}

/// The worker's `engine_runs` counter, read directly off its port.
fn engine_runs(addr: &str) -> u64 {
    let mut c = Client::connect_retry(addr, 200).unwrap();
    match c.request(&Request::Stats).unwrap() {
        Response::Stats { engine_runs, .. } => engine_runs,
        other => panic!("unexpected stats response: {other:?}"),
    }
}

/// The worker's per-backend cold-run counters, read off its port.
fn backend_runs(addr: &str) -> Vec<u64> {
    let mut c = Client::connect_retry(addr, 200).unwrap();
    match c.request(&Request::Stats).unwrap() {
        Response::Stats { backend_runs, .. } => backend_runs,
        other => panic!("unexpected stats response: {other:?}"),
    }
}

/// The acceptance sweep: 256 points through a 2-worker coordinator are
/// byte-identical to a standalone service, the points split across
/// both workers, v1 single-point and non-scenario requests proxy
/// through unchanged, and the coordinator's `stats` aggregates the
/// workers plus the `cluster_*` block.
#[test]
fn coordinator_sweep_matches_standalone_and_splits_work() {
    let w1 = spawn_worker(None);
    let w2 = spawn_worker(None);
    let coord = spawn_coordinator(vec![w1.clone(), w2.clone()]);
    let mut client = Client::connect_retry(coord.as_str(), 200).unwrap();
    client.set_timeout(None).unwrap();

    let spec = sweep(16, 16); // 256 points
    let merged =
        client.request(&Request::Scenario { spec: spec.clone() }).unwrap();
    let standalone = Service::new(Config::mi300a());
    let local = standalone.handle(&Request::Scenario { spec });
    assert_eq!(
        merged.to_json(None).to_string(),
        local.to_json(None).to_string(),
        "merged cluster sweep drifted from the standalone bytes"
    );

    // Both workers executed a substantial share of the 256 points.
    let (r1, r2) = (engine_runs(&w1), engine_runs(&w2));
    assert_eq!(r1 + r2, 256, "points were lost or double-executed");
    assert!(r1 >= 64, "worker 1 ran only {r1}/256 points");
    assert!(r2 >= 64, "worker 2 ran only {r2}/256 points");

    // A v1 single-point request proxies through in its v1 shape.
    let sim = Request::Sim {
        n: 256,
        precision: mi300a_char::isa::Precision::Fp8,
        streams: 2,
    };
    assert_eq!(
        client.request(&sim).unwrap().to_json(None).to_string(),
        standalone.handle(&sim).to_json(None).to_string(),
        "proxied v1 request drifted from the standalone bytes"
    );

    // A non-scenario request proxies whole to one worker.
    let cfg = Request::Config;
    assert_eq!(
        client.request(&cfg).unwrap().to_json(None).to_string(),
        standalone.handle(&cfg).to_json(None).to_string(),
        "proxied config drifted from the standalone bytes"
    );

    // Cluster-wide stats: aggregated worker counters + cluster_* block.
    match client.request(&Request::Stats).unwrap() {
        Response::Stats { cache, engine_runs, cluster, .. } => {
            let c = cluster.expect("coordinator stats carry the block");
            assert_eq!(c.workers, 2);
            // 256 sweep points + 1 from the proxied v1 sim.
            assert_eq!(c.points_routed, 257);
            assert_eq!(c.proxied, 1, "only config proxies whole");
            assert_eq!(c.point_failures, 0);
            assert_eq!(engine_runs, 257);
            assert_eq!(cache.entries, 257, "every point cached once");
        }
        other => panic!("unexpected stats response: {other:?}"),
    }
}

/// Points owned by a dead worker retry on the survivor: kill one
/// worker deterministically (its connection budget is burned before
/// the sweep), then run a 64-point sweep — every point must answer,
/// the retry counter must move, and the survivor must have executed
/// the whole sweep.
#[test]
fn dead_worker_points_retry_on_the_survivor() {
    let frail = spawn_worker(Some(3));
    let solid = spawn_worker(None);
    // Burn the frail worker's three connections, then confirm death.
    for _ in 0..3 {
        let mut c = Client::connect_retry(frail.as_str(), 200).unwrap();
        let _ = c.request(&Request::Config).unwrap();
    }
    for _ in 0..400 {
        if Client::connect(frail.as_str()).is_err() {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }

    let coord = Coordinator::new(
        vec![frail.clone(), solid.clone()],
        backend::DEFAULT,
    );
    let spec = sweep(8, 8); // 64 points
    let merged = coord.handle(&Request::Scenario { spec: spec.clone() });
    let local = Service::new(Config::mi300a())
        .handle(&Request::Scenario { spec });
    assert_eq!(
        merged.to_json(None).to_string(),
        local.to_json(None).to_string(),
        "sweep over a dead worker drifted from the standalone bytes"
    );

    let stats = coord.cluster_stats();
    assert_eq!(stats.points_routed, 64);
    assert_eq!(stats.point_failures, 0, "no point may fail the sweep");
    assert!(
        stats.retries >= 1,
        "the dead worker's points never exercised the retry path"
    );
    assert_eq!(engine_runs(&solid), 64, "the survivor must run all points");
}

/// A worker dying *mid-sweep* (its connection budget runs out while
/// points are in flight) must not lose the sweep: the survivor picks
/// up the remainder and the merged response stays byte-identical.
#[test]
fn mid_sweep_worker_death_still_completes() {
    let frail = spawn_worker(Some(10));
    let solid = spawn_worker(None);
    let coord = Coordinator::new(
        vec![frail.clone(), solid.clone()],
        backend::DEFAULT,
    );
    let spec = sweep(8, 8); // 64 points >> the frail worker's budget
    let merged = coord.handle(&Request::Scenario { spec: spec.clone() });
    let local = Service::new(Config::mi300a())
        .handle(&Request::Scenario { spec });
    assert_eq!(
        merged.to_json(None).to_string(),
        local.to_json(None).to_string(),
        "mid-sweep worker death changed the merged bytes"
    );
    let stats = coord.cluster_stats();
    assert_eq!(stats.points_routed, 64);
    assert_eq!(stats.point_failures, 0, "no point may fail the sweep");
}

/// The job API on a coordinator: a watched submit streams the full
/// progress-frame ladder while the cluster job worker executes points
/// remotely, and the job result matches the synchronous sweep bytes.
#[test]
fn watched_jobs_run_remotely_with_full_progress() {
    let w1 = spawn_worker(None);
    let w2 = spawn_worker(None);
    let coord = spawn_coordinator(vec![w1, w2]);
    let mut client = Client::connect_retry(coord.as_str(), 200).unwrap();
    client.set_timeout(None).unwrap();

    let spec = sweep(4, 2); // 8 points
    let mut frames = Vec::new();
    let result = client
        .submit_and_wait(&spec, |v| frames.push(*v))
        .unwrap();
    let last = frames.last().expect("at least the terminal frame");
    assert_eq!(last.state, JobState::Done);
    assert_eq!((last.completed, last.total), (8, 8));
    // Queued snapshot + running + one per point + terminal.
    assert_eq!(frames.len() as u64, 8 + 3);

    let local = Service::new(Config::mi300a())
        .handle(&Request::Scenario { spec });
    assert_eq!(
        result.to_json(None).to_string(),
        local.to_json(None).to_string(),
        "job result drifted from the synchronous sweep bytes"
    );
}

/// ISSUE 8: a budgeted `auto` job through a 2-worker coordinator. The
/// sweep crosses the trust boundary (streams 1 trusted, 2/4 refinable,
/// 12 DES-routed); the refinement pass re-runs the low-confidence
/// points on the DES *through the same ring*, so every execution —
/// analytic, DES, and refined DES — lands on the owner of its
/// concrete-backend cache key, and the aggregated `cluster_*` /
/// `engine_runs_*` counters reconcile exactly with the reported
/// refinement count.
#[test]
fn budgeted_auto_jobs_refine_on_the_ring_owner() {
    let w1 = spawn_worker(None);
    let w2 = spawn_worker(None);
    let coord = spawn_coordinator(vec![w1.clone(), w2.clone()]);
    let mut client = Client::connect_retry(coord.as_str(), 200).unwrap();
    client.set_timeout(None).unwrap();

    let mut spec = ScenarioSpec::sim(256, Precision::Fp8, 4);
    spec.sweep.streams = vec![1, 2, 4, 12];
    spec.backend = Some(BackendId::Auto);
    spec.max_error = Some(DEFAULT_MAX_ERROR);

    let mut frames = Vec::new();
    let result =
        client.submit_and_wait(&spec, |v| frames.push(*v)).unwrap();
    let last = frames.last().expect("at least the terminal frame");
    assert_eq!(last.state, JobState::Done);
    assert_eq!((last.completed, last.total), (4, 4));

    // The refinement count is exactly the trust table's refinable set.
    let points = spec.expand();
    let refinable = points
        .iter()
        .filter(|p| TrustTable::wants_refinement(&spec, p))
        .count() as u64;
    assert_eq!(refinable, 2, "streams 2 and 4 are the refinable points");
    assert_eq!(last.refined, refinable);
    // Queued snapshot + running + one per point + one per refinement +
    // terminal.
    assert_eq!(frames.len() as u64, 4 + 3 + refinable);

    // Every execution landed on the ring owner of its concrete-backend
    // cache key: the initial pass keyed on the routed engine, the
    // refinement pass keyed on `des`.
    let ring = Ring::new(2);
    let mut want = vec![vec![0u64; backend::COUNT]; 2];
    for p in &points {
        let route = TrustTable::route(&spec, p);
        let mut single = spec.at(p);
        single.backend = Some(route);
        let key = Request::Scenario { spec: single }.cache_key();
        want[ring.owner(&key)][route.index()] += 1;
        if TrustTable::wants_refinement(&spec, p) {
            let mut des = spec.at(p);
            des.backend = Some(BackendId::Des);
            let key = Request::Scenario { spec: des }.cache_key();
            want[ring.owner(&key)][BackendId::Des.index()] += 1;
        }
    }
    assert_eq!(
        backend_runs(&w1),
        want[0],
        "worker 1 ran points it does not own"
    );
    assert_eq!(
        backend_runs(&w2),
        want[1],
        "worker 2 ran points it does not own"
    );

    // Aggregated stats reconcile: routed points = sweep + refinements,
    // DES runs = boundary points + refinements, the auto slot stays 0.
    match client.request(&Request::Stats).unwrap() {
        Response::Stats { engine_runs, backend_runs, cluster, .. } => {
            let c = cluster.expect("coordinator stats carry the block");
            assert_eq!(c.points_routed, 4 + refinable);
            assert_eq!(c.point_failures, 0);
            assert_eq!(engine_runs, 4 + refinable);
            assert_eq!(backend_runs[BackendId::Des.index()], 1 + refinable);
            assert_eq!(backend_runs[BackendId::Analytic.index()], 3);
            assert_eq!(
                backend_runs[BackendId::Auto.index()],
                0,
                "auto resolves before counting — its slot never moves"
            );
        }
        other => panic!("unexpected stats response: {other:?}"),
    }

    // The refined job result is byte-identical to the same budgeted
    // job on a standalone worker (refinement replaces the analytic
    // answers with DES ground truth on both paths).
    let solo = spawn_worker(None);
    let mut sc = Client::connect_retry(solo.as_str(), 200).unwrap();
    sc.set_timeout(None).unwrap();
    let solo_result = sc.submit_and_wait(&spec, |_| {}).unwrap();
    assert_eq!(
        result.to_json(None).to_string(),
        solo_result.to_json(None).to_string(),
        "cluster refinement drifted from the standalone job bytes"
    );
}

/// The opt-in client retry policy: typed `overloaded` answers are
/// retried with backoff until a real answer arrives (the coordinator's
/// inter-node setting), while the fail-fast default surfaces the first
/// `overloaded` verbatim.
#[test]
fn client_overloaded_retry_is_bounded_and_opt_in() {
    // A hand-rolled server: per connection, answer `overloaded` twice,
    // then a real response — always echoing the request's id.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        for conn in listener.incoming() {
            let stream = match conn {
                Ok(s) => s,
                Err(_) => break,
            };
            thread::spawn(move || {
                let mut reader =
                    BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                let mut line = String::new();
                let mut seen = 0usize;
                loop {
                    line.clear();
                    if reader.read_line(&mut line).unwrap_or(0) == 0 {
                        break;
                    }
                    let v = Json::parse(line.trim()).unwrap();
                    let id = v
                        .get("id")
                        .and_then(Json::as_f64)
                        .map(|x| x as u64);
                    seen += 1;
                    let resp = if seen <= 2 {
                        Response::from(ApiError::new(
                            ErrorCode::Overloaded,
                            "job queue is full (test fixture)",
                        ))
                    } else {
                        Response::Config { config: Json::obj(vec![]) }
                    };
                    if writeln!(writer, "{}", resp.to_json(id)).is_err() {
                        break;
                    }
                }
            });
        }
    });

    // Fail-fast default: the first overloaded answer surfaces.
    let mut plain = Client::connect_retry(addr.as_str(), 200).unwrap();
    assert_eq!(plain.overloaded_retry(), None);
    match plain.request(&Request::Config).unwrap() {
        Response::Error { code, .. } => {
            assert_eq!(code, ErrorCode::Overloaded)
        }
        other => panic!("unexpected fail-fast response: {other:?}"),
    }

    // Opt-in retry: two overloaded answers are absorbed, the third
    // answer (the real one) comes back.
    let mut retrying = Client::connect_retry(addr.as_str(), 200).unwrap();
    retrying.set_overloaded_retry(Some(OverloadedRetry {
        attempts: 3,
        backoff: Duration::from_millis(1),
    }));
    match retrying.request(&Request::Config).unwrap() {
        Response::Config { .. } => {}
        other => panic!("unexpected retried response: {other:?}"),
    }

    // Bounded: a policy smaller than the failure streak surfaces the
    // typed error after its attempts run out.
    let mut bounded = Client::connect_retry(addr.as_str(), 200).unwrap();
    bounded.set_overloaded_retry(Some(OverloadedRetry {
        attempts: 1,
        backoff: Duration::from_millis(1),
    }));
    match bounded.request(&Request::Config).unwrap() {
        Response::Error { code, .. } => {
            assert_eq!(code, ErrorCode::Overloaded)
        }
        other => panic!("unexpected bounded response: {other:?}"),
    }
}
