//! Integration + property tests: coordinator pipeline (batcher ->
//! governor -> router) against the DES, plus engine conservation
//! invariants.

use mi300a_char::config::Config;
use mi300a_char::coordinator::{Batcher, BatcherConfig, Coordinator,
                               Objective, Router};
use mi300a_char::isa::Precision;
use mi300a_char::metrics::fairness;
use mi300a_char::sim::{ConcurrencyProfile, Engine, KernelDesc};
use mi300a_char::util::proptest::check;

#[test]
fn plan_then_simulate_latency_objective_keeps_fairness() {
    let cfg = Config::mi300a();
    let coord = Coordinator::new(cfg.clone(), Objective::LatencySensitive);
    let pool = vec![KernelDesc::gemm(512, Precision::F32).with_iters(40); 8];
    let plan = coord.plan(&pool, false);
    let engine = Engine::new(&cfg, ConcurrencyProfile::ace());
    for group in &plan.groups {
        let ks: Vec<KernelDesc> =
            group.kernels[..group.streams.min(group.kernels.len())].to_vec();
        if ks.len() < 2 {
            continue;
        }
        // Average over seeds: a single DES run's fairness is one draw
        // from the placement-bias distribution.
        let reps = 8u64;
        let f = (0..reps)
            .map(|r| fairness(&engine.run(&ks, 99 + r).per_stream_totals()))
            .sum::<f64>()
            / reps as f64;
        // The governor promised > 0.5 for latency-sensitive plans; the
        // DES should roughly agree at <= 4 streams.
        assert!(
            f > 0.3,
            "simulated mean fairness {f:.3} far below the governor's \
             promise ({:.3}) at {} streams",
            group.expected_fairness,
            ks.len()
        );
    }
}

#[test]
fn full_pipeline_batch_route_complete() {
    // Batcher forms batches; router dispatches them; everything drains.
    let mut batcher = Batcher::new(BatcherConfig {
        precision: Precision::Fp8,
        deadline_ns: 1e6,
        max_requests: 8,
    });
    let mut router = Router::new(4, 8, 2);
    let mut now = 0.0;
    let mut batches_done = 0u64;
    let mut in_flight: Vec<usize> = Vec::new();
    for i in 0..200 {
        now += 10_000.0;
        batcher.submit(32, now);
        if let Some(_batch) = batcher.poll(now) {
            if let Some(d) = router.submit(i as u64) {
                in_flight.push(d.stream);
            }
        }
        // Complete one outstanding dispatch every other tick.
        if i % 2 == 0 {
            if let Some(s) = in_flight.pop() {
                if let Some(d) = router.complete(s) {
                    in_flight.push(d.stream);
                }
                batches_done += 1;
            }
        }
    }
    // Drain everything.
    now += 1e9;
    while batcher.poll(now).is_some() {}
    while let Some(s) = in_flight.pop() {
        if let Some(d) = router.complete(s) {
            in_flight.push(d.stream);
        }
        batches_done += 1;
    }
    assert_eq!(batcher.submitted, batcher.dispatched);
    assert_eq!(router.dispatched, router.completed);
    assert!(batches_done > 0);
    assert_eq!(router.backlog_len(), 0);
}

#[test]
fn engine_conservation_property() {
    // DES invariants: every stream records exactly `iters` iterations;
    // makespan >= each stream's span; totals positive; time monotone.
    let cfg = Config::mi300a();
    check(40, 0xE5617E, |g| {
        let profile = match g.usize_in(0, 2) {
            0 => ConcurrencyProfile::ace(),
            1 => ConcurrencyProfile::sparsity(),
            _ => ConcurrencyProfile::fragmentation(),
        };
        let engine = Engine::new(&cfg, profile);
        let n_streams = g.usize_in(1, 6);
        let kernels: Vec<KernelDesc> = (0..n_streams)
            .map(|_| {
                let n = *g.pick(&[256usize, 512, 1024, 2048]);
                let p = *g.pick(&[
                    Precision::Fp8,
                    Precision::F16,
                    Precision::F32,
                ]);
                KernelDesc::gemm(n, p).with_iters(g.usize_in(1, 12))
            })
            .collect();
        let run = engine.run(&kernels, g.case_seed);
        if run.streams.len() != kernels.len() {
            return Err("stream count mismatch".into());
        }
        for (k, s) in kernels.iter().zip(&run.streams) {
            if s.iter_ns.len() != k.iters {
                return Err(format!(
                    "{}: {} iters recorded, {} requested",
                    s.label,
                    s.iter_ns.len(),
                    k.iters
                ));
            }
            if s.iter_ns.iter().any(|&t| t <= 0.0 || !t.is_finite()) {
                return Err(format!("{}: non-positive iteration time", s.label));
            }
            if s.end_ns > run.makespan_ns + 1e-6 {
                return Err("stream ends after makespan".into());
            }
        }
        if !(0.0..=1.0).contains(&run.overlap_efficiency) {
            return Err(format!("overlap {} out of range", run.overlap_efficiency));
        }
        Ok(())
    });
}

#[test]
fn speedup_property_bounded_by_stream_count() {
    // Non-pipelined profiles cannot exceed s-fold speedup.
    let cfg = Config::mi300a();
    check(20, 0x5beed, |g| {
        let engine = Engine::new(&cfg, ConcurrencyProfile::ace());
        let s = g.usize_in(2, 8);
        let ks = vec![
            KernelDesc::gemm(512, Precision::F32).with_iters(g.usize_in(3, 20));
            s
        ];
        let sp = engine.speedup(&ks, g.case_seed);
        // E[bias] = 1, but a favorable draw can push one run slightly
        // past s; bound with headroom for the stochastic placement bias.
        if sp > s as f64 * 1.45 {
            return Err(format!("speedup {sp:.2} far exceeds {s} streams"));
        }
        if sp < 0.5 {
            return Err(format!("speedup {sp:.2} implausibly low"));
        }
        Ok(())
    });
}
