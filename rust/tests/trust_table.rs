//! Trust-table calibration (ISSUE 8 satellite): the `auto` router's
//! advertised error envelope must be *true*. This harness regenerates
//! the calibration corpus from the `docs/scenarios.md` cookbook sweeps,
//! routes every point through `TrustTable`, and proves that each
//! analytic-routed region tracks DES ground truth within the default
//! `max_error` the router advertises (`DEFAULT_MAX_ERROR`). A failure
//! names the offending (shape, streams, precision) triple so the table
//! can be re-drawn around the drifted region.
//!
//! DES-routed points are exempt by construction (they *are* ground
//! truth); the closed-form asks must stay exact on every route.

use mi300a_char::api::{Ask, ScenarioSpec, Shape};
use mi300a_char::backend::auto::{
    TrustTable, DEFAULT_MAX_ERROR, TRUST_MAX_STREAMS,
};
use mi300a_char::backend::{self, BackendId};
use mi300a_char::config::Config;
use mi300a_char::coordinator::Objective;
use mi300a_char::isa::Precision;

/// Metric tolerances inside the trust region. Time-domain outputs
/// (makespan, speedup) are bounded by the router's advertised envelope;
/// the bounded ratio metrics carry the corpus's absolute tolerances
/// (docs/backends.md).
const ABS_TOL_OVERLAP: f64 = 0.35;
const ABS_TOL_FAIRNESS: f64 = 0.40;

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

/// The calibration corpus: every sim sweep the cookbook publishes.
/// These are the regions the trust table claims to have measured — new
/// cookbook sweeps belong here so the claim keeps pace.
fn calibration_corpus() -> Vec<(&'static str, ScenarioSpec)> {
    // #1 occupancy threshold: the full ACE stream range at 512³ FP8.
    let mut occupancy = ScenarioSpec::sim(512, Precision::Fp8, 4);
    occupancy.sweep.streams = vec![1, 2, 3, 4, 6, 8, 12, 16];

    // #2 precision crossover: precision × streams at 1024³.
    let mut crossover = ScenarioSpec::sim(1024, Precision::Fp8, 4);
    crossover.sweep.precision = vec![Precision::Fp8, Precision::F16];
    crossover.sweep.streams = vec![1, 2, 4, 8];

    // Mixed sparse/dense stream sets (the sparse-weighting model).
    let mut mixed = ScenarioSpec::new(Ask::Sim);
    mixed.shape = Shape::MixedSparse;
    mixed.n = 512;
    mixed.sweep.streams = vec![2, 4, 8];

    // #4 imbalanced pair: entirely outside the trusted envelope — the
    // corpus includes it to prove the router sends it to the DES.
    let mut pair = ScenarioSpec::new(Ask::Sim);
    pair.shape = Shape::ImbalancedPair;
    pair.streams = 2;
    pair.n = 2048;
    pair.iters = 10;
    pair.sweep.n = vec![1024, 2048];

    // #5 multi-APU data-parallel scaling (docs/multi_apu.md): the
    // devices=1 anchor is inside the calibrated envelope; every
    // devices>1 point carries fabric contention the table has no
    // calibration for and must ship to the DES.
    let mut multi = ScenarioSpec::new(Ask::Sim);
    multi.shape = Shape::DataParallel;
    multi.n = 512;
    multi.sweep.devices = vec![1, 2, 4];
    multi.sweep.streams = vec![2, 4];

    vec![
        ("occupancy", occupancy),
        ("crossover", crossover),
        ("mixed_sparse", mixed),
        ("imbalanced_pair", pair),
        ("multi_apu", multi),
    ]
}

/// The headline assertion: every analytic-routed point in the corpus
/// answers within the advertised default error budget against DES
/// ground truth, on every tolerance-bearing metric.
#[test]
fn analytic_routed_regions_meet_the_advertised_max_error() {
    let cfg = Config::mi300a();
    let des = backend::get(BackendId::Des);
    let analytic = backend::get(BackendId::Analytic);
    let mut analytic_points = 0usize;
    let mut des_points = 0usize;

    for (name, spec) in calibration_corpus() {
        for p in spec.expand() {
            if TrustTable::route(&spec, &p) == BackendId::Des {
                // Ground-truth region: nothing to calibrate.
                des_points += 1;
                continue;
            }
            analytic_points += 1;
            let d = des.simulate(&cfg, &spec, &p);
            let a = analytic.simulate(&cfg, &spec, &p);
            let triple = format!(
                "(shape={:?}, streams={}, precision={:?})",
                spec.shape, p.streams, p.precision
            );
            assert!(
                rel(a.makespan_ms, d.makespan_ms) <= DEFAULT_MAX_ERROR,
                "{name}: makespan error {:.3} > advertised \
                 max_error {DEFAULT_MAX_ERROR} at {triple} — the trust \
                 table routes this region to analytic but calibration \
                 has drifted",
                rel(a.makespan_ms, d.makespan_ms)
            );
            assert!(
                rel(a.speedup_vs_serial, d.speedup_vs_serial)
                    <= DEFAULT_MAX_ERROR,
                "{name}: speedup error {:.3} > advertised \
                 max_error {DEFAULT_MAX_ERROR} at {triple}",
                rel(a.speedup_vs_serial, d.speedup_vs_serial)
            );
            assert!(
                (a.overlap_efficiency - d.overlap_efficiency).abs()
                    <= ABS_TOL_OVERLAP,
                "{name}: overlap drift at {triple}"
            );
            assert!(
                (a.fairness - d.fairness).abs() <= ABS_TOL_FAIRNESS,
                "{name}: fairness drift at {triple}"
            );
        }
    }
    // The corpus must actually exercise both sides of the boundary, or
    // this harness proves nothing.
    assert!(
        analytic_points >= 16,
        "corpus too small: {analytic_points} analytic-routed points"
    );
    assert!(
        des_points >= 4,
        "corpus never crossed the boundary: {des_points} des-routed \
         points"
    );
}

/// The routing boundary itself matches the corpus: inside the stream
/// envelope homogeneous points are analytic, outside they are DES, and
/// the imbalanced pair is DES at every point.
#[test]
fn corpus_routes_split_exactly_at_the_trust_boundary() {
    for (name, spec) in calibration_corpus() {
        for p in spec.expand() {
            let want = if spec.shape == Shape::ImbalancedPair
                || p.devices > 1
                || p.streams > TRUST_MAX_STREAMS
            {
                BackendId::Des
            } else {
                BackendId::Analytic
            };
            assert_eq!(
                TrustTable::route(&spec, &p),
                want,
                "{name}: unexpected route at streams={} shape={:?}",
                p.streams,
                spec.shape
            );
            // Confidence is consistent with the route: DES-routed
            // points are fully trusted, analytic ones never more so.
            let c = TrustTable::confidence(&spec, &p);
            if want == BackendId::Des {
                assert_eq!(c, 1.0, "{name}: DES route must score 1.0");
                assert!(!TrustTable::wants_refinement(&spec, &p));
            } else {
                assert!((0.0..=1.0).contains(&c), "{name}: c={c}");
                assert_eq!(
                    TrustTable::wants_refinement(&spec, &p),
                    c < 1.0,
                    "{name}: refinement must track confidence"
                );
            }
        }
    }
}

/// Closed-form asks are exact on every route — the fast path is always
/// safe for plan/sparsity, so the router keeps them analytic even
/// under a tight error budget.
#[test]
fn closed_form_asks_stay_exact_under_routing() {
    let cfg = Config::mi300a();
    let des = backend::get(BackendId::Des);
    let auto = backend::get(BackendId::Auto);

    let mut sp = ScenarioSpec::sparsity_question(512, 4);
    sp.sweep.n = vec![256, 512, 2048, 8192];
    sp.sweep.streams = vec![1, 4];
    sp.max_error = Some(1e-6); // far tighter than the sim envelope
    for p in sp.expand() {
        assert_eq!(
            TrustTable::route(&sp, &p),
            BackendId::Analytic,
            "closed forms never need the replay"
        );
        assert_eq!(
            auto.sparsity(&cfg, &sp, &p),
            des.sparsity(&cfg, &sp, &p),
            "sparsity must be route-invariant at n={} streams={}",
            p.n,
            p.streams
        );
    }

    let plan = ScenarioSpec::plan(
        Objective::ThroughputOriented,
        8,
        512,
        Precision::Fp8,
    );
    let p = plan.expand()[0];
    assert_eq!(TrustTable::route(&plan, &p), BackendId::Analytic);
    assert_eq!(
        auto.plan(&cfg, &plan, &p),
        des.plan(&cfg, &plan, &p),
        "plan must be route-invariant"
    );
}

/// A budget tighter than the advertised envelope flips every sim point
/// in the corpus to the DES — the router refuses to answer with less
/// accuracy than it was asked for.
#[test]
fn tight_budgets_route_the_whole_corpus_to_ground_truth() {
    for (name, mut spec) in calibration_corpus() {
        spec.max_error = Some(DEFAULT_MAX_ERROR / 2.0);
        for p in spec.expand() {
            assert_eq!(
                TrustTable::route(&spec, &p),
                BackendId::Des,
                "{name}: a tight budget must force the reference \
                 engine at streams={}",
                p.streams
            );
        }
    }
}
