//! Cross-backend equivalence (ISSUE 5 acceptance): the `analytic`
//! backend must track the `des` reference within the tolerance
//! documented in `docs/backends.md` on the `docs/scenarios.md` cookbook
//! sweeps, answer the closed-form asks (`plan`/`sparsity`) exactly, and
//! leave every backend-less request byte-identical to the pre-backend
//! service. The per-backend `engine_runs` counters prove the analytic
//! path executed zero DES points.

use mi300a_char::api::{
    Ask, Request, RequestEnvelope, Response, ScenarioSpec, Service, Shape,
};
use mi300a_char::backend::{self, BackendId};
use mi300a_char::config::Config;
use mi300a_char::coordinator::Objective;
use mi300a_char::fabric::Topology;
use mi300a_char::isa::Precision;
use mi300a_char::util::json::Json;

/// Documented tolerance (docs/backends.md): time-domain outputs are
/// first-order estimates.
const REL_TOL_TIME: f64 = 0.45; // makespan_ms, speedup_vs_serial
const ABS_TOL_OVERLAP: f64 = 0.35; // overlap_efficiency
const ABS_TOL_FAIRNESS: f64 = 0.40; // fairness
const EXACT: f64 = 1e-9; // l2_miss, lds_util share the model code

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

/// Compare both backends on every point of a sim sweep.
fn assert_sim_sweep_within_tolerance(spec: &ScenarioSpec) {
    let cfg = Config::mi300a();
    let des = backend::get(BackendId::Des);
    let analytic = backend::get(BackendId::Analytic);
    for p in spec.expand() {
        let d = des.simulate(&cfg, spec, &p);
        let a = analytic.simulate(&cfg, spec, &p);
        let ctx = format!(
            "point n={} precision={:?} streams={} devices={}: \
             des={d:?} analytic={a:?}",
            p.n, p.precision, p.streams, p.devices
        );
        assert!(
            rel(a.makespan_ms, d.makespan_ms) <= REL_TOL_TIME,
            "makespan drift {:.3} > {REL_TOL_TIME} at {ctx}",
            rel(a.makespan_ms, d.makespan_ms)
        );
        assert!(
            rel(a.speedup_vs_serial, d.speedup_vs_serial) <= REL_TOL_TIME,
            "speedup drift {:.3} > {REL_TOL_TIME} at {ctx}",
            rel(a.speedup_vs_serial, d.speedup_vs_serial)
        );
        assert!(
            (a.overlap_efficiency - d.overlap_efficiency).abs()
                <= ABS_TOL_OVERLAP,
            "overlap drift at {ctx}"
        );
        assert!(
            (a.fairness - d.fairness).abs() <= ABS_TOL_FAIRNESS,
            "fairness drift at {ctx}"
        );
        assert!(
            (a.l2_miss - d.l2_miss).abs() <= EXACT,
            "l2_miss must match exactly at {ctx}"
        );
        assert!(
            (a.lds_util - d.lds_util).abs() <= EXACT,
            "lds_util must match exactly at {ctx}"
        );
    }
}

/// Cookbook sweep 1 (occupancy threshold, paper §6.1 Fig 4): streams
/// across the full ACE range at 512³ FP8.
#[test]
fn cookbook_occupancy_threshold_within_tolerance() {
    let mut spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
    spec.sweep.streams = vec![1, 2, 3, 4, 6, 8, 12, 16];
    assert_sim_sweep_within_tolerance(&spec);
}

/// Cookbook sweep 2 (FP8-vs-FP16 crossover, paper §5/§8): precision ×
/// streams at 1024³.
#[test]
fn cookbook_precision_crossover_within_tolerance() {
    let mut spec = ScenarioSpec::sim(1024, Precision::Fp8, 4);
    spec.sweep.precision = vec![Precision::Fp8, Precision::F16];
    spec.sweep.streams = vec![1, 2, 4, 8];
    assert_sim_sweep_within_tolerance(&spec);
}

/// The advertised mixed_sparse sim capability: alternating
/// sparse/dense streams exercise the analytic model's sparse weighting
/// (per-stream mem_w / sparse_w, effective-stream rounding) against
/// the DES under the same tolerance as the homogeneous sweeps.
#[test]
fn mixed_sparse_sim_within_tolerance() {
    let mut spec = ScenarioSpec::new(Ask::Sim);
    spec.shape = Shape::MixedSparse;
    spec.n = 512;
    spec.sweep.streams = vec![2, 4, 8];
    assert_sim_sweep_within_tolerance(&spec);
}

/// Cookbook sweep 3 (sparsity break-even, paper §7): the sparsity ask
/// is a shared closed form — backends must agree *exactly*.
#[test]
fn cookbook_sparsity_break_even_is_exact_across_backends() {
    let cfg = Config::mi300a();
    let des = backend::get(BackendId::Des);
    let analytic = backend::get(BackendId::Analytic);
    let mut spec = ScenarioSpec::sparsity_question(512, 4);
    spec.sweep.n = vec![256, 512, 2048, 8192];
    spec.sweep.streams = vec![1, 4];
    for p in spec.expand() {
        assert_eq!(
            des.sparsity(&cfg, &spec, &p),
            analytic.sparsity(&cfg, &spec, &p),
            "sparsity must be backend-invariant at n={} streams={}",
            p.n,
            p.streams
        );
    }
    // Plan asks are the same shared closed form.
    let plan = ScenarioSpec::plan(
        Objective::ThroughputOriented,
        8,
        512,
        Precision::Fp8,
    );
    let p = plan.expand()[0];
    assert_eq!(
        des.plan(&cfg, &plan, &p),
        analytic.plan(&cfg, &plan, &p),
        "plan must be backend-invariant"
    );
}

/// Cookbook sweep 4 (imbalanced-pair fairness, paper §6.3): outside the
/// analytic capability surface — a typed `unsupported_by_backend`
/// before any point runs, while `des` answers it.
#[test]
fn cookbook_imbalanced_pair_is_des_only() {
    let svc = Service::new(Config::mi300a());
    let mut spec = ScenarioSpec::new(Ask::Sim);
    spec.shape = Shape::ImbalancedPair;
    spec.streams = 2;
    spec.n = 2048;
    spec.iters = 10;
    match svc.handle(&Request::Scenario { spec: spec.clone() }) {
        Response::Scenario { points } => assert_eq!(points.len(), 1),
        other => panic!("des must answer the pair: {other:?}"),
    }
    spec.backend = Some(BackendId::Analytic);
    match svc.handle(&Request::Scenario { spec }) {
        Response::Error { code, message } => {
            assert_eq!(
                code,
                mi300a_char::api::ErrorCode::UnsupportedByBackend
            );
            assert!(message.contains("analytic"), "{message}");
        }
        other => panic!("expected unsupported_by_backend, got {other:?}"),
    }
    // Only the des point executed.
    assert_eq!(svc.backend_runs(), vec![1, 0, 0]);
}

/// Acceptance: with `backend` omitted, responses are byte-identical to
/// the explicit-`des` selection (i.e. the pre-backend behavior), and an
/// analytic sweep executes **zero** DES points — ≥100× fewer by any
/// measure, proven through the per-backend counters.
#[test]
fn omitted_backend_is_des_and_analytic_runs_zero_des_points() {
    let default_svc = Service::new(Config::mi300a());
    let explicit_svc = Service::new(Config::mi300a());
    let req = Request::Sim {
        n: 512,
        precision: Precision::Fp8,
        streams: 4,
    };
    let omitted = default_svc.handle(&req);
    let explicit = explicit_svc.handle_env(
        &req,
        &RequestEnvelope {
            backend: Some(BackendId::Des),
            ..RequestEnvelope::default()
        },
    );
    assert_eq!(
        omitted.to_json(Some(1)).to_string(),
        explicit.to_json(Some(1)).to_string(),
        "omitting backend must be byte-identical to selecting des"
    );
    assert_eq!(default_svc.backend_runs(), vec![1, 0, 0]);

    // A 16-point analytic sweep: all analytic, zero des.
    let svc = Service::new(Config::mi300a());
    let mut spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
    spec.sweep.streams = vec![1, 2, 4, 8];
    spec.sweep.iters = vec![25, 50, 75, 100];
    spec.backend = Some(BackendId::Analytic);
    match svc.handle(&Request::Scenario { spec }) {
        Response::Scenario { points } => assert_eq!(points.len(), 16),
        other => panic!("unexpected response: {other:?}"),
    }
    assert_eq!(
        svc.backend_runs(),
        vec![0, 16, 0],
        "an analytic sweep must execute zero DES points"
    );
    assert_eq!(svc.engine_runs(), 16, "totals stay truthful");
}

/// Multi-APU points add a transfer dimension on top of the base sweep
/// checks: the stepped fabric round and the closed forms agree exactly
/// (pinned in `sim::fabric`), so transfer drift only enters through the
/// per-backend compute estimate and stays inside the time tolerance on
/// the two makespans. `devices=1` points must carry exactly zero
/// fabric time on both backends.
fn assert_multi_apu_sweep_within_tolerance(spec: &ScenarioSpec) {
    assert_sim_sweep_within_tolerance(spec);
    let cfg = Config::mi300a();
    let des = backend::get(BackendId::Des);
    let analytic = backend::get(BackendId::Analytic);
    for p in spec.expand() {
        let d = des.simulate(&cfg, spec, &p);
        let a = analytic.simulate(&cfg, spec, &p);
        let ctx = format!(
            "point n={} devices={}: des={d:?} analytic={a:?}",
            p.n, p.devices
        );
        if p.devices <= 1 {
            assert_eq!(d.transfer_ms, 0.0, "des fabric at d=1: {ctx}");
            assert_eq!(a.transfer_ms, 0.0, "analytic fabric at d=1: {ctx}");
        } else {
            assert!(d.transfer_ms > 0.0, "des saw no fabric: {ctx}");
            assert!(a.transfer_ms > 0.0, "analytic saw no fabric: {ctx}");
            assert!(
                (a.transfer_ms - d.transfer_ms).abs()
                    <= REL_TOL_TIME * (a.makespan_ms + d.makespan_ms),
                "transfer drift beyond the time tolerance at {ctx}"
            );
        }
    }
}

/// Multi-APU sweep 1 (docs/multi_apu.md data-parallel scaling): the
/// replicated-GEMM allreduce across 1→4 fully-connected APUs. The
/// devices=1 column is the scaling anchor — zero fabric on both
/// backends, everything else within the standard tolerances.
#[test]
fn multi_apu_data_parallel_sweep_within_tolerance() {
    let mut spec = ScenarioSpec::new(Ask::Sim);
    spec.shape = Shape::DataParallel;
    spec.n = 512;
    spec.sweep.devices = vec![1, 2, 3, 4];
    spec.sweep.streams = vec![2, 8];
    assert_multi_apu_sweep_within_tolerance(&spec);
}

/// Multi-APU sweep 2 (docs/multi_apu.md pipeline break-even): K-split
/// stages relayed over a ring — the topology with the worst collective
/// latency multiplier, so agreement here bounds the easier
/// fully-connected case too.
#[test]
fn multi_apu_pipeline_ring_sweep_within_tolerance() {
    let mut spec = ScenarioSpec::new(Ask::Sim);
    spec.shape = Shape::Pipeline;
    spec.n = 1024;
    spec.device_set.topology = Topology::Ring;
    spec.sweep.devices = vec![1, 2, 4];
    assert_multi_apu_sweep_within_tolerance(&spec);
}

/// Acceptance (ISSUE 9): a `devices=1` request that spells out its
/// `device_set` answers byte-identically to the same request without
/// one, on both backends — the fabric dimension is invisible until a
/// second APU exists.
#[test]
fn single_apu_device_set_is_byte_invisible() {
    for backend_sel in ["", r#","backend":"analytic""#] {
        let bare = format!(
            r#"{{"v":1,"type":"scenario","n":512,"shape":"data_parallel","iters":10{backend_sel}}}"#
        );
        let spelled = format!(
            r#"{{"v":1,"type":"scenario","n":512,"shape":"data_parallel","iters":10,"device_set":{{"devices":1}}{backend_sel}}}"#
        );
        let decode = |line: &str| {
            let (req, _) =
                Request::from_json(&Json::parse(line).unwrap()).unwrap();
            req
        };
        let svc = Service::new(Config::mi300a());
        let got_bare = svc.handle(&decode(&bare)).to_json(Some(1));
        let got_spelled = svc.handle(&decode(&spelled)).to_json(Some(1));
        assert_eq!(
            got_bare.to_string(),
            got_spelled.to_string(),
            "devices=1 must be byte-invisible (backend {backend_sel:?})"
        );
        assert!(
            !got_bare.to_string().contains("transfer_ms"),
            "single-APU answers must not grow fabric fields"
        );
    }
}
