//! The `auto` backend: a trust-region **router** between the analytic
//! fast path and the DES reference engine (DESIGN.md §6.10).
//!
//! The equivalence corpus (`tests/backend_equivalence.rs`, regenerated
//! as ground truth by `tests/trust_table.rs`) measures where the
//! closed forms track the replay within the advertised error envelope:
//! homogeneous and mixed-sparse stream sets up to moderate contention.
//! Outside that envelope — the imbalanced pair's fragmentation
//! fairness, high-contention corners past [`TRUST_MAX_STREAMS`]
//! streams — only the DES is trustworthy. [`TrustTable`] encodes that
//! measured boundary as a static routing function: shape × streams ×
//! precision × sparsity in, a concrete [`BackendId`] out.
//!
//! The router is deliberately **not** an engine. The service resolves
//! `backend:"auto"` to the routed concrete id *before* cache-keying
//! and cold-run accounting (`api::Service::run_point`,
//! `cluster::ClusterCore::run_point_remote`), so auto-routed points
//! share cache entries with explicitly-`des`/`analytic` requests and
//! `engine_runs_auto` stays 0 by design. The trait implementation here
//! still answers directly (delegating through [`TrustTable::route`])
//! so the registry row is a complete backend for discovery, the CI
//! backend matrix, and direct library use.
//!
//! Budgets sharpen the route: a spec carrying `max_error` tighter than
//! [`DEFAULT_MAX_ERROR`] demands more accuracy than the measured
//! envelope advertises, so every sim point routes to the DES. Budgeted
//! *jobs* additionally get a refinement pass — analytic answers first,
//! then the lowest-[`TrustTable::confidence`] points re-run on the DES
//! in the background, streamed as `refined` progress frames (see
//! `api::job` and `docs/auto_backend.md`).

use super::{Backend, BackendId, Capabilities, PlanResult, SimResult,
            SparsityResult};
use crate::api::scenario::{Ask, Point, ScenarioSpec, Shape};
use crate::config::Config;

/// The advertised error envelope of an analytic-routed point: the
/// worst-case relative error on time-like metrics inside the trust
/// region, matching `REL_TOL_TIME` in the equivalence corpus.
/// `tests/trust_table.rs` re-measures every analytic-routed cookbook
/// region against DES ground truth and fails (naming the offending
/// shape/streams/precision triple) if calibration drifts past this.
pub const DEFAULT_MAX_ERROR: f64 = 0.45;

/// Highest stream count the analytic sim is trusted at. Past this the
/// §6 contention dynamics (queueing, fairness collapse) are replay
/// territory: the closed forms' error grows with contention, and the
/// equivalence corpus only pins them up to here.
pub const TRUST_MAX_STREAMS: usize = 8;

/// The measured trust region, as a static routing function. Keyed on
/// shape × streams × precision × sparsity buckets (precision and the
/// 2:4 sparsity overlays are *inside* the trusted envelope — the cost
/// model treats them as throughput scalars both backends share — so
/// they shift [`TrustTable::confidence`], not the route).
pub struct TrustTable;

impl TrustTable {
    /// Resolve one point to the concrete engine that answers it.
    pub fn route(spec: &ScenarioSpec, p: &Point) -> BackendId {
        // plan/sparsity are shared closed forms — exact on every
        // backend, so the fast path is always safe.
        if spec.ask != Ask::Sim {
            return BackendId::Analytic;
        }
        // A budget tighter than the measured envelope can only be
        // honored by the reference engine.
        if let Some(e) = spec.max_error {
            if e < DEFAULT_MAX_ERROR {
                return BackendId::Des;
            }
        }
        // Fragmentation fairness on the imbalanced pair is replay
        // territory (the analytic backend refuses the shape outright),
        // and so are issue-time trace replay and irregular SpMM
        // contention (both new shapes have no closed forms at all).
        if matches!(
            spec.shape,
            Shape::ImbalancedPair | Shape::SpmmMix | Shape::Trace
        ) {
            return BackendId::Des;
        }
        // Multi-device points route to replay until the fabric
        // calibration corpus (tests/trust_table.rs) grows enough
        // history to trust the closed-form composition under
        // contention. Single-device points on multi-device shapes are
        // plain single-APU sets and stay inside the envelope.
        if p.devices > 1 {
            return BackendId::Des;
        }
        // High-contention corners fall outside the measured envelope.
        if p.streams > TRUST_MAX_STREAMS {
            return BackendId::Des;
        }
        BackendId::Analytic
    }

    /// How confidently the routed answer sits inside the envelope, in
    /// `[0, 1]`. DES-routed points (and the exact closed-form asks)
    /// score 1.0; analytic sim points lose confidence with contention
    /// and with sparsity overlays. Refinement re-runs ascending by
    /// this score, so the least-trusted answers are replaced first.
    pub fn confidence(spec: &ScenarioSpec, p: &Point) -> f64 {
        if spec.ask != Ask::Sim
            || Self::route(spec, p) == BackendId::Des
        {
            return 1.0;
        }
        let mut c = 1.0 - 0.06 * p.streams.saturating_sub(1) as f64;
        if spec.shape == Shape::MixedSparse {
            c -= 0.15;
        }
        if spec.sparsity.is_sparse() {
            c -= 0.05;
        }
        c.clamp(0.0, 1.0)
    }

    /// Whether a routed answer is a candidate for DES refinement: an
    /// analytic-routed `sim` point whose confidence is below 1.0.
    pub fn wants_refinement(spec: &ScenarioSpec, p: &Point) -> bool {
        spec.ask == Ask::Sim
            && Self::route(spec, p) == BackendId::Analytic
            && Self::confidence(spec, p) < 1.0
    }
}

/// The router registered as the third backend. Answers by delegating
/// each point to [`TrustTable::route`]'s pick, so it covers everything
/// the DES covers (nothing is refused — out-of-region points fall back
/// to replay, hence `steps_des`).
pub struct AutoBackend;

impl Backend for AutoBackend {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            id: BackendId::Auto,
            description: "trust-region router: analytic inside the \
                          measured error envelope, DES elsewhere",
            asks: &Ask::ALL,
            sim_shapes: &Shape::ALL,
            deterministic: true,
            steps_des: true,
        }
    }

    fn simulate(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SimResult {
        super::get(TrustTable::route(spec, p)).simulate(cfg, spec, p)
    }

    fn plan(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> PlanResult {
        super::get(TrustTable::route(spec, p)).plan(cfg, spec, p)
    }

    fn sparsity(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SparsityResult {
        super::get(TrustTable::route(spec, p)).sparsity(cfg, spec, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;
    use crate::sim::SparsityMode;

    fn point(n: usize, streams: usize) -> Point {
        Point { n, precision: Precision::Fp8, streams, iters: 50,
                devices: 1,
                transform: crate::replay::Transform::Identity }
    }

    #[test]
    fn routing_matches_the_measured_trust_region() {
        // Closed-form asks always take the fast path.
        let plan = ScenarioSpec::new(Ask::Plan);
        assert_eq!(
            TrustTable::route(&plan, &plan.expand()[0]),
            BackendId::Analytic
        );
        let sp = ScenarioSpec::sparsity_question(512, 4);
        assert_eq!(
            TrustTable::route(&sp, &sp.expand()[0]),
            BackendId::Analytic
        );
        // Homogeneous sim inside the envelope is analytic...
        let sim = ScenarioSpec::sim(512, Precision::Fp8, 4);
        assert_eq!(
            TrustTable::route(&sim, &point(512, 4)),
            BackendId::Analytic
        );
        // ...but high contention falls back to replay.
        assert_eq!(
            TrustTable::route(&sim, &point(512, TRUST_MAX_STREAMS + 1)),
            BackendId::Des
        );
        assert_eq!(
            TrustTable::route(&sim, &point(512, TRUST_MAX_STREAMS)),
            BackendId::Analytic
        );
        // The imbalanced pair is always replay.
        let mut pair = ScenarioSpec::new(Ask::Sim);
        pair.shape = Shape::ImbalancedPair;
        pair.streams = 2;
        assert_eq!(
            TrustTable::route(&pair, &point(2048, 2)),
            BackendId::Des
        );
        // Multi-device points are replay; their single-device scaling
        // anchor stays on the fast path.
        let mut dp = ScenarioSpec::new(Ask::Sim);
        dp.shape = Shape::DataParallel;
        let d4 = Point { devices: 4, ..point(512, 4) };
        assert_eq!(TrustTable::route(&dp, &d4), BackendId::Des);
        assert_eq!(
            TrustTable::route(&dp, &point(512, 4)),
            BackendId::Analytic
        );
        // ...and DES-routed multi-device points are fully trusted (no
        // refinement candidacy).
        assert_eq!(TrustTable::confidence(&dp, &d4), 1.0);
        assert!(!TrustTable::wants_refinement(&dp, &d4));
        // The replay shapes are always the reference engine, fully
        // trusted — no closed forms exist for them.
        for shape in [Shape::SpmmMix, Shape::Trace] {
            let mut s = ScenarioSpec::new(Ask::Sim);
            s.shape = shape;
            let p = point(512, 4);
            assert_eq!(TrustTable::route(&s, &p), BackendId::Des);
            assert_eq!(TrustTable::confidence(&s, &p), 1.0);
            assert!(!TrustTable::wants_refinement(&s, &p));
        }
    }

    #[test]
    fn tight_error_budgets_force_the_reference_engine() {
        let mut sim = ScenarioSpec::sim(512, Precision::Fp8, 4);
        sim.max_error = Some(DEFAULT_MAX_ERROR / 10.0);
        assert_eq!(TrustTable::route(&sim, &point(512, 4)), BackendId::Des);
        // At or above the advertised envelope the fast path stays on.
        sim.max_error = Some(DEFAULT_MAX_ERROR);
        assert_eq!(
            TrustTable::route(&sim, &point(512, 4)),
            BackendId::Analytic
        );
        // Budgets never loosen plan/sparsity (already exact).
        let mut plan = ScenarioSpec::new(Ask::Plan);
        plan.max_error = Some(0.01);
        assert_eq!(
            TrustTable::route(&plan, &plan.expand()[0]),
            BackendId::Analytic
        );
    }

    #[test]
    fn confidence_orders_refinement_most_uncertain_first() {
        let sim = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let mut prev = 1.1;
        for s in 1..=TRUST_MAX_STREAMS {
            let c = TrustTable::confidence(&sim, &point(512, s));
            assert!((0.0..=1.0).contains(&c));
            assert!(c < prev, "confidence falls with contention");
            prev = c;
        }
        // DES-routed and closed-form points are fully trusted.
        assert_eq!(
            TrustTable::confidence(&sim, &point(512, 16)),
            1.0
        );
        let plan = ScenarioSpec::new(Ask::Plan);
        assert_eq!(
            TrustTable::confidence(&plan, &plan.expand()[0]),
            1.0
        );
        // Sparsity overlays and the mixed shape cost confidence.
        let mut mixed = ScenarioSpec::sim(512, Precision::Fp8, 4);
        mixed.shape = Shape::MixedSparse;
        assert!(
            TrustTable::confidence(&mixed, &point(512, 4))
                < TrustTable::confidence(&sim, &point(512, 4))
        );
        let mut sparse = ScenarioSpec::sim(512, Precision::Fp8, 4);
        sparse.sparsity = SparsityMode::SparseLhs;
        assert!(
            TrustTable::confidence(&sparse, &point(512, 4))
                < TrustTable::confidence(&sim, &point(512, 4))
        );
        // Refinement wants exactly the analytic sim points that are
        // not fully trusted.
        assert!(TrustTable::wants_refinement(&sim, &point(512, 4)));
        assert!(!TrustTable::wants_refinement(&sim, &point(512, 16)));
        assert!(!TrustTable::wants_refinement(&plan, &plan.expand()[0]));
    }

    #[test]
    fn the_router_answers_exactly_like_its_routed_engine() {
        let cfg = Config::mi300a();
        let auto = super::super::get(BackendId::Auto);
        let analytic = super::super::get(BackendId::Analytic);
        let des = super::super::get(BackendId::Des);

        // In-region sim: byte-for-byte the analytic answer.
        let sim = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let p = point(512, 4);
        assert_eq!(
            auto.simulate(&cfg, &sim, &p),
            analytic.simulate(&cfg, &sim, &p)
        );
        // Out-of-region sim: byte-for-byte the replay answer.
        let hot = point(512, 12);
        assert_eq!(
            auto.simulate(&cfg, &sim, &hot),
            des.simulate(&cfg, &sim, &hot)
        );
        // Closed-form asks match both engines (they share one
        // implementation).
        let plan = ScenarioSpec::new(Ask::Plan);
        let pp = plan.expand()[0];
        assert_eq!(
            auto.plan(&cfg, &plan, &pp),
            analytic.plan(&cfg, &plan, &pp)
        );
        let sp = ScenarioSpec::sparsity_question(512, 4);
        let spp = sp.expand()[0];
        assert_eq!(
            auto.sparsity(&cfg, &sp, &spp),
            des.sparsity(&cfg, &sp, &spp)
        );
    }
}
