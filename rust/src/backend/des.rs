//! The `des` backend: the discrete-event engine behind the [`Backend`]
//! trait.
//!
//! This is the pre-backend service execution path moved verbatim — the
//! `sim` ask replays contention through [`crate::sim::Engine`] exactly
//! as `api::Service` did before the backend layer existed, so a request
//! that does not select a backend answers byte-identically to PR 4.

use super::{
    closed_form_plan, closed_form_sparsity, Backend, BackendId,
    Capabilities, PlanResult, SimResult, SparsityResult,
};
use crate::api::scenario::{Ask, Point, ScenarioSpec, Shape};
use crate::config::Config;
use crate::metrics::fairness::fairness;
use crate::sim::{ConcurrencyProfile, Engine};

/// The reference engine: replay the dynamics, event by event.
pub struct DesBackend;

impl Backend for DesBackend {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            id: BackendId::Des,
            description: "discrete-event replay of the contention \
                          dynamics (the reference engine)",
            asks: &Ask::ALL,
            sim_shapes: &Shape::ALL,
            deterministic: true,
            steps_des: true,
        }
    }

    fn simulate(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SimResult {
        let ks = spec.kernels(p);
        let engine = Engine::new(cfg, ConcurrencyProfile::ace());
        // One concurrent simulation per point: the speedup derives from
        // this run plus the (much cheaper) serial solo makespans instead
        // of re-simulating the set.
        let run = engine.run(&ks, cfg.seed);
        let speedup =
            engine.serial_makespan_ns(&ks, cfg.seed) / run.makespan_ns;
        SimResult {
            makespan_ms: run.makespan_ns / 1e6,
            speedup_vs_serial: speedup,
            overlap_efficiency: run.overlap_efficiency,
            fairness: fairness(&run.per_stream_totals()),
            l2_miss: run.l2_miss[0],
            lds_util: run.lds_util,
        }
    }

    fn plan(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> PlanResult {
        closed_form_plan(cfg, spec, p)
    }

    fn sparsity(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SparsityResult {
        closed_form_sparsity(cfg, spec, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    #[test]
    fn sim_points_answer_with_physical_invariants() {
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let p = spec.expand()[0];
        let r = DesBackend.simulate(&cfg, &spec, &p);
        assert!(
            r.speedup_vs_serial > 1.0 && r.speedup_vs_serial < 4.0,
            "speedup {}",
            r.speedup_vs_serial
        );
        assert!((0.0..=1.0).contains(&r.fairness));
        assert!(r.makespan_ms > 0.0);
    }

    #[test]
    fn sim_points_are_deterministic() {
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        let p = spec.expand()[0];
        let a = DesBackend.simulate(&cfg, &spec, &p);
        let b = DesBackend.simulate(&cfg, &spec, &p);
        assert_eq!(a, b);
    }
}
