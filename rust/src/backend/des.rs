//! The `des` backend: the discrete-event engine behind the [`Backend`]
//! trait.
//!
//! This is the pre-backend service execution path moved verbatim — the
//! `sim` ask replays contention through [`crate::sim::Engine`] exactly
//! as `api::Service` did before the backend layer existed, so a request
//! that does not select a backend answers byte-identically to PR 4.

use super::{
    closed_form_plan, closed_form_sparsity, Backend, BackendId,
    Capabilities, PlanResult, SimResult, SparsityResult,
};
use crate::api::scenario::{Ask, Point, ScenarioSpec, Shape};
use crate::config::Config;
use crate::fabric::{compose, DeviceSet, Fabric};
use crate::metrics::fairness::fairness;
use crate::replay::{replay, TraceSpec};
use crate::sim::{ConcurrencyProfile, Engine, FabricSim};

/// The reference engine: replay the dynamics, event by event.
pub struct DesBackend;

impl Backend for DesBackend {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            id: BackendId::Des,
            description: "discrete-event replay of the contention \
                          dynamics (the reference engine)",
            asks: &Ask::ALL,
            sim_shapes: &Shape::ALL,
            deterministic: true,
            steps_des: true,
        }
    }

    fn simulate(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SimResult {
        if spec.shape == Shape::Trace {
            // Trace points bypass the iterating stream-set engine: the
            // replay DES honors recorded issue times (streams idle
            // between launches) and reports per-launch spans. The spec
            // was validated at decode, so re-wrapping cannot fail.
            let ts = TraceSpec::from_records(spec.trace.clone())
                .expect("trace specs are validated before execution");
            let run = replay(cfg, &ts, p.transform, cfg.seed);
            return SimResult {
                makespan_ms: run.makespan_ns / 1e6,
                // vs the one-launch-at-a-time serial baseline; can dip
                // below 1 when the timeline is mostly idle gaps.
                speedup_vs_serial: run.serial_ns / run.makespan_ns,
                overlap_efficiency: run.overlap_efficiency,
                fairness: fairness(&run.per_stream_busy_ns),
                l2_miss: run.l2_miss,
                lds_util: run.lds_util,
                transfer_ms: 0.0,
                spans: run.spans.len(),
            };
        }
        let ks = spec.kernels(p);
        let engine = Engine::new(cfg, ConcurrencyProfile::ace());
        // One concurrent simulation per point: the speedup derives from
        // this run plus the (much cheaper) serial solo makespans instead
        // of re-simulating the set. Multi-device placements are uniform
        // (replica / K-split / M-shard), so this single run is every
        // device's compute.
        let run = engine.run(&ks, cfg.seed);
        let serial_ns = engine.serial_makespan_ns(&ks, cfg.seed);
        let mut makespan_ns = run.makespan_ns;
        let mut transfer_ns = 0.0;
        if p.devices > 1 && spec.shape.is_multi_device() {
            // Step the shape's per-iteration exchange as first-class
            // fabric events (processor sharing over links + egress
            // ports, the ACE machinery's twin in `sim::fabric`), then
            // compose it with the compute under the same overlap model
            // the analytic backend uses.
            let fabric = Fabric::for_set(DeviceSet::normalized(
                p.devices,
                spec.device_set.topology,
            ));
            let bytes = Fabric::shape_bytes(
                spec.shape,
                p.n,
                p.precision.bytes(),
            );
            let sched = fabric.shape_schedule(spec.shape, bytes);
            let stepped = FabricSim::new(fabric).run_schedule(&sched);
            // The pipeline schedule chains one relay per stage
            // boundary; compose wants the single-boundary relay.
            let round_ns = if spec.shape == Shape::Pipeline {
                stepped.elapsed_ns / (p.devices - 1) as f64
            } else {
                stepped.elapsed_ns
            };
            let c = compose(
                spec.shape,
                p.devices,
                run.makespan_ns,
                p.iters,
                round_ns,
            );
            makespan_ns = c.makespan_ns;
            transfer_ns = c.transfer_ns;
        }
        SimResult {
            makespan_ms: makespan_ns / 1e6,
            speedup_vs_serial: serial_ns / makespan_ns,
            overlap_efficiency: run.overlap_efficiency,
            fairness: fairness(&run.per_stream_totals()),
            l2_miss: run.l2_miss[0],
            lds_util: run.lds_util,
            transfer_ms: transfer_ns / 1e6,
            spans: 0,
        }
    }

    fn plan(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> PlanResult {
        closed_form_plan(cfg, spec, p)
    }

    fn sparsity(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SparsityResult {
        closed_form_sparsity(cfg, spec, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    #[test]
    fn sim_points_answer_with_physical_invariants() {
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let p = spec.expand()[0];
        let r = DesBackend.simulate(&cfg, &spec, &p);
        assert!(
            r.speedup_vs_serial > 1.0 && r.speedup_vs_serial < 4.0,
            "speedup {}",
            r.speedup_vs_serial
        );
        assert!((0.0..=1.0).contains(&r.fairness));
        assert!(r.makespan_ms > 0.0);
    }

    #[test]
    fn sim_points_are_deterministic() {
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        let p = spec.expand()[0];
        let a = DesBackend.simulate(&cfg, &spec, &p);
        let b = DesBackend.simulate(&cfg, &spec, &p);
        assert_eq!(a, b);
    }

    #[test]
    fn multi_device_points_pay_fabric_time_monotonically() {
        use crate::fabric::DeviceSet;
        use crate::util::json::Json;
        let cfg = Config::mi300a();
        let mut spec = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"shape":"data_parallel"}"#).unwrap(),
        )
        .unwrap();
        let mut prev_share = -1.0;
        for devices in 1..=4 {
            spec.device_set = DeviceSet::normalized(
                devices,
                spec.device_set.topology,
            );
            let p = spec.expand()[0];
            assert_eq!(p.devices, devices);
            let r = DesBackend.simulate(&cfg, &spec, &p);
            let share = r.transfer_ms / r.makespan_ms;
            assert!(
                share > prev_share,
                "d={devices}: transfer share {share} !> {prev_share}"
            );
            if devices == 1 {
                assert_eq!(r.transfer_ms, 0.0, "one device, no fabric");
            } else {
                assert!(r.transfer_ms > 0.0);
                assert!(r.makespan_ms > r.transfer_ms);
            }
            prev_share = share;
        }
    }

    #[test]
    fn trace_points_replay_with_spans_and_precision_monotonicity() {
        use crate::util::json::Json;
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::from_json(
            &Json::parse(
                r#"{"shape":"trace","trace":[
                    {"n":512,"precision":"fp16","stream":0,"issue_ns":0},
                    {"n":512,"precision":"fp16","stream":1,"issue_ns":1000},
                    {"n":512,"precision":"fp16","stream":0,"issue_ns":500000},
                    {"n":512,"precision":"fp16","stream":1,"issue_ns":500000}
                ]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let p = spec.expand()[0];
        let a = DesBackend.simulate(&cfg, &spec, &p);
        assert_eq!(a.spans, 4, "one span per launch");
        assert!(a.makespan_ms > 0.0);
        assert!((0.0..=1.0).contains(&a.fairness));
        assert_eq!(a.transfer_ms, 0.0);
        assert_eq!(a, DesBackend.simulate(&cfg, &spec, &p), "deterministic");
        // The precision_rewrite what-if strictly beats the fp16
        // original (smaller launches, same issue times).
        let fp8 = Point {
            transform: crate::replay::Transform::PrecisionRewrite(
                Precision::Fp8,
            ),
            ..p
        };
        let b = DesBackend.simulate(&cfg, &spec, &fp8);
        assert!(
            b.makespan_ms < a.makespan_ms,
            "fp8 {} !< fp16 {}",
            b.makespan_ms,
            a.makespan_ms
        );
    }

    #[test]
    fn single_device_multi_shape_matches_homogeneous() {
        // devices=1 on data_parallel is the scaling anchor: the replica
        // placement equals the homogeneous set, so the answer must be
        // the plain single-APU one (no fabric terms at all).
        use crate::util::json::Json;
        let cfg = Config::mi300a();
        let dp = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"shape":"data_parallel"}"#).unwrap(),
        )
        .unwrap();
        let p = dp.expand()[0];
        let a = DesBackend.simulate(&cfg, &dp, &p);
        let homog = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let b = DesBackend.simulate(&cfg, &homog, &homog.expand()[0]);
        assert_eq!(a, b);
    }
}
