//! The `analytic` backend: calibrated closed forms, no event stepping.
//!
//! The paper's models are already closed forms almost everywhere — the
//! roofline solo cost (`sim/cost.rs`), the LDS saturation heatmap
//! (`hw/lds.rs`), the L2 anchor interpolation (`hw/l2.rs`), the §9.2
//! fairness table (`coordinator/concurrency.rs`), and the sparsity
//! break-even model (`sparsity/speedup.rs`). The DES exists to replay
//! how those forces *interact over time*; this backend instead composes
//! them directly:
//!
//! * **Mean-field cycle model** — each stream's iteration cycle is
//!   `launch + solo_work × slowdown(full set)`, with the slowdown built
//!   from exactly the DES's rate formula (LDS saturation, L2 miss
//!   growth, sparse memory-weight relief) evaluated once for the full
//!   running set, and the command-lane capacity bound
//!   (`Σ launch-duty ≤ lanes`) applied as a uniform stretch.
//! * **Order-statistics tail** — the DES draws one placement bias per
//!   stream (lognormal, contention-scaled sigma); the makespan is
//!   governed by the slowest draw, whose excess runs near solo speed
//!   once the other streams have drained. We add
//!   `(E[max of s lognormals] − 1) × solo makespan` for that tail.
//! * **Calibrated anchors** — fairness comes from the paper's Fig 5a
//!   table ([`expected_fairness`], the same table the coordinator
//!   schedules by), overlap efficiency from the §6.1 calibration
//!   anchors of the `ace` profile.
//!
//! `l2_miss` and `lds_util` use the *same* model calls as the DES
//! report path, so they match it exactly; the time-domain outputs are
//! first-order estimates. The tolerance statement lives in
//! `docs/backends.md` and is enforced against the DES on the
//! `docs/scenarios.md` cookbook points by `tests/backend_equivalence.rs`.
//!
//! The `imbalanced_pair` sim shape is deliberately unsupported:
//! fragmentation fairness is driven by bias order statistics
//! interacting with unequal completion times — replay territory. The
//! service answers it with a typed `unsupported_by_backend` error.

use super::{
    closed_form_plan, closed_form_sparsity, Backend, BackendId,
    Capabilities, PlanResult, SimResult, SparsityResult,
};
use crate::api::scenario::{Ask, Point, ScenarioSpec, Shape};
use crate::config::Config;
use crate::coordinator::expected_fairness;
use crate::fabric::{compose, DeviceSet, Fabric};
use crate::hw::lds::lds_utilization;
use crate::sim::cost::CostModel;
use crate::sim::{ConcurrencyProfile, Engine, KernelDesc};

/// E[max of s iid standard normals] for s = 1..=16 (the `sim` ask's
/// stream range). Standard order-statistic means; index `s - 1`.
const NORMAL_MAX_MEAN: [f64; 16] = [
    0.0, 0.5642, 0.8463, 1.0294, 1.1630, 1.2672, 1.3522, 1.4236, 1.4850,
    1.5388, 1.5865, 1.6292, 1.6680, 1.7034, 1.7359, 1.7660,
];

/// E[max of s iid unit-mean lognormals] with log-sigma `sigma`:
/// each draw is `exp(sigma·Z − sigma²/2)`, so the max is approximately
/// `exp(sigma·E[max Z] − sigma²/2)`.
fn expected_max_lognormal(sigma: f64, s: usize) -> f64 {
    let c = NORMAL_MAX_MEAN[s.clamp(1, 16) - 1];
    (sigma * c - sigma * sigma / 2.0).exp()
}

/// Calibrated overlap-efficiency anchors for the `ace` profile
/// (§6.1: 43-46% at four streams, 64-65% at eight; zero solo), linearly
/// interpolated, saturating toward 0.80 at the 16-stream cap. The
/// 2-stream anchor is a model estimate, not a paper measurement: two
/// streams on two command lanes launch without queuing, so their work
/// phases stay partially aligned (more overlap per stream than the
/// lane-staggered 4-stream case would extrapolate to).
fn expected_overlap(streams: usize) -> f64 {
    const ANCHORS: [(f64, f64); 5] = [
        (1.0, 0.0),
        (2.0, 0.35),
        (4.0, 0.445),
        (8.0, 0.645),
        (16.0, 0.80),
    ];
    let s = streams as f64;
    if s <= 1.0 {
        return 0.0;
    }
    for w in ANCHORS.windows(2) {
        let ((s0, f0), (s1, f1)) = (w[0], w[1]);
        if s <= s1 {
            return f0 + (f1 - f0) * (s - s0) / (s1 - s0);
        }
    }
    0.80
}

/// The fast-path estimator: answer points from the calibrated closed
/// forms, never stepping a discrete event.
pub struct AnalyticBackend;

impl Backend for AnalyticBackend {
    fn capabilities(&self) -> Capabilities {
        Capabilities {
            id: BackendId::Analytic,
            description: "calibrated closed forms (cost/occupancy/\
                          sparsity models), no DES stepping",
            asks: &Ask::ALL,
            sim_shapes: &[
                Shape::Homogeneous,
                Shape::MixedSparse,
                Shape::DataParallel,
                Shape::Pipeline,
                Shape::Halo,
            ],
            deterministic: true,
            steps_des: false,
        }
    }

    fn simulate(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SimResult {
        let ks = spec.kernels(p);
        let s = ks.len();
        // The same calibration family the DES sim ask runs under.
        let profile = ConcurrencyProfile::ace();
        let cost = CostModel::new(cfg);
        let l2 = cost.l2();
        let max_n = ks.iter().map(|k| k.m.max(k.n)).max().unwrap_or(512);
        let lds_sat = lds_utilization(
            max_n,
            s,
            cfg.total_cus(),
            cfg.lds_bytes_per_cu() as usize,
            cfg.calib.lds_double_buffer,
        );
        let conc = if s >= 2 { 1.0 } else { 0.0 };
        let mem_w = |k: &KernelDesc| {
            if k.sparsity.is_sparse() {
                cfg.sparsity.mem_fraction
            } else {
                1.0
            }
        };
        // Effective memory streams, exactly as the DES's rate model
        // rounds them (sparse streams exert proportionally less).
        let eff = ks
            .iter()
            .map(|k| mem_w(k))
            .sum::<f64>()
            .round()
            .max(1.0) as usize;

        let mut serial_ns = 0.0f64;
        let mut lane_duty = 0.0f64;
        let mut base_ns = 0.0f64; // slowest stream, mean-field
        let mut solo_ns = 0.0f64; // slowest stream, uncontended
        let mut sigma_sum = 0.0f64;
        for k in &ks {
            let w = cost.solo_work_ns(k);
            let launch = w * profile.launch_ratio;
            let mw = mem_w(k);
            let sparse_w = if k.sparsity.is_sparse() {
                cfg.sparsity.mem_fraction.powi(2)
            } else {
                1.0
            };
            let ws = k.working_set();
            let grown = l2.miss_ratio(ws, eff);
            let l2_growth = ((grown / l2.isolated_miss(ws)) - 1.0).max(0.0)
                * mw
                / cfg.calib.l2_miss_stream_slope;
            // The DES rate formula with the full set resident (the ace
            // profile has no external contention term).
            let slowdown = 1.0
                + profile.k_lds * lds_sat * sparse_w * conc
                + profile.k_l2 * l2_growth;
            let iters = k.iters as f64;
            let cycle = launch + w * slowdown;
            lane_duty += launch / cycle;
            base_ns = base_ns.max(iters * cycle);
            solo_ns = solo_ns.max(iters * (launch + w));
            serial_ns += iters * (launch + w);
            sigma_sum += profile.bias_sigma
                * Engine::pressure(s)
                * cfg.jitter_scale(k.precision)
                * mw;
        }
        // Command-lane capacity: when aggregate launch duty exceeds the
        // lanes, every cycle stretches by the overload factor.
        let lanes = profile.launch_lanes.max(1) as f64;
        let lane_scale = (lane_duty / lanes).max(1.0);
        // Placement-bias tail: the slowest draw's excess work runs near
        // solo speed once the faster streams have drained.
        let sigma = sigma_sum / s as f64;
        let tail_ns = (expected_max_lognormal(sigma, s) - 1.0) * solo_ns;
        let mut makespan_ns = base_ns * lane_scale + tail_ns;
        let mut transfer_ns = 0.0;
        if p.devices > 1 && spec.shape.is_multi_device() {
            // The fabric half stays closed-form: the link-saturation
            // collective formulas at the calibrated anchors, composed
            // with the compute estimate under the exact overlap model
            // the DES uses — so the multi-device equivalence gap is the
            // compute estimate's alone.
            let fabric = Fabric::for_set(DeviceSet::normalized(
                p.devices,
                spec.device_set.topology,
            ));
            let bytes = Fabric::shape_bytes(
                spec.shape,
                p.n,
                p.precision.bytes(),
            );
            let round_ns = match spec.shape {
                Shape::DataParallel => fabric.allreduce_ns(bytes),
                Shape::Pipeline => fabric.stage_ns(bytes),
                _ => fabric.halo_ns(bytes),
            };
            let c = compose(
                spec.shape,
                p.devices,
                makespan_ns,
                p.iters,
                round_ns,
            );
            makespan_ns = c.makespan_ns;
            transfer_ns = c.transfer_ns;
        }
        SimResult {
            makespan_ms: makespan_ns / 1e6,
            speedup_vs_serial: serial_ns / makespan_ns,
            overlap_efficiency: expected_overlap(s),
            fairness: expected_fairness(p.precision, s),
            // Identical model calls to the DES report path: exact match.
            l2_miss: l2.miss_ratio(ks[0].working_set(), s),
            lds_util: lds_sat,
            transfer_ms: transfer_ns / 1e6,
            spans: 0,
        }
    }

    fn plan(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> PlanResult {
        closed_form_plan(cfg, spec, p)
    }

    fn sparsity(
        &self,
        cfg: &Config,
        spec: &ScenarioSpec,
        p: &Point,
    ) -> SparsityResult {
        closed_form_sparsity(cfg, spec, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    fn sim_at(n: usize, streams: usize) -> SimResult {
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::sim(n, Precision::Fp8, streams);
        let p = spec.expand()[0];
        AnalyticBackend.simulate(&cfg, &spec, &p)
    }

    #[test]
    fn solo_point_is_the_exact_uncontended_baseline() {
        let r = sim_at(512, 1);
        assert!(
            (r.speedup_vs_serial - 1.0).abs() < 1e-9,
            "solo speedup must be exactly 1, got {}",
            r.speedup_vs_serial
        );
        assert_eq!(r.overlap_efficiency, 0.0);
        assert_eq!(r.fairness, 1.0);
    }

    #[test]
    fn concurrency_beats_serial_but_sublinearly() {
        for s in [2usize, 4, 8, 16] {
            let r = sim_at(512, s);
            assert!(
                r.speedup_vs_serial > 1.0 && r.speedup_vs_serial < s as f64,
                "streams={s}: speedup {}",
                r.speedup_vs_serial
            );
            assert!((0.0..=1.0).contains(&r.fairness));
            assert!((0.0..=1.0).contains(&r.overlap_efficiency));
        }
    }

    #[test]
    fn overlap_and_fairness_trend_like_the_paper() {
        let r4 = sim_at(512, 4);
        let r8 = sim_at(512, 8);
        assert!(r8.overlap_efficiency > r4.overlap_efficiency);
        assert!(r8.fairness < r4.fairness, "fairness collapses at 8");
        // The §6.1 calibration anchors.
        assert!((0.40..=0.50).contains(&r4.overlap_efficiency));
        assert!((0.45..=0.60).contains(&r4.fairness), "{}", r4.fairness);
    }

    #[test]
    fn order_statistics_helpers_are_sane() {
        assert_eq!(expected_max_lognormal(0.0, 8), 1.0);
        assert_eq!(expected_max_lognormal(0.5, 1), (-0.125f64).exp());
        let m4 = expected_max_lognormal(0.4, 4);
        let m8 = expected_max_lognormal(0.4, 8);
        assert!(m8 > m4 && m4 > 1.0);
        assert_eq!(expected_overlap(1), 0.0);
        assert!((expected_overlap(4) - 0.445).abs() < 1e-12);
        assert!(expected_overlap(32) <= 0.80 + 1e-12);
    }

    #[test]
    fn deterministic_per_config() {
        assert_eq!(sim_at(1024, 4), sim_at(1024, 4));
    }

    #[test]
    fn multi_device_closed_forms_expose_growing_transfer_share() {
        use crate::fabric::{DeviceSet, Topology};
        use crate::util::json::Json;
        let cfg = Config::mi300a();
        for topology in Topology::ALL {
            let mut spec = ScenarioSpec::from_json(
                &Json::parse(r#"{"n":512,"shape":"data_parallel"}"#)
                    .unwrap(),
            )
            .unwrap();
            let mut prev = -1.0;
            for devices in 1..=4 {
                spec.device_set = DeviceSet::normalized(devices, topology);
                let p = spec.expand()[0];
                let r = AnalyticBackend.simulate(&cfg, &spec, &p);
                let share = r.transfer_ms / r.makespan_ms;
                assert!(
                    share > prev,
                    "{topology:?} d={devices}: {share} !> {prev}"
                );
                prev = share;
            }
        }
        // devices=1 on a multi-device shape stays the plain answer.
        let dp = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"shape":"data_parallel"}"#).unwrap(),
        )
        .unwrap();
        let a = AnalyticBackend.simulate(&cfg, &dp, &dp.expand()[0]);
        assert_eq!(a.transfer_ms, 0.0);
        let homog = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let b =
            AnalyticBackend.simulate(&cfg, &homog, &homog.expand()[0]);
        assert_eq!(a, b);
    }
}
