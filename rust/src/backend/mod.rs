//! Pluggable execution backends (DESIGN.md §6.8).
//!
//! The paper yields two distinct ways to answer the same question:
//! **replay** the contention dynamics (the DES in [`crate::sim`]) or
//! **evaluate** the calibrated closed forms directly (occupancy
//! thresholds, fairness ratios, sparsity break-evens). A [`Backend`]
//! packages one such answering strategy behind a uniform trait; the
//! service compiles every scenario point down to whichever backend the
//! request selected (`"backend"` envelope key / ScenarioSpec field,
//! default [`DEFAULT`] = `des`).
//!
//! Three implementations ship:
//!
//! * [`des::DesBackend`] — the existing `sim::engine` discrete-event
//!   simulator, moved behind the trait with **zero behavior change**:
//!   a request that does not name a backend answers byte-identically
//!   to the pre-backend service.
//! * [`analytic::AnalyticBackend`] — closed-form evaluation from the
//!   calibrated cost/occupancy/sparsity models (`sim/cost.rs`,
//!   `coordinator/occupancy.rs` + `concurrency.rs`,
//!   `sparsity/speedup.rs`) without stepping the DES. Orders of
//!   magnitude faster per point; first-order accurate (the tolerance
//!   statement lives in `docs/backends.md` and is enforced by
//!   `tests/backend_equivalence.rs`).
//! * [`auto::AutoBackend`] — a **router**, not an engine: each point
//!   resolves through the measured [`auto::TrustTable`] to `analytic`
//!   where the equivalence corpus proves the closed forms trustworthy
//!   and to `des` elsewhere (DESIGN.md §6.10, `docs/auto_backend.md`;
//!   calibration is regression-tested by `tests/trust_table.rs`). The
//!   service resolves the route *before* execution and cache-keying,
//!   so auto-routed points share cache entries — and cold-run
//!   counters — with their concrete backend.
//!
//! [`REGISTRY`] mirrors the `experiments::REGISTRY` pattern: a static
//! table that `Request::Backends` discovery, the service dispatcher,
//! the docs-coverage test, and the CI backend-matrix smoke all consume.
//! Adding a backend is one new module implementing [`Backend`] plus one
//! [`BackendId`] variant and one registry row.
//!
//! The `plan` and `sparsity` asks were already closed-form (the
//! coordinator and the speedup model never step the DES), so both
//! backends share one implementation ([`closed_form_plan`] /
//! [`closed_form_sparsity`]) and answer those asks byte-identically;
//! only the `sim` ask diverges (replay vs estimate).

pub mod analytic;
pub mod auto;
pub mod des;

pub use analytic::AnalyticBackend;
pub use auto::AutoBackend;
pub use des::DesBackend;

use crate::api::scenario::{Ask, Point, ScenarioSpec, Shape};
use crate::config::Config;
use crate::coordinator::{decide_sparsity, Coordinator, Objective};
use crate::sim::{KernelDesc, SparsityMode};
use crate::sparsity::SpeedupModel;

/// Stable backend identifier. The wire spelling ([`BackendId::as_str`])
/// is part of the protocol: it is what the `"backend"` key carries,
/// what `Request::Backends` lists, and what the per-backend `stats`
/// counters are named after.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendId {
    /// Discrete-event replay (`sim::engine`) — the reference engine.
    Des,
    /// Calibrated closed forms — the fast-path estimator.
    Analytic,
    /// Trust-region router: analytic inside the measured envelope,
    /// DES elsewhere.
    Auto,
}

impl BackendId {
    /// Every registered backend, in [`REGISTRY`] order.
    pub const ALL: [BackendId; 3] =
        [BackendId::Des, BackendId::Analytic, BackendId::Auto];

    /// The stable wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            BackendId::Des => "des",
            BackendId::Analytic => "analytic",
            BackendId::Auto => "auto",
        }
    }

    /// Inverse of [`BackendId::as_str`].
    pub fn parse(s: &str) -> Option<BackendId> {
        BackendId::ALL.iter().copied().find(|b| b.as_str() == s)
    }

    /// Index into [`REGISTRY`] (and the service's per-backend
    /// counters).
    pub fn index(self) -> usize {
        match self {
            BackendId::Des => 0,
            BackendId::Analytic => 1,
            BackendId::Auto => 2,
        }
    }

    /// The flattened `stats` field carrying this backend's cold-run
    /// counter (pinned by `tests/api_protocol.rs`). `engine_runs_auto`
    /// stays 0 by design: the router resolves to a concrete engine
    /// before execution, so its points count under `des`/`analytic`.
    pub fn stat_field(self) -> &'static str {
        match self {
            BackendId::Des => "engine_runs_des",
            BackendId::Analytic => "engine_runs_analytic",
            BackendId::Auto => "engine_runs_auto",
        }
    }

    /// `des|analytic|auto` — for error messages listing the registry.
    pub fn names() -> String {
        BackendId::ALL
            .iter()
            .map(|b| b.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

/// Number of registered backends (sizes the service's counters).
pub const COUNT: usize = BackendId::ALL.len();

/// The backend requests get when they do not name one. `des` keeps
/// every pre-backend response byte-identical.
pub const DEFAULT: BackendId = BackendId::Des;

/// What a backend can answer. Requests outside a backend's
/// capabilities are refused up front with a typed
/// `unsupported_by_backend` error — never half-answered.
#[derive(Debug, Clone, Copy)]
pub struct Capabilities {
    pub id: BackendId,
    /// One-line description (surfaced by `Request::Backends`).
    pub description: &'static str,
    /// Asks the backend answers at all.
    pub asks: &'static [Ask],
    /// Stream-set shapes the backend's `sim` ask handles. (`plan` and
    /// `sparsity` are shape-complete on every backend: the coordinator
    /// plans arbitrary pools, and the sparsity ask is validated to a
    /// homogeneous candidate anyway.)
    pub sim_shapes: &'static [Shape],
    /// Whether answers are pure functions of the `Config` (safe to
    /// cache). Both shipped backends are.
    pub deterministic: bool,
    /// Whether `sim` points execute discrete events (the cost the
    /// analytic fast path exists to avoid).
    pub steps_des: bool,
}

impl Capabilities {
    /// Whether this backend can answer `ask` over `shape`.
    pub fn supports(&self, ask: Ask, shape: Shape) -> bool {
        if !self.asks.contains(&ask) {
            return false;
        }
        ask != Ask::Sim || self.sim_shapes.contains(&shape)
    }
}

/// What a `sim` point answers (mirrors the wire `sim` response).
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    pub makespan_ms: f64,
    pub speedup_vs_serial: f64,
    pub overlap_efficiency: f64,
    pub fairness: f64,
    pub l2_miss: f64,
    pub lds_util: f64,
    /// Unhidden Infinity Fabric transfer time (`crate::fabric`);
    /// exactly 0 on single-device points.
    pub transfer_ms: f64,
    /// Per-launch span count from trace replay (`crate::replay`);
    /// exactly 0 on every non-`trace` shape.
    pub spans: usize,
}

/// One scheduled group inside a [`PlanResult`].
#[derive(Debug, Clone, PartialEq)]
pub struct PlanGroupResult {
    pub kernels: Vec<String>,
    pub streams: usize,
    pub expected_fairness: f64,
    pub process_isolation: bool,
}

/// What a `plan` point answers.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanResult {
    pub objective: Objective,
    pub sparse: bool,
    pub groups: Vec<PlanGroupResult>,
}

/// What a `sparsity` point answers.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityResult {
    pub enable: bool,
    pub reason: String,
    pub isolated_speedup: f64,
    pub concurrent_speedup: f64,
}

/// One answering strategy for scenario points. Implementations must be
/// stateless (`Send + Sync`, shared from a static registry) and
/// deterministic per `Config`; callers gate on
/// [`Capabilities::supports`] before invoking, so the answer methods
/// are infallible.
pub trait Backend: Send + Sync {
    /// What this backend can answer, and how.
    fn capabilities(&self) -> Capabilities;
    /// Answer a `sim` point.
    fn simulate(&self, cfg: &Config, spec: &ScenarioSpec, p: &Point)
        -> SimResult;
    /// Answer a `plan` point.
    fn plan(&self, cfg: &Config, spec: &ScenarioSpec, p: &Point)
        -> PlanResult;
    /// Answer a `sparsity` point.
    fn sparsity(&self, cfg: &Config, spec: &ScenarioSpec, p: &Point)
        -> SparsityResult;
}

/// Every backend, in [`BackendId::ALL`] order — the single source of
/// truth for discovery, dispatch, docs coverage, and the CI matrix.
pub static REGISTRY: &[&dyn Backend] =
    &[&DesBackend, &AnalyticBackend, &AutoBackend];

/// Look a backend up by id (total: every [`BackendId`] is registered).
pub fn get(id: BackendId) -> &'static dyn Backend {
    REGISTRY[id.index()]
}

/// Look a backend up by wire spelling.
pub fn find(s: &str) -> Option<&'static dyn Backend> {
    BackendId::parse(s).map(get)
}

/// The one `plan` implementation both backends share: the coordinator
/// is already a closed-form layer (occupancy-matched co-scheduling,
/// the §9.2 concurrency governor, the context-dependent sparsity
/// policy) — no DES involved. Byte-for-byte the pre-backend service
/// path.
pub fn closed_form_plan(
    cfg: &Config,
    spec: &ScenarioSpec,
    p: &Point,
) -> PlanResult {
    let ks = spec.kernels(p);
    let objective = spec.objective.unwrap_or(Objective::LatencySensitive);
    let coord = Coordinator::new(cfg.clone(), objective);
    let plan = coord.plan(&ks, true);
    PlanResult {
        objective,
        sparse: plan
            .groups
            .iter()
            .any(|g| g.kernels.iter().any(|k| k.sparsity.is_sparse())),
        groups: plan
            .groups
            .iter()
            .map(|g| PlanGroupResult {
                kernels: g.kernels.iter().map(|k| k.label()).collect(),
                streams: g.streams,
                expected_fairness: g.expected_fairness,
                process_isolation: g.process_isolation,
            })
            .collect(),
    }
}

/// The one `sparsity` implementation both backends share: the §9.2
/// decision table plus the Fig 11-13 speedup model — closed forms by
/// construction. Byte-for-byte the pre-backend service path
/// (validation pins sparsity asks to a dense homogeneous candidate, so
/// the single kernel is built directly).
pub fn closed_form_sparsity(
    cfg: &Config,
    _spec: &ScenarioSpec,
    p: &Point,
) -> SparsityResult {
    let k = KernelDesc::gemm(p.n, p.precision).with_iters(p.iters);
    let d = decide_sparsity(&k, p.streams, true);
    let model = SpeedupModel::new(cfg);
    SparsityResult {
        enable: d.enable,
        reason: format!("{:?}", d.reason),
        isolated_speedup: model
            .isolated(&k, SparsityMode::SparseLhs)
            .speedup(),
        concurrent_speedup: model.concurrent_per_stream(&k, p.streams.max(2)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    #[test]
    fn ids_roundtrip_and_index_the_registry() {
        assert_eq!(REGISTRY.len(), COUNT);
        for (i, id) in BackendId::ALL.iter().enumerate() {
            assert_eq!(BackendId::parse(id.as_str()), Some(*id));
            assert_eq!(id.index(), i);
            assert_eq!(
                REGISTRY[i].capabilities().id,
                *id,
                "registry order must match BackendId::ALL"
            );
            assert!(id.stat_field().starts_with("engine_runs_"));
            assert!(id.stat_field().ends_with(id.as_str()));
        }
        assert_eq!(BackendId::parse("nope"), None);
        assert!(find("des").is_some());
        assert!(find("frobnicate").is_none());
        assert_eq!(DEFAULT, BackendId::Des);
    }

    #[test]
    fn capability_table_is_honest() {
        let des = get(BackendId::Des).capabilities();
        let analytic = get(BackendId::Analytic).capabilities();
        let auto = get(BackendId::Auto).capabilities();
        // The reference engine answers everything.
        for ask in Ask::ALL {
            for shape in Shape::ALL {
                assert!(des.supports(ask, shape), "{ask:?}/{shape:?}");
            }
        }
        assert!(des.steps_des && !analytic.steps_des);
        assert!(des.deterministic && analytic.deterministic);
        // The analytic sim handles homogeneous/mixed but refuses the
        // imbalanced pair (fragmentation fairness is replay territory).
        assert!(analytic.supports(Ask::Sim, Shape::Homogeneous));
        assert!(analytic.supports(Ask::Sim, Shape::MixedSparse));
        assert!(!analytic.supports(Ask::Sim, Shape::ImbalancedPair));
        // The multi-device shapes are closed-form on the comm side
        // (link-saturation bounds), so analytic answers them too.
        assert!(analytic.supports(Ask::Sim, Shape::DataParallel));
        assert!(analytic.supports(Ask::Sim, Shape::Pipeline));
        assert!(analytic.supports(Ask::Sim, Shape::Halo));
        // Irregular SpMM contention and issue-time replay are replay
        // territory: the closed forms refuse both, typed.
        assert!(!analytic.supports(Ask::Sim, Shape::SpmmMix));
        assert!(!analytic.supports(Ask::Sim, Shape::Trace));
        // Plan/sparsity are shape-complete on every backend.
        for shape in Shape::ALL {
            assert!(analytic.supports(Ask::Plan, shape));
            assert!(analytic.supports(Ask::Sparsity, shape));
        }
        // The router covers everything the DES covers (out-of-region
        // points fall back to replay, so nothing is refused) and may
        // step the DES on the fallback path.
        for ask in Ask::ALL {
            for shape in Shape::ALL {
                assert!(auto.supports(ask, shape), "auto {ask:?}/{shape:?}");
            }
        }
        assert!(auto.steps_des && auto.deterministic);
    }

    #[test]
    fn plan_and_sparsity_are_shared_closed_forms_across_backends() {
        let cfg = Config::mi300a();
        let spec = ScenarioSpec::plan(
            Objective::ThroughputOriented,
            8,
            512,
            Precision::Fp8,
        );
        let p = spec.expand()[0];
        let a = get(BackendId::Des).plan(&cfg, &spec, &p);
        let b = get(BackendId::Analytic).plan(&cfg, &spec, &p);
        assert_eq!(a, b, "plan must be backend-invariant");

        let spec = ScenarioSpec::sparsity_question(512, 4);
        let p = spec.expand()[0];
        let a = get(BackendId::Des).sparsity(&cfg, &spec, &p);
        let b = get(BackendId::Analytic).sparsity(&cfg, &spec, &p);
        assert_eq!(a, b, "sparsity must be backend-invariant");
    }
}
