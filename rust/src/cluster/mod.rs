//! Cluster mode: a coordinator sharding scenario sweeps across a
//! static set of workers (DESIGN.md §6.9, docs/cluster.md).
//!
//! A **worker** is an ordinary `mi300a-char serve` instance — cluster
//! mode adds nothing to it. The **coordinator** ([`Coordinator`]) is a
//! second [`crate::serve::Dispatch`] implementation served through the
//! identical framing machinery ([`crate::serve::serve_on`]), so clients
//! — the typed [`Client`], `scenario --addr`, `loadgen --addr` — speak
//! the unchanged v1 protocol and cannot tell a coordinator from a
//! standalone service.
//!
//! ## Routing
//!
//! Every sweep point is routed by the consistent hash
//! ([`ring::Ring`]) of its canonical per-point cache key — the same
//! key a standalone service memoizes the point under, with the
//! resolved backend baked in — so a given point always lands on the
//! same worker and repeats hit that worker's warm result cache.
//! Single-point and non-scenario requests (`run`, `repro`, `config`,
//! `backends`, `list_experiments`) proxy whole to the owner of their
//! request cache key, keeping request-level cache entries per-worker
//! too. Job requests (`submit`/`job_*`) are answered from the
//! coordinator's own bounded [`JobTable`]; its cluster job workers
//! execute each job's points remotely through the same routed path, so
//! progress frames and cancel semantics match a standalone service
//! frame for frame — including the DES refinement pass of budgeted
//! `auto` jobs ([`refine_job_remote`]), whose re-runs route through
//! the same ring to the owner of each point's des-resolved key.
//!
//! ## Failure handling
//!
//! A dead or `overloaded` worker is retried on the surviving replicas:
//! the ring yields every worker once in a key-deterministic preference
//! order, the coordinator walks that order up to [`ROUTE_ROUNDS`]
//! times with doubling backoff between rounds, and only when every
//! replica has refused every round does the point answer a typed
//! `runtime` error naming the last failure. Typed worker errors other
//! than `overloaded` are not retried — they would fail identically on
//! every replica — and flow through as the point's result, exactly as
//! a standalone service embeds per-point errors.
//!
//! ## Observability
//!
//! `stats` on the coordinator aggregates the reachable workers'
//! `cache_*`/`engine_runs*` counters and adds the coordinator-only
//! `cluster_*` block ([`crate::api::ClusterStats`]): configured worker
//! count, points routed, requests proxied, delivery retries, and
//! points that exhausted every replica.

pub mod ring;

pub use ring::Ring;

use crate::api::job::{JobTable, Watcher};
use crate::api::{
    ApiError, CacheStats, Client, ClusterStats, ErrorCode, JobLimits,
    JobView, OverloadedRetry, Point, PointResult, Request, RequestEnvelope,
    Response, ScenarioSpec, MAX_BATCH_ITEMS,
};
use crate::backend::auto::TrustTable;
use crate::backend::{self, BackendId};
use crate::serve::{serve_on, Dispatch, IoModel};
use crate::util::pool;
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

/// How many times the coordinator walks the full replica order before
/// a point (or proxied request) answers a typed `runtime` failure.
/// Between rounds the walk sleeps with doubling backoff (the
/// [`OverloadedRetry`] default's base, capped at 250 ms).
pub const ROUTE_ROUNDS: usize = 3;

/// The shared routing state: worker addresses, the hash ring, and the
/// `cluster_*` counters. Connection threads and cluster job workers
/// share it behind an `Arc`.
struct ClusterCore {
    /// Worker addresses, index-aligned with the ring's members.
    workers: Vec<String>,
    ring: Ring,
    /// The backend answering requests that name none — resolved into
    /// the spec *before* hashing, so the routed key equals the worker's
    /// cache key.
    default_backend: BackendId,
    /// Inter-node `overloaded` retry policy (always on; see
    /// [`OverloadedRetry`]).
    retry: OverloadedRetry,
    points_routed: AtomicU64,
    proxied: AtomicU64,
    retries: AtomicU64,
    point_failures: AtomicU64,
}

/// The cluster front door: a [`Dispatch`] implementation that fans
/// sweep points out across workers and merges their answers. Serve it
/// with [`serve_cluster`] (or [`serve_on`] directly); use it in-process
/// exactly like a [`crate::api::Service`].
pub struct Coordinator {
    core: Arc<ClusterCore>,
    jobs: Arc<JobTable>,
    job_workers: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Coordinator over `workers` (non-empty; the CLI validates the
    /// `--workers` list before building one) with default job limits.
    pub fn new(workers: Vec<String>, default_backend: BackendId) -> Coordinator {
        Coordinator::with_limits(workers, default_backend, JobLimits::default())
    }

    /// [`Coordinator::new`] with explicit job-table limits (tests
    /// shrink the queue to exercise `overloaded` deterministically).
    /// Spawns `limits.max_running` cluster job workers; all exit when
    /// the coordinator is dropped.
    pub fn with_limits(
        workers: Vec<String>,
        default_backend: BackendId,
        limits: JobLimits,
    ) -> Coordinator {
        let ring = Ring::new(workers.len());
        let core = Arc::new(ClusterCore {
            workers,
            ring,
            default_backend,
            retry: OverloadedRetry::default(),
            points_routed: AtomicU64::new(0),
            proxied: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            point_failures: AtomicU64::new(0),
        });
        let jobs = Arc::new(JobTable::new(limits));
        let job_workers = (0..limits.max_running)
            .map(|i| {
                let core = Arc::clone(&core);
                let jobs = Arc::clone(&jobs);
                thread::Builder::new()
                    .name(format!("cluster-job-worker-{i}"))
                    .spawn(move || cluster_job_worker(&core, &jobs))
                    .expect("spawn cluster job worker")
            })
            .collect();
        Coordinator { core, jobs, job_workers }
    }

    /// The configured worker addresses (ring order).
    pub fn workers(&self) -> &[String] {
        &self.core.workers
    }

    /// A point-in-time snapshot of the `cluster_*` counters (what the
    /// `stats` request reports).
    pub fn cluster_stats(&self) -> ClusterStats {
        self.core.snapshot()
    }

    /// Answer one typed request under the default envelope. Mirrors
    /// [`crate::api::Service::handle`].
    pub fn handle(&self, req: &Request) -> Response {
        self.handle_env(req, &RequestEnvelope::default())
    }

    /// Answer one typed request honoring the envelope options. The
    /// batch contract (item count bounds, per-item fan-out, lenient
    /// per-item backend selectors) matches
    /// [`crate::api::Service::handle_env`] message for message.
    pub fn handle_env(&self, req: &Request, env: &RequestEnvelope) -> Response {
        if let Request::Batch { items } = req {
            if items.is_empty() {
                return Response::from(ApiError::bad_request(
                    "batch: \"items\" must not be empty",
                ));
            }
            if items.len() > MAX_BATCH_ITEMS {
                return Response::from(ApiError::new(
                    ErrorCode::BadRange,
                    format!(
                        "batch items must be in 1..={MAX_BATCH_ITEMS} \
                         (got {})",
                        items.len()
                    ),
                ));
            }
            return Response::Batch {
                items: items
                    .iter()
                    .map(|item| self.handle_one(item, env, false))
                    .collect(),
            };
        }
        self.handle_one(req, env, true)
    }

    /// One non-batch request: scenario-backed requests fan their points
    /// across the ring, `submit` enqueues into the coordinator's own
    /// job table, `job_*` and `stats` answer locally, and everything
    /// else proxies whole to the worker owning its cache key.
    fn handle_one(
        &self,
        req: &Request,
        env: &RequestEnvelope,
        strict_backend: bool,
    ) -> Response {
        if let Some((spec, single)) = desugar(req) {
            let resolved = match self.core.resolved_spec(&spec, env.backend) {
                Ok(s) => s,
                Err(e) => return Response::from(e),
            };
            return match self.core.run_scenario(&resolved, env.cache) {
                Ok(resp) if single => unwrap_single(resp),
                Ok(resp) => resp,
                Err(e) => Response::from(e),
            };
        }
        if let Request::Submit { spec, .. } = req {
            let resolved = match self.core.resolved_spec(spec, env.backend) {
                Ok(s) => s,
                Err(e) => return Response::from(e),
            };
            let points = match resolved.validated_points() {
                Ok(p) => p,
                Err(e) => return Response::from(e),
            };
            return match self.jobs.submit(
                resolved,
                points.len() as u64,
                false,
                env.cache,
            ) {
                Ok((view, _rx)) => Response::Job(view),
                Err(e) => Response::from(e),
            };
        }
        if strict_backend && env.backend.is_some() {
            return Response::from(ApiError::bad_request(format!(
                "\"backend\" only applies to sim/plan/sparsity/scenario/\
                 submit requests (got {:?})",
                req.type_name()
            )));
        }
        match req {
            Request::JobStatus { job } => match self.jobs.status(*job) {
                Ok(view) => Response::Job(view),
                Err(e) => Response::from(e),
            },
            Request::JobResult { job } => match self.jobs.result(*job) {
                Ok(resp) => resp,
                Err(e) => Response::from(e),
            },
            Request::JobCancel { job } => match self.jobs.cancel(*job) {
                Ok(view) => Response::Job(view),
                Err(e) => Response::from(e),
            },
            Request::Stats => self.core.aggregated_stats(),
            Request::Batch { .. } => {
                Response::from(ApiError::bad_request("batches do not nest"))
            }
            other => self.core.proxy(other, env.cache),
        }
    }

    /// Enqueue a watched submit; mirrors
    /// [`crate::api::Service::submit_watched`] (the threads io model's
    /// progress-push source).
    pub fn submit_watched(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
    ) -> (Response, Option<mpsc::Receiver<JobView>>) {
        let resolved = match self.core.resolved_spec(spec, env.backend) {
            Ok(s) => s,
            Err(e) => return (Response::from(e), None),
        };
        let points = match resolved.validated_points() {
            Ok(p) => p,
            Err(e) => return (Response::from(e), None),
        };
        match self.jobs.submit(resolved, points.len() as u64, true, env.cache)
        {
            Ok((view, rx)) => (Response::Job(view), rx),
            Err(e) => (Response::from(e), None),
        }
    }

    /// Enqueue a watched submit with a callback watcher; mirrors
    /// [`crate::api::Service::submit_watched_with`] (the epoll io
    /// model's thread-free progress push).
    pub fn submit_watched_with(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
        on_frame: Box<dyn Fn(JobView) + Send>,
    ) -> Response {
        let resolved = match self.core.resolved_spec(spec, env.backend) {
            Ok(s) => s,
            Err(e) => return Response::from(e),
        };
        let points = match resolved.validated_points() {
            Ok(p) => p,
            Err(e) => return Response::from(e),
        };
        match self.jobs.submit_with(
            resolved,
            points.len() as u64,
            Some(Watcher::Callback(on_frame)),
            env.cache,
        ) {
            Ok(view) => Response::Job(view),
            Err(e) => Response::from(e),
        }
    }
}

impl Dispatch for Coordinator {
    fn handle(&self, req: &Request) -> Response {
        Coordinator::handle(self, req)
    }

    fn handle_env(&self, req: &Request, env: &RequestEnvelope) -> Response {
        Coordinator::handle_env(self, req, env)
    }

    fn submit_watched(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
    ) -> (Response, Option<mpsc::Receiver<JobView>>) {
        Coordinator::submit_watched(self, spec, env)
    }

    fn submit_watched_with(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
        on_frame: Box<dyn Fn(JobView) + Send>,
    ) -> Response {
        Coordinator::submit_watched_with(self, spec, env, on_frame)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Stop handing out jobs; running jobs cancel between points.
        self.jobs.shutdown();
        for h in self.job_workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl ClusterCore {
    /// Resolve a spec's execution backend exactly like
    /// [`crate::api::Service`] does (same precedence, same gate, same
    /// message bytes) — resolution happens on the coordinator so the
    /// routed per-point keys name the backend explicitly and match the
    /// workers' cache keys.
    fn resolved_spec(
        &self,
        spec: &ScenarioSpec,
        envelope: Option<BackendId>,
    ) -> Result<ScenarioSpec, ApiError> {
        let id = match (spec.backend, envelope) {
            (Some(a), Some(b)) if a != b => {
                return Err(ApiError::bad_request(format!(
                    "backend requested twice and disagreeing: the spec \
                     says {:?}, the envelope says {:?}",
                    a.as_str(),
                    b.as_str()
                )))
            }
            (a, b) => a.or(b).unwrap_or(self.default_backend),
        };
        let caps = backend::get(id).capabilities();
        if !caps.supports(spec.ask, spec.shape) {
            return Err(ApiError::new(
                ErrorCode::UnsupportedByBackend,
                format!(
                    "backend {:?} does not support ask {:?} with shape \
                     {:?} (ask \"backends\" for the capability table)",
                    id.as_str(),
                    spec.ask.as_str(),
                    spec.shape.as_str()
                ),
            ));
        }
        let mut resolved = spec.clone();
        resolved.backend = Some(id);
        Ok(resolved)
    }

    /// Validate, expand, and fan a sweep's points across the ring in
    /// parallel (results merge back in expansion order, so the merged
    /// response is byte-identical to a standalone run of the same
    /// spec).
    fn run_scenario(
        &self,
        spec: &ScenarioSpec,
        use_cache: bool,
    ) -> Result<Response, ApiError> {
        let points = spec.validated_points()?;
        let results = pool::scoped_map(
            &points,
            pool::default_workers(),
            |_, p| PointResult {
                point: *p,
                result: Box::new(self.run_point_remote(spec, p, use_cache)),
            },
        );
        Ok(Response::Scenario { points: results })
    }

    /// Execute one validated point on its owning worker (falling back
    /// across replicas), unwrapping the worker's single-point scenario
    /// answer into the point's result.
    fn run_point_remote(
        &self,
        spec: &ScenarioSpec,
        p: &Point,
        use_cache: bool,
    ) -> Response {
        let mut single = spec.at(p);
        // Resolve the auto router to its concrete engine before
        // hashing (routing reads the budgets off `spec`, which `at`
        // strips from the cache form), so the routed key equals the
        // worker's cache key for the concrete backend and routed
        // points share the worker's entries with explicit requests
        // (DESIGN.md §6.10).
        if single.backend == Some(BackendId::Auto) {
            single.backend = Some(TrustTable::route(spec, p));
        }
        let req = Request::Scenario { spec: single };
        let key = req.cache_key();
        self.points_routed.fetch_add(1, Ordering::Relaxed);
        let resp = match self.route(&key, &req, use_cache) {
            Ok(resp) => resp,
            Err(e) => {
                self.point_failures.fetch_add(1, Ordering::Relaxed);
                return Response::from(e);
            }
        };
        match resp {
            Response::Scenario { mut points } if points.len() == 1 => {
                *points.remove(0).result
            }
            resp @ Response::Error { .. } => resp,
            other => {
                self.point_failures.fetch_add(1, Ordering::Relaxed);
                Response::from(ApiError::new(
                    ErrorCode::Runtime,
                    format!(
                        "worker answered {:?} to a single-point scenario \
                         request",
                        other.type_name()
                    ),
                ))
            }
        }
    }

    /// Forward a non-scenario request whole to the worker owning its
    /// cache key (so request-level cache entries stay per-worker),
    /// walking replicas on failure like a point does.
    fn proxy(&self, req: &Request, use_cache: bool) -> Response {
        self.proxied.fetch_add(1, Ordering::Relaxed);
        match self.route(&req.cache_key(), req, use_cache) {
            Ok(resp) => resp,
            Err(e) => Response::from(e),
        }
    }

    /// Deliver `req` to the first answering worker in `key`'s replica
    /// order. Transport failures and typed `overloaded` answers move to
    /// the next replica (counting a retry); any other answer — success
    /// or typed error — is final. After [`ROUTE_ROUNDS`] full walks
    /// with doubling backoff between rounds, the delivery fails with a
    /// typed `runtime` error naming the last per-worker failure.
    fn route(
        &self,
        key: &str,
        req: &Request,
        use_cache: bool,
    ) -> Result<Response, ApiError> {
        let order = self.ring.replicas(key);
        let mut wait = self.retry.backoff;
        let mut last = String::from("no delivery attempted");
        for round in 0..ROUTE_ROUNDS {
            for (i, &w) in order.iter().enumerate() {
                if round > 0 || i > 0 {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                }
                match self.call_worker(w, req, use_cache) {
                    Ok(Response::Error {
                        code: ErrorCode::Overloaded,
                        message,
                    }) => {
                        last = format!(
                            "worker {}: overloaded: {message}",
                            self.workers[w]
                        );
                    }
                    Ok(resp) => return Ok(resp),
                    Err(e) => {
                        last = format!("worker {}: {e}", self.workers[w]);
                    }
                }
            }
            if round + 1 < ROUTE_ROUNDS {
                thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_millis(250));
            }
        }
        Err(ApiError::new(
            ErrorCode::Runtime,
            format!(
                "all {} workers failed to answer after {ROUTE_ROUNDS} \
                 rounds (last: {last})",
                self.workers.len()
            ),
        ))
    }

    /// One request/response round against worker `w` over a fresh
    /// connection, with the inter-node `overloaded` retry policy
    /// enabled (same-worker retries happen inside the client; replica
    /// fallback happens in [`ClusterCore::route`]).
    fn call_worker(
        &self,
        w: usize,
        req: &Request,
        use_cache: bool,
    ) -> std::io::Result<Response> {
        let mut c = Client::connect(self.workers[w].as_str())?;
        c.set_overloaded_retry(Some(self.retry));
        c.request_env(
            req,
            &RequestEnvelope { cache: use_cache, ..RequestEnvelope::default() },
        )
    }

    /// The coordinator's `stats` answer: best-effort sums of every
    /// *reachable* worker's cache and execution counters (an
    /// unreachable worker is skipped, not an error — `stats` must work
    /// mid-outage), plus the coordinator-only `cluster_*` block.
    /// `cache_enabled` reports whether every reachable worker has its
    /// cache on; the caps are summed (total cluster capacity). Workers'
    /// own nested `cluster` blocks (a coordinator fronting
    /// coordinators) are not aggregated.
    fn aggregated_stats(&self) -> Response {
        let mut cache = CacheStats { enabled: true, ..CacheStats::default() };
        let mut engine_runs = 0u64;
        let mut backend_runs = vec![0u64; backend::COUNT];
        let mut reachable = 0usize;
        for w in 0..self.workers.len() {
            let resp = match self.call_worker(w, &Request::Stats, true) {
                Ok(resp) => resp,
                Err(_) => continue,
            };
            if let Response::Stats {
                cache: c,
                engine_runs: runs,
                backend_runs: per,
                ..
            } = resp
            {
                reachable += 1;
                cache.hits += c.hits;
                cache.misses += c.misses;
                cache.evictions += c.evictions;
                cache.entries += c.entries;
                cache.bytes += c.bytes;
                cache.max_entries += c.max_entries;
                cache.max_bytes += c.max_bytes;
                cache.enabled &= c.enabled;
                engine_runs += runs;
                for (i, v) in per.into_iter().enumerate() {
                    if i < backend_runs.len() {
                        backend_runs[i] += v;
                    } else {
                        backend_runs.push(v);
                    }
                }
            }
        }
        if reachable == 0 {
            cache.enabled = false;
        }
        Response::Stats {
            cache,
            engine_runs,
            backend_runs,
            cluster: Some(self.snapshot()),
        }
    }

    /// The `cluster_*` counter snapshot.
    fn snapshot(&self) -> ClusterStats {
        ClusterStats {
            workers: self.workers.len() as u64,
            points_routed: self.points_routed.load(Ordering::Relaxed),
            proxied: self.proxied.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            point_failures: self.point_failures.load(Ordering::Relaxed),
        }
    }
}

/// A cluster job worker: identical loop shape to the standalone
/// service's job worker — pull queued jobs, run points sequentially
/// (the progress/cancel granularity), frame watchers via the table —
/// but each point executes remotely through the routed path.
fn cluster_job_worker(core: &ClusterCore, jobs: &JobTable) {
    while let Some((id, spec, use_cache)) = jobs.next_job() {
        let points = spec.expand();
        let mut results = Vec::with_capacity(points.len());
        for p in &points {
            if !jobs.should_continue(id) {
                break;
            }
            let resp = core.run_point_remote(&spec, p, use_cache);
            results.push(PointResult { point: *p, result: Box::new(resp) });
            if !jobs.point_done(id) {
                break;
            }
        }
        if results.len() == points.len() {
            refine_job_remote(core, jobs, id, &spec, &mut results, use_cache);
            jobs.finish(id, Ok(Response::Scenario { points: results }));
        } else {
            // A cancel (or shutdown) was honored mid-sweep.
            jobs.mark_cancelled(id);
        }
    }
}

/// The refinement pass of a budgeted `auto` cluster job — the
/// coordinator-side mirror of the service's `refine_job` (DESIGN.md
/// §6.10): the same trust-table selection and ascending-confidence
/// order, with each DES re-run delivered through the routed path, so a
/// refined point lands on the ring owner of its des-resolved key and
/// warms that worker's cache exactly like an explicit `des` request.
fn refine_job_remote(
    core: &ClusterCore,
    jobs: &JobTable,
    id: u64,
    spec: &ScenarioSpec,
    results: &mut [PointResult],
    use_cache: bool,
) {
    if spec.backend != Some(BackendId::Auto)
        || (spec.max_error.is_none() && spec.max_time_ms.is_none())
    {
        return;
    }
    let mut todo: Vec<usize> = (0..results.len())
        .filter(|&i| {
            TrustTable::wants_refinement(spec, &results[i].point)
        })
        .collect();
    todo.sort_by(|&a, &b| {
        TrustTable::confidence(spec, &results[a].point)
            .partial_cmp(&TrustTable::confidence(spec, &results[b].point))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let started = std::time::Instant::now();
    let mut des = spec.clone();
    des.backend = Some(BackendId::Des);
    for i in todo {
        if !jobs.should_continue(id) {
            return;
        }
        if let Some(budget) = spec.max_time_ms {
            if started.elapsed().as_secs_f64() * 1000.0 >= budget {
                return;
            }
        }
        let p = results[i].point;
        results[i].result =
            Box::new(core.run_point_remote(&des, &p, use_cache));
        if !jobs.point_refined(id) {
            return;
        }
    }
}

/// The scenario-backed request kinds and their single-point unwrap
/// flag — the coordinator desugars exactly like the standalone
/// service, so v1 requests answer in their v1 shape.
fn desugar(req: &Request) -> Option<(ScenarioSpec, bool)> {
    match req {
        Request::Sim { n, precision, streams } => {
            Some((ScenarioSpec::sim(*n, *precision, *streams), true))
        }
        Request::Plan { objective, streams, n, precision } => Some((
            ScenarioSpec::plan(*objective, *streams, *n, *precision),
            true,
        )),
        Request::Sparsity { n, streams } => {
            Some((ScenarioSpec::sparsity_question(*n, *streams), true))
        }
        Request::Scenario { spec } => Some((spec.clone(), false)),
        _ => None,
    }
}

/// Unwrap a single-point scenario response back into its v1 shape.
fn unwrap_single(resp: Response) -> Response {
    match resp {
        Response::Scenario { mut points } if points.len() == 1 => {
            *points.remove(0).result
        }
        other => other,
    }
}

/// Serve a coordinator on `addr` over `workers` (the CLI's
/// `serve --coordinator --workers a,b,...`): bind, print the bound
/// address on stdout (callers/tests discover the ephemeral port), and
/// run the shared accept machinery under `io`. Returns after
/// `max_conns` connections have been accepted and fully served
/// (None = forever).
pub fn serve_cluster(
    addr: &str,
    workers: Vec<String>,
    max_conns: Option<usize>,
    default_backend: BackendId,
    io: IoModel,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("serving on {}", listener.local_addr()?);
    let coord = Arc::new(Coordinator::new(workers, default_backend));
    serve_on(listener, coord, max_conns, io)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coordinator_rejects_misplaced_backend_like_a_service() {
        // No worker is ever contacted: the strict check fires first.
        let coord = Coordinator::new(
            vec!["127.0.0.1:1".into()],
            backend::DEFAULT,
        );
        let env = RequestEnvelope {
            backend: Some(BackendId::Analytic),
            ..RequestEnvelope::default()
        };
        match coord.handle_env(&Request::Config, &env) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("only applies"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn empty_and_oversized_batches_mirror_the_service_messages() {
        let coord = Coordinator::new(
            vec!["127.0.0.1:1".into()],
            backend::DEFAULT,
        );
        match coord.handle(&Request::Batch { items: vec![] }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("must not be empty"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
        let items = vec![Request::Config; MAX_BATCH_ITEMS + 1];
        match coord.handle(&Request::Batch { items }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::BadRange)
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn job_requests_answer_locally_without_workers() {
        let coord = Coordinator::new(
            vec!["127.0.0.1:1".into()],
            backend::DEFAULT,
        );
        match coord.handle(&Request::JobStatus { job: 42 }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::UnknownJob)
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn unreachable_workers_fail_points_with_a_typed_runtime_error() {
        // Port 1 refuses connections; the routed point must exhaust its
        // replicas and answer a typed error, and the counters must
        // record the failure.
        let coord = Coordinator::new(
            vec!["127.0.0.1:1".into()],
            backend::DEFAULT,
        );
        let req = Request::Sim {
            n: 256,
            precision: crate::isa::Precision::Fp8,
            streams: 2,
        };
        match coord.handle(&req) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::Runtime);
                assert!(message.contains("workers failed"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
        let stats = coord.cluster_stats();
        assert_eq!(stats.workers, 1);
        assert_eq!(stats.points_routed, 1);
        assert_eq!(stats.point_failures, 1);
        assert!(stats.retries >= 1, "replica walk counted no retries");
    }
}
