//! Consistent-hash ring over a static worker set (DESIGN.md §6.9).
//!
//! The coordinator routes every sweep point by the FNV-1a hash of its
//! canonical per-point cache key — the *same* key and the *same* hash
//! the workers' result caches shard on ([`crate::api::cache`]) — so a
//! point lands on the same worker every time and repeats hit that
//! worker's warm cache. Each worker owns [`Ring::VNODES`] virtual
//! nodes, which spreads a 256-point sweep close to evenly across even a
//! two-worker set; the successor walk ([`Ring::replicas`]) yields every
//! worker exactly once in a key-deterministic preference order, which
//! is the retry path when the owner is dead or overloaded.

use crate::api::cache::fnv1a;

/// An immutable consistent-hash ring over `workers` indexes
/// (`0..workers`). Built once at coordinator startup; the worker set is
/// static for the instance's lifetime (docs/cluster.md).
#[derive(Debug, Clone)]
pub struct Ring {
    /// `(hash, worker)` pairs sorted by hash — the ring positions.
    points: Vec<(u64, usize)>,
    workers: usize,
}

impl Ring {
    /// Virtual nodes per worker: enough that a maximum-size
    /// ([`crate::api::MAX_SWEEP_POINTS`]-point) sweep splits
    /// near-evenly across small worker sets.
    pub const VNODES: usize = 128;

    /// Ring over `workers` members with [`Ring::VNODES`] virtual nodes
    /// each. `workers` must be at least 1.
    pub fn new(workers: usize) -> Ring {
        Ring::with_vnodes(workers, Ring::VNODES)
    }

    /// [`Ring::new`] with an explicit virtual-node count (tests shrink
    /// it to make collisions and skew observable).
    pub fn with_vnodes(workers: usize, vnodes: usize) -> Ring {
        assert!(workers >= 1, "a ring needs at least one worker");
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(workers * vnodes);
        for w in 0..workers {
            for v in 0..vnodes {
                points.push((fnv1a(&format!("worker-{w}#vnode-{v}")), w));
            }
        }
        // Ties (identical hashes across workers) break by worker index
        // so the ring order is fully deterministic.
        points.sort();
        Ring { points, workers }
    }

    /// The number of ring members.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker owning `key`: the first ring position at or after the
    /// key's hash, wrapping at the top.
    pub fn owner(&self, key: &str) -> usize {
        let h = fnv1a(key);
        let i = self.points.partition_point(|&(p, _)| p < h);
        self.points[i % self.points.len()].1
    }

    /// Every worker exactly once, in the key's successor order around
    /// the ring: `replicas(key)[0]` is [`Ring::owner`], the rest are
    /// the deterministic fallback sequence the coordinator walks when
    /// earlier replicas are unreachable or overloaded.
    pub fn replicas(&self, key: &str) -> Vec<usize> {
        let h = fnv1a(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.workers];
        let mut order = Vec::with_capacity(self.workers);
        for i in 0..self.points.len() {
            let w = self.points[(start + i) % self.points.len()].1;
            if !seen[w] {
                seen[w] = true;
                order.push(w);
                if order.len() == self.workers {
                    break;
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic() {
        let a = Ring::new(3);
        let b = Ring::new(3);
        for k in 0..64 {
            let key = format!("key-{k}");
            assert_eq!(a.owner(&key), b.owner(&key));
            assert_eq!(a.replicas(&key), b.replicas(&key));
        }
    }

    #[test]
    fn owner_heads_the_replica_order() {
        let ring = Ring::new(4);
        for k in 0..64 {
            let key = format!("key-{k}");
            let reps = ring.replicas(&key);
            assert_eq!(reps[0], ring.owner(&key));
        }
    }

    #[test]
    fn replicas_cover_every_worker_exactly_once() {
        for workers in 1..=5 {
            let ring = Ring::new(workers);
            let mut reps = ring.replicas("some-key");
            assert_eq!(reps.len(), workers);
            reps.sort();
            assert_eq!(reps, (0..workers).collect::<Vec<_>>());
        }
    }

    #[test]
    fn two_worker_split_is_roughly_even() {
        // The acceptance bar for a 256-point sweep over 2 workers is
        // >= 64 points (a quarter) each; hold the ring to that bound
        // over a larger key population so the sweep case has margin.
        let ring = Ring::new(2);
        let mut counts = [0usize; 2];
        for k in 0..1024 {
            counts[ring.owner(&format!("key-{k}"))] += 1;
        }
        for (w, &c) in counts.iter().enumerate() {
            assert!(
                c >= 256,
                "worker {w} owns only {c}/1024 keys — ring is skewed"
            );
        }
    }

    #[test]
    fn few_vnodes_still_cover_all_workers() {
        let ring = Ring::with_vnodes(3, 1);
        let mut reps = ring.replicas("k");
        reps.sort();
        assert_eq!(reps, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn empty_ring_is_refused() {
        let _ = Ring::new(0);
    }
}
