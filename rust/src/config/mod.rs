//! Configuration system: hardware topology, simulator calibration
//! constants, and experiment parameters.
//!
//! Configs load from TOML (subset, see [`toml`]) or JSON files and can be
//! overridden field-by-field from the CLI. `Config::default()` is the
//! calibrated MI300A model (paper Table 1 topology + DESIGN.md §7
//! calibration policy); every constant is documented with the paper
//! artifact it anchors.

pub mod toml;

use crate::isa::Precision;
use crate::util::json::Json;
use std::path::Path;

/// Declares a config struct whose fields can be read from / written to a
/// JSON object (which the TOML loader also produces). Keeps the loader
/// code in one place instead of 60 hand-written accessors.
macro_rules! config_struct {
    ($(#[$meta:meta])* pub struct $name:ident { $($(#[$fm:meta])* pub $field:ident : f64 = $default:expr,)* }) => {
        $(#[$meta])*
        #[derive(Debug, Clone, PartialEq)]
        pub struct $name {
            $($(#[$fm])* pub $field: f64,)*
        }

        impl Default for $name {
            fn default() -> Self {
                Self { $($field: $default,)* }
            }
        }

        impl $name {
            /// Overlay fields present in a JSON object onto `self`.
            pub fn apply_json(&mut self, v: &Json) {
                $(if let Some(x) = v.get(stringify!($field)).and_then(|j| j.as_f64()) {
                    self.$field = x;
                })*
            }

            /// Dump all fields as a JSON object.
            pub fn to_json(&self) -> Json {
                Json::obj(vec![
                    $((stringify!($field), Json::Num(self.$field)),)*
                ])
            }

            /// Set one field by name (CLI `--set section.field=value`).
            pub fn set_field(&mut self, name: &str, value: f64) -> bool {
                match name {
                    $(stringify!($field) => { self.$field = value; true })*
                    _ => false,
                }
            }
        }
    };
}

config_struct! {
    /// Physical topology of the modelled APU (paper §2, Fig 1, Table 1).
    pub struct HardwareConfig {
        /// GPU compute dies.
        pub xcds: f64 = 6.0,
        /// Compute units per XCD ("each XCD containing 40 compute units").
        pub cus_per_xcd: f64 = 40.0,
        /// MFMA matrix engines per CU.
        pub mfma_per_cu: f64 = 4.0,
        /// Local data share per CU, KiB.
        pub lds_kib_per_cu: f64 = 64.0,
        /// L2 cache per XCD, MiB.
        pub l2_mib_per_xcd: f64 = 4.0,
        /// Shared HBM3 capacity, GiB.
        pub hbm_gib: f64 = 128.0,
        /// Peak HBM bandwidth, TB/s.
        pub hbm_tbps: f64 = 5.3,
        /// Engine clock, GHz.
        pub clock_ghz: f64 = 2.1,
        /// Architectural max wavefronts resident per CU.
        pub max_waves_per_cu: f64 = 32.0,
        /// Hardware asynchronous compute engines (command processors).
        pub n_aces: f64 = 8.0,
    }
}

config_struct! {
    /// Calibration constants for the execution-cost model (DESIGN.md §7).
    ///
    /// `issue_eff_*`: effective independent MFMA chains per wavefront in
    /// the paper's Fig-2 microbenchmark (per-instruction interval =
    /// Table-3 latency / issue_eff). Calibrated so the 256-wavefront
    /// normalized throughput matches Fig 2 (FP8 13.7%, FP64 12.1%,
    /// FP32 10.4%).
    pub struct CalibConfig {
        pub issue_eff_fp8: f64 = 1.576,
        pub issue_eff_bf8: f64 = 1.55,
        pub issue_eff_f16: f64 = 6.30,
        pub issue_eff_bf16: f64 = 6.15,
        pub issue_eff_f32: f64 = 0.955,
        pub issue_eff_f64: f64 = 0.942,
        /// Fraction of MFMA operand bytes streamed from HBM in the
        /// microbenchmark (operands are mostly register/LDS resident);
        /// produces the sublinear bend of Fig 2 at high wavefront counts.
        pub mb_stream_fraction: f64 = 0.08,
        /// Aspect-ratio sensitivity (Fig 3): relative throughput loss at
        /// 4:1 vs 1:1 for FP8 (worst case, 16%) — other precisions scale
        /// by their tile skew.
        pub shape_penalty_fp8: f64 = 0.16,
        pub shape_penalty_f32: f64 = 0.03,
        /// GEMM block tile (square) used by the stream-level GEMM model.
        pub gemm_block_tile: f64 = 128.0,
        /// Latency-hiding half-point: wavefronts per CU at which memory
        /// latency is half hidden (Fig 2 occupancy threshold behaviour).
        pub hide_half_waves: f64 = 4.0,
        /// Concurrency utilization boost exponent (Fig 4): aggregate
        /// throughput ~ streams^boost until contention caps it.
        pub conc_boost: f64 = 0.84,
        /// Contention cap: effective machine share at saturation.
        pub conc_sat_streams: f64 = 10.0,
        /// Per-stream jitter (lognormal sigma) at 1 stream.
        pub jitter_base: f64 = 0.015,
        /// Additional jitter per unit of contention pressure (drives the
        /// fairness collapse of Fig 5a at 8 streams).
        pub jitter_contention: f64 = 0.062,
        /// Precision-relative contention sensitivity (FP16 worst at 8
        /// streams: fairness 0.016 vs FP8 0.138 — paper §6.1).
        pub jitter_scale_f16: f64 = 1.22,
        pub jitter_scale_f32: f64 = 1.13,
        pub jitter_scale_fp8: f64 = 0.80,
        /// L2 miss-ratio anchors (Fig 6, isolated): thin/medium/thick.
        pub l2_miss_thin: f64 = 0.05,
        pub l2_miss_medium: f64 = 0.15,
        pub l2_miss_thick: f64 = 0.35,
        /// Relative L2 miss growth per added concurrent stream (Fig 6:
        /// thin kernels +24% relative at 4 streams).
        pub l2_miss_stream_slope: f64 = 0.08,
        /// L2 miss penalty in ns (exposed portion per missed line).
        pub l2_miss_penalty_ns: f64 = 350.0,
        /// LDS staging bytes per wavefront for the GEMM kernels, as a
        /// multiple of the block-tile operand footprint (double buffer).
        pub lds_double_buffer: f64 = 2.0,
        /// Occupancy-fragmentation share exponent (Fig 9): CU share of a
        /// kernel ~ wavefronts^gamma (proportional allocation, §6.3).
        pub frag_share_gamma: f64 = 1.0,
        /// Idle-resource exploitation: throughput bonus a large kernel
        /// extracts when co-running with a much smaller one (Fig 9a).
        pub frag_boost: f64 = 1.35,
    }
}

config_struct! {
    /// rocSPARSE-like API overhead model (paper §7.1.1, Fig 10).
    pub struct SparsityConfig {
        /// Dense->compressed format conversion, µs.
        pub format_conversion_us: f64 = 2.0,
        /// Metadata buffer allocation, µs.
        pub metadata_alloc_us: f64 = 1.0,
        /// Kernel dispatch through the sparse API, µs.
        pub dispatch_us: f64 = 0.7,
        /// Additional overhead when BOTH sides are sparse, µs
        /// (second conversion + merged metadata; total 5.3-5.8 µs).
        pub both_side_extra_us: f64 = 1.8,
        /// Run-to-run overhead spread (uniform +- µs, Fig 10's
        /// 3.5-3.9 µs band).
        pub overhead_spread_us: f64 = 0.2,
        /// Compute fraction retained by 2:4 sparsity (50% FLOPs) — the
        /// hardware capability.
        pub flop_fraction: f64 = 0.5,
        /// FLOP fraction the rocSPARSE software path actually executes.
        /// The paper's central sparsity finding is that this is ~1.0
        /// ("sparsity is software-limited, not hardware-limited", §9.1):
        /// the vendor path does dense-equivalent math plus overhead.
        /// Custom kernels would set this toward `flop_fraction`.
        pub realized_flop_fraction: f64 = 1.0,
        /// Dense rocBLAS-path API/launch overhead per GEMM call, µs —
        /// the common cost both dense and sparse paths pay. Calibrated
        /// from the paper's own §7 baseline (59.98 GFLOPS at 512^3 =>
        /// ms-scale per-call time, far above raw compute).
        pub dense_api_launch_us: f64 = 4400.0,
        /// Dense-path penalty on strongly rectangular shapes (the §7.1.2
        /// exception: rocSPARSE's decompress path streams skewed shapes
        /// better, so sparse wins 1.6-1.76x there).
        pub rect_dense_penalty: f64 = 1.68,
        /// Memory-traffic fraction of the sparse path (values halve, but
        /// metadata adds 2 bits per element pair).
        pub mem_fraction: f64 = 0.5625,
        /// Throughput efficiency of the sparse pipeline relative to dense
        /// at equal FLOPs (sparse MFMA issue overhead).
        pub sparse_pipe_eff: f64 = 0.87,
        /// Rectangular-shape overlap bonus (paper §7.1.2: 512x2048x1024
        /// reaches 1.6-1.76x): fraction of overhead + memory hidden for
        /// strongly non-square shapes.
        pub rect_overlap_bonus: f64 = 0.72,
    }
}

/// Top-level configuration bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub hw: HardwareConfig,
    pub calib: CalibConfig,
    pub sparsity: SparsityConfig,
    /// Master RNG seed for all stochastic simulator elements.
    pub seed: u64,
}

impl Config {
    /// The calibrated MI300A model.
    pub fn mi300a() -> Config {
        Config::default()
    }

    /// Total compute units (240 on the paper's Fig-1 topology).
    pub fn total_cus(&self) -> usize {
        (self.hw.xcds * self.hw.cus_per_xcd) as usize
    }

    /// Total L2 bytes across XCDs.
    pub fn l2_bytes(&self) -> f64 {
        self.hw.xcds * self.hw.l2_mib_per_xcd * 1024.0 * 1024.0
    }

    /// LDS bytes per CU.
    pub fn lds_bytes_per_cu(&self) -> f64 {
        self.hw.lds_kib_per_cu * 1024.0
    }

    /// HBM bandwidth in bytes/ns (== GB/s * 1e-9 * 1e9).
    pub fn hbm_bytes_per_ns(&self) -> f64 {
        self.hw.hbm_tbps * 1e12 / 1e9
    }

    /// Load from a `.toml` or `.json` file and overlay onto defaults.
    pub fn load(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let v = if path.extension().map(|e| e == "json").unwrap_or(false) {
            Json::parse(&text).map_err(|e| e.to_string())?
        } else {
            toml::parse(&text).map_err(|e| e.to_string())?
        };
        let mut cfg = Config::default();
        cfg.apply_json(&v);
        Ok(cfg)
    }

    /// Overlay a JSON/TOML value tree onto this config.
    pub fn apply_json(&mut self, v: &Json) {
        if let Some(hw) = v.get("hardware") {
            self.hw.apply_json(hw);
        }
        if let Some(c) = v.get("calibration") {
            self.calib.apply_json(c);
        }
        if let Some(s) = v.get("sparsity") {
            self.sparsity.apply_json(s);
        }
        if let Some(seed) = v.get("seed").and_then(|j| j.as_f64()) {
            self.seed = seed as u64;
        }
    }

    /// Apply a `section.field=value` override (CLI `--set`).
    pub fn set(&mut self, spec: &str) -> Result<(), String> {
        let (path, val) = spec
            .split_once('=')
            .ok_or_else(|| format!("--set wants section.field=value, got {spec:?}"))?;
        if path == "seed" {
            self.seed = val.parse().map_err(|_| format!("bad seed {val:?}"))?;
            return Ok(());
        }
        let value: f64 = val.parse().map_err(|_| format!("bad value {val:?}"))?;
        let (section, field) = path
            .split_once('.')
            .ok_or_else(|| format!("--set wants section.field=value, got {spec:?}"))?;
        let ok = match section {
            "hardware" | "hw" => self.hw.set_field(field, value),
            "calibration" | "calib" => self.calib.set_field(field, value),
            "sparsity" => self.sparsity.set_field(field, value),
            _ => false,
        };
        if ok {
            Ok(())
        } else {
            Err(format!("unknown config field {path:?}"))
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hardware", self.hw.to_json()),
            ("calibration", self.calib.to_json()),
            ("sparsity", self.sparsity.to_json()),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    /// issue_eff lookup per precision (see CalibConfig docs).
    pub fn issue_eff(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp8 => self.calib.issue_eff_fp8,
            Precision::Bf8 => self.calib.issue_eff_bf8,
            Precision::F16 => self.calib.issue_eff_f16,
            Precision::Bf16 => self.calib.issue_eff_bf16,
            Precision::F32 => self.calib.issue_eff_f32,
            Precision::F64 => self.calib.issue_eff_f64,
        }
    }

    /// Precision-relative contention-jitter scale (paper §6.1: FP16
    /// degrades worst at 8 streams, FP8 least).
    pub fn jitter_scale(&self, p: Precision) -> f64 {
        match p {
            Precision::F16 | Precision::Bf16 => self.calib.jitter_scale_f16,
            Precision::F32 | Precision::F64 => self.calib.jitter_scale_f32,
            Precision::Fp8 | Precision::Bf8 => self.calib.jitter_scale_fp8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_topology_matches_paper() {
        let c = Config::mi300a();
        assert_eq!(c.total_cus(), 240); // 6 XCDs x 40 CUs (paper Fig 1)
        assert_eq!(c.hw.mfma_per_cu, 4.0);
        assert_eq!(c.l2_bytes(), 6.0 * 4.0 * 1024.0 * 1024.0);
    }

    #[test]
    fn toml_overlay() {
        let src = r#"
seed = 99
[hardware]
xcds = 2
cus_per_xcd = 10
[calibration]
issue_eff_fp8 = 2.0
[sparsity]
dispatch_us = 1.5
"#;
        let v = toml::parse(src).unwrap();
        let mut c = Config::default();
        c.apply_json(&v);
        assert_eq!(c.total_cus(), 20);
        assert_eq!(c.seed, 99);
        assert_eq!(c.calib.issue_eff_fp8, 2.0);
        assert_eq!(c.sparsity.dispatch_us, 1.5);
        // Untouched fields keep defaults.
        assert_eq!(c.hw.mfma_per_cu, 4.0);
    }

    #[test]
    fn set_override() {
        let mut c = Config::default();
        c.set("hardware.xcds=3").unwrap();
        c.set("calib.jitter_base=0.5").unwrap();
        c.set("seed=7").unwrap();
        assert_eq!(c.hw.xcds, 3.0);
        assert_eq!(c.calib.jitter_base, 0.5);
        assert_eq!(c.seed, 7);
        assert!(c.set("nope.x=1").is_err());
        assert!(c.set("hardware.nope=1").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = Config::default();
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.hw.xcds = 0.0; // perturb
        c2.apply_json(&j);
        assert_eq!(c, c2);
    }

    #[test]
    fn issue_eff_covers_all_precisions() {
        let c = Config::default();
        for p in Precision::SWEEP {
            assert!(c.issue_eff(p) > 0.0);
        }
    }
}
