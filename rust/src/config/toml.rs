//! Minimal TOML-subset parser for config files (offline build: no `toml`
//! crate). Supports: `[section]` / `[section.sub]` headers, `key = value`
//! with string / integer / float / bool / flat-array values, and `#`
//! comments. Produces a [`Json`] object tree so the config layer has a
//! single value representation.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse TOML text into a nested JSON object.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(head) = line.strip_prefix('[') {
            let head = head.strip_suffix(']').ok_or(TomlError {
                line: ln + 1,
                msg: "unterminated section header".into(),
            })?;
            section = head.split('.').map(|s| s.trim().to_string()).collect();
            ensure_section(&mut root, &section, ln + 1)?;
        } else if let Some((k, v)) = line.split_once('=') {
            let key = k.trim().to_string();
            let val = parse_value(v.trim(), ln + 1)?;
            insert(&mut root, &section, key, val, ln + 1)?;
        } else {
            return Err(TomlError {
                line: ln + 1,
                msg: format!("expected key = value, got {line:?}"),
            });
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Json, TomlError> {
    let err = |msg: String| TomlError { line, msg };
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(Json::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?;
        let mut items = Vec::new();
        if !body.trim().is_empty() {
            for item in body.split(',') {
                items.push(parse_value(item.trim(), line)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err(format!("cannot parse value {s:?}")))
}

fn ensure_section(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    line: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => {
                return Err(TomlError {
                    line,
                    msg: format!("section {part:?} collides with a value"),
                })
            }
        };
    }
    Ok(())
}

fn insert(
    root: &mut BTreeMap<String, Json>,
    section: &[String],
    key: String,
    val: Json,
    line: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for part in section {
        cur = match cur.get_mut(part) {
            Some(Json::Obj(m)) => m,
            _ => {
                return Err(TomlError {
                    line,
                    msg: format!("missing section {part:?}"),
                })
            }
        };
    }
    cur.insert(key, val);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_arrays() {
        let src = r#"
# MI300A hardware model
[hardware]
xcds = 6
cus_per_xcd = 40
clock_ghz = 2.1
name = "mi300a"   # inline comment
enabled = true
peaks = [122_600, 980_600]

[sim.jitter]
sigma = 0.05
"#;
        let v = parse(src).unwrap();
        let hw = v.get("hardware").unwrap();
        assert_eq!(hw.get("xcds").unwrap().as_f64(), Some(6.0));
        assert_eq!(hw.get("clock_ghz").unwrap().as_f64(), Some(2.1));
        assert_eq!(hw.get("name").unwrap().as_str(), Some("mi300a"));
        assert_eq!(hw.get("enabled"), Some(&Json::Bool(true)));
        assert_eq!(
            hw.get("peaks").unwrap().as_arr().unwrap()[1].as_f64(),
            Some(980_600.0)
        );
        assert_eq!(
            v.get("sim").unwrap().get("jitter").unwrap().get("sigma")
                .unwrap().as_f64(),
            Some(0.05)
        );
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @bad").is_err());
    }

    #[test]
    fn empty_and_comment_only_ok() {
        assert_eq!(parse("# nothing\n\n").unwrap(), Json::Obj(Default::default()));
    }
}
