//! # mi300a-char
//!
//! Execution-centric characterization of FP8 matrix cores, asynchronous
//! execution, and structured sparsity on an MI300A-class APU —
//! a full reproduction of Jarmusch, Vitz & Chandrasekaran (CS.DC 2026)
//! on a simulated substrate (DESIGN.md documents the substitution).
//!
//! Layers:
//! * [`isa`], [`hw`], [`sim`] — the simulated MI300A: MFMA opcodes with
//!   the paper's measured Table-3 latencies, CU/LDS/L2/HBM models, and a
//!   processor-sharing DES for ACE concurrency.
//! * [`sparsity`] — 2:4 structured sparsity encoding + the rocSPARSE-like
//!   API overhead model.
//! * [`metrics`] — fairness, overlap efficiency, CV (paper §4.2).
//! * [`workload`] — GEMM / transformer / mixed-precision generators.
//! * [`coordinator`] — the execution-aware runtime the paper's §9 calls
//!   for: occupancy-aware batching, concurrency governance,
//!   context-dependent sparsity, precision-aware co-scheduling.
//! * [`runtime`] — PJRT executor for the AOT'd JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); the only real-compute path.
//! * [`experiments`] — one driver per paper figure/table, indexed by a
//!   registry (DESIGN.md §5).
//! * [`api`] — the typed, versioned request/response surface
//!   (DESIGN.md §6); the CLI and the TCP serve loop are thin transports
//!   over its [`api::Service`].
//! * [`backend`] — pluggable execution backends behind the service
//!   (DESIGN.md §6.8): the `des` replay engine and the `analytic`
//!   closed-form fast path, registered for wire-level selection and
//!   discovery.
//! * [`serve`], [`loadgen`] — the TCP transport (two io models: an
//!   epoll reactor and thread-per-connection) and its built-in
//!   closed-loop load generator (`BENCH_serve.json`,
//!   docs/performance.md).
//! * [`cluster`] — coordinator/worker scale-out (DESIGN.md §6.9):
//!   a coordinator speaks the same v1 protocol and consistent-hashes
//!   sweep points across a static worker set over [`api::Client`]
//!   connections (docs/cluster.md).
//! * [`fabric`] — the multi-APU Infinity Fabric model (DESIGN.md
//!   §6.11): link topology, calibrated latency/bandwidth costs,
//!   contention accounting, and the compute/communication overlap
//!   composition behind `device_set` scenarios (docs/multi_apu.md).
//! * [`replay`] — trace replay (DESIGN.md §6.12): recorded
//!   kernel-launch timelines as a first-class `trace` scenario shape,
//!   an issue-time-honoring DES, and sweepable what-if transforms
//!   (docs/replay.md).

pub mod api;
pub mod backend;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fabric;
pub mod hw;
pub mod isa;
pub mod loadgen;
pub mod metrics;
pub mod replay;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sparsity;
pub mod util;
pub mod workload;
