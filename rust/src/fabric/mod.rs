//! Inter-APU Infinity Fabric model (DESIGN.md §6.11).
//!
//! The paper characterizes one MI300A; production MI300A nodes are four
//! APUs on Infinity Fabric (xGMI). This subsystem models that node
//! level: a [`Topology`] over 1–4 devices, a per-link latency/bandwidth
//! cost model calibrated against the PAPERS.md deep-dive ("Inter-APU
//! Communication on AMD MI300A Systems via Infinity Fabric"), and
//! contention accounting over two resource classes — **directed links**
//! (one per ordered device pair in `fully_connected`, ring edges in
//! `ring`) and **egress ports** (each APU's aggregate outbound fabric
//! bandwidth is capped at one link's worth, which is what makes a
//! direct all-to-all exchange serialize per sender).
//!
//! Two consumers, one calibration:
//!
//! * the **analytic** backend evaluates the closed-form link-saturation
//!   formulas here ([`Fabric::allreduce_ns`], [`Fabric::stage_ns`],
//!   [`Fabric::halo_ns`]) — `time = step latency + saturated-resource
//!   bytes / link bandwidth`;
//! * the **DES** backend steps the same transfer schedules as
//!   first-class events through [`crate::sim::fabric::FabricSim`]
//!   (processor sharing over links + egress ports, mirroring the
//!   engine's ACE machinery). On the uniform collective schedules the
//!   two agree exactly, so the DES/analytic equivalence gap on
//!   multi-device points comes from the *compute* estimate alone.
//!
//! The compute/communication overlap composition shared by both
//! backends lives in [`compose`]: per-round exchanges are
//! double-buffered against the next round's compute (the same
//! async-queue overlap story the ACE profile models for kernels), and
//! pipeline stage relays fill and drain like a classic linear pipeline.

use crate::api::scenario::Shape;

/// Devices per node: MI300A ships in quad-APU nodes, and the
/// calibration source only anchors up to four endpoints.
pub const MAX_DEVICES: usize = 4;

/// Accepted `device_set.devices` range (shared with scenario
/// validation, like the other `check_range` bounds).
pub const DEVICE_RANGE: (usize, usize) = (1, MAX_DEVICES);

/// Sustained per-link (and per-egress-port) Infinity Fabric bandwidth,
/// in bytes/ns (= GB/s). Calibration anchor: the PAPERS.md deep-dive
/// measures ~48 GB/s sustained unidirectional peer bandwidth per xGMI
/// link on quad-APU MI300A nodes.
pub const LINK_BYTES_PER_NS: f64 = 48.0;

/// Small-transfer link latency in ns. Calibration anchor: the deep-dive
/// reports ~1.9 µs end-to-end latency for small peer-to-peer copies.
pub const LINK_LATENCY_NS: f64 = 1_900.0;

/// Link topology of a device set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Every device pair owns a direct link (the MI300A quad-node
    /// wiring); senders are still serialized by their egress port.
    FullyConnected,
    /// Devices form a cycle; only adjacent pairs are linked, so
    /// collectives pay one latency step per hop.
    Ring,
}

impl Topology {
    pub const ALL: [Topology; 2] = [Topology::FullyConnected, Topology::Ring];

    /// Wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Topology::FullyConnected => "fully_connected",
            Topology::Ring => "ring",
        }
    }

    /// Inverse of [`Topology::as_str`].
    pub fn parse(s: &str) -> Option<Topology> {
        Topology::ALL.iter().copied().find(|t| t.as_str() == s)
    }
}

impl Default for Topology {
    fn default() -> Topology {
        Topology::FullyConnected
    }
}

/// The `device_set` dimension of a scenario: how many APUs run the
/// point and how they are wired. The default (one device, the default
/// topology) is the pre-fabric single-APU world and is omitted from the
/// wire entirely, keeping every pre-fabric fixture byte-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceSet {
    pub devices: usize,
    pub topology: Topology,
}

impl Default for DeviceSet {
    fn default() -> DeviceSet {
        DeviceSet { devices: 1, topology: Topology::default() }
    }
}

impl DeviceSet {
    /// Canonicalizing constructor: topology is meaningless with one
    /// device, so `devices == 1` normalizes to the default topology
    /// (decode→encode→decode stays a fixpoint, and a `devices:[1,..]`
    /// sweep's single-device point cache-collides with the equivalent
    /// plain spec).
    pub fn normalized(devices: usize, topology: Topology) -> DeviceSet {
        if devices <= 1 {
            DeviceSet { devices, topology: Topology::default() }
        } else {
            DeviceSet { devices, topology }
        }
    }

    /// Whether this is the single-APU default (omitted from the wire).
    pub fn is_default(self) -> bool {
        self == DeviceSet::default()
    }
}

/// One point-to-point copy over the fabric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// A device set's wired fabric: the topology instantiated with the
/// calibrated link cost model.
#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    pub devices: usize,
    pub topology: Topology,
    pub latency_ns: f64,
    pub bytes_per_ns: f64,
}

impl Fabric {
    /// Build the node fabric for a device set at the calibrated
    /// anchors.
    pub fn for_set(ds: DeviceSet) -> Fabric {
        Fabric {
            devices: ds.devices,
            topology: ds.topology,
            latency_ns: LINK_LATENCY_NS,
            bytes_per_ns: LINK_BYTES_PER_NS,
        }
    }

    /// Hop count between two devices (1 everywhere in
    /// `fully_connected`; minimal ring distance in `ring`).
    pub fn hops(&self, src: usize, dst: usize) -> usize {
        if src == dst {
            return 0;
        }
        match self.topology {
            Topology::FullyConnected => 1,
            Topology::Ring => {
                let d = self.devices;
                let fwd = (dst + d - src) % d;
                fwd.min(d - fwd)
            }
        }
    }

    /// Directed links in the topology.
    pub fn link_count(&self) -> usize {
        let d = self.devices;
        if d <= 1 {
            return 0;
        }
        match self.topology {
            Topology::FullyConnected => d * (d - 1),
            // d == 2 degenerates to one bidirectional pair (2 directed
            // links), otherwise 2 directed links per ring edge.
            Topology::Ring => {
                if d == 2 {
                    2
                } else {
                    2 * d
                }
            }
        }
    }

    /// Uncontended single-hop transfer time.
    pub fn transfer_ns(&self, bytes: f64) -> f64 {
        self.latency_ns + bytes / self.bytes_per_ns
    }

    /// The contention resources a transfer occupies, as stable indices:
    /// `0..devices` are egress ports, the rest directed links. Shared
    /// by the closed-form saturation bound and the DES event stepper in
    /// [`crate::sim::fabric`], so both account contention identically.
    pub fn resources(&self, t: &Transfer) -> Vec<usize> {
        let d = self.devices;
        let mut out = Vec::with_capacity(3);
        if t.src == t.dst || d <= 1 {
            return out;
        }
        out.push(t.src);
        match self.topology {
            Topology::FullyConnected => {
                out.push(d + t.src * d + t.dst);
            }
            Topology::Ring => {
                let fwd = (t.dst + d - t.src) % d;
                let go_fwd = fwd <= d - fwd;
                let mut at = t.src;
                while at != t.dst {
                    let (edge, dir) = if go_fwd {
                        (at, 0)
                    } else {
                        ((at + d - 1) % d, 1)
                    };
                    out.push(d + edge * 2 + dir);
                    at = if go_fwd {
                        (at + 1) % d
                    } else {
                        (at + d - 1) % d
                    };
                }
            }
        }
        out
    }

    /// Generic link-saturation bound for one synchronized round of
    /// transfers: latency for the deepest path plus the busiest
    /// resource's bytes at link bandwidth. Resources are directed ring
    /// edges (each hop of a routed transfer loads every edge it
    /// crosses) plus each source's egress port. This is the closed
    /// form the collective formulas below specialize — and what
    /// `sim::fabric` reproduces by stepping events.
    pub fn round_ns(&self, transfers: &[Transfer]) -> f64 {
        if self.devices <= 1 || transfers.is_empty() {
            return 0.0;
        }
        let d = self.devices;
        // Egress ports (d) + directed edges. Fully connected: d*(d-1)
        // pair slots; ring: 2 directions x d edges (index by start
        // device and direction).
        let mut egress = vec![0.0f64; d];
        let mut link = vec![0.0f64; d * d.max(2) * 2];
        let mut max_hops = 0usize;
        for t in transfers {
            if t.src == t.dst {
                continue;
            }
            egress[t.src] += t.bytes;
            max_hops = max_hops.max(self.hops(t.src, t.dst));
            match self.topology {
                Topology::FullyConnected => {
                    link[t.src * d + t.dst] += t.bytes;
                }
                Topology::Ring => {
                    // Route the minimal way around; ties go forward.
                    let fwd = (t.dst + d - t.src) % d;
                    let go_fwd = fwd <= d - fwd;
                    let mut at = t.src;
                    while at != t.dst {
                        let (edge, dir) = if go_fwd {
                            (at, 0)
                        } else {
                            ((at + d - 1) % d, 1)
                        };
                        link[edge * 2 + dir] += t.bytes;
                        at = if go_fwd { (at + 1) % d } else { (at + d - 1) % d };
                    }
                }
            }
        }
        let busiest = egress
            .iter()
            .chain(link.iter())
            .cloned()
            .fold(0.0f64, f64::max);
        max_hops as f64 * self.latency_ns + busiest / self.bytes_per_ns
    }

    /// Closed-form allreduce of `bytes` across the set (the
    /// `data_parallel` per-round exchange). Bandwidth-optimal
    /// schedules move `2(d-1)/d x bytes` through every device's
    /// bottleneck resource on either topology; the latency term is
    /// what the topology changes — 2 synchronized phases
    /// (reduce-scatter + allgather, chunks fanned over direct links)
    /// in `fully_connected`, `2(d-1)` neighbor steps in `ring`.
    pub fn allreduce_ns(&self, bytes: f64) -> f64 {
        let d = self.devices as f64;
        if self.devices <= 1 {
            return 0.0;
        }
        let steps = match self.topology {
            Topology::FullyConnected => 2.0,
            Topology::Ring => 2.0 * (d - 1.0),
        };
        steps * self.latency_ns
            + 2.0 * (d - 1.0) / d * bytes / self.bytes_per_ns
    }

    /// Closed-form inter-stage activation relay (the `pipeline`
    /// per-iteration handoff): adjacent stages are direct neighbors on
    /// both topologies, one hop each.
    pub fn stage_ns(&self, bytes: f64) -> f64 {
        if self.devices <= 1 {
            return 0.0;
        }
        self.transfer_ns(bytes)
    }

    /// Closed-form halo exchange (the `halo` per-iteration neighbor
    /// round): every device swaps `bytes` with each ring neighbor
    /// (adjacent on both topologies, one hop). Sends to both
    /// neighbors serialize on the egress port; receives land in
    /// parallel. Two devices have a single neighbor.
    pub fn halo_ns(&self, bytes: f64) -> f64 {
        if self.devices <= 1 {
            return 0.0;
        }
        let neighbors = if self.devices == 2 { 1.0 } else { 2.0 };
        self.latency_ns + neighbors * bytes / self.bytes_per_ns
    }

    /// The per-iteration exchange the shape performs, as an explicit
    /// transfer schedule (what the DES steps). Each inner `Vec` is one
    /// synchronized step; steps run back to back.
    pub fn shape_schedule(
        &self,
        shape: Shape,
        bytes: f64,
    ) -> Vec<Vec<Transfer>> {
        let d = self.devices;
        if d <= 1 {
            return Vec::new();
        }
        match shape {
            Shape::DataParallel => match self.topology {
                // Direct reduce-scatter + allgather: two steps, every
                // device fans bytes/d chunks to every peer.
                Topology::FullyConnected => {
                    let chunk = bytes / d as f64;
                    let phase: Vec<Transfer> = (0..d)
                        .flat_map(|s| {
                            (0..d).filter(move |&t| t != s).map(move |t| {
                                Transfer { src: s, dst: t, bytes: chunk }
                            })
                        })
                        .collect();
                    vec![phase.clone(), phase]
                }
                // Ring allreduce: 2(d-1) steps of neighbor chunk
                // rotations.
                Topology::Ring => {
                    let chunk = bytes / d as f64;
                    let step: Vec<Transfer> = (0..d)
                        .map(|s| Transfer {
                            src: s,
                            dst: (s + 1) % d,
                            bytes: chunk,
                        })
                        .collect();
                    vec![step; 2 * (d - 1)]
                }
            },
            // One activation handoff per stage boundary, relayed in
            // stage order (stage i feeds stage i+1 the same tick its
            // iteration retires, so the steps chain).
            Shape::Pipeline => (0..d - 1)
                .map(|s| vec![Transfer { src: s, dst: s + 1, bytes }])
                .collect(),
            // One synchronized neighbor round.
            Shape::Halo => {
                let mut step = Vec::new();
                for s in 0..d {
                    step.push(Transfer { src: s, dst: (s + 1) % d, bytes });
                    if d > 2 {
                        step.push(Transfer {
                            src: s,
                            dst: (s + d - 1) % d,
                            bytes,
                        });
                    }
                }
                vec![step]
            }
            _ => Vec::new(),
        }
    }

    /// The per-iteration exchange payload for a shape at kernel size
    /// `n` and element size `elem_bytes`: gradients (f32 accumulators,
    /// the full output) for `data_parallel`, an activation matrix at
    /// the compute precision for `pipeline`, one macro-tile row of
    /// boundary per neighbor for `halo`.
    pub fn shape_bytes(shape: Shape, n: usize, elem_bytes: usize) -> f64 {
        match shape {
            Shape::DataParallel => (n * n) as f64 * 4.0,
            Shape::Pipeline => (n * n * elem_bytes) as f64,
            Shape::Halo => {
                let tile = crate::hw::lds::gemm_macro_tile(n);
                (tile * n * elem_bytes) as f64
            }
            _ => 0.0,
        }
    }
}

/// A composed multi-device answer: total makespan plus the exposed
/// (non-overlapped) communication inside it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Composed {
    pub makespan_ns: f64,
    pub transfer_ns: f64,
}

/// Fold per-device compute and the per-iteration exchange into the
/// node-level makespan. `compute_ns` is one device's makespan over all
/// `iters` iterations of its (replicated or split) kernel set;
/// `round_ns` is one iteration's exchange.
///
/// * `data_parallel` / `halo`: exchanges are double-buffered against
///   the next iteration's compute (the ACE async-queue overlap story),
///   so each of the first `iters-1` rounds exposes only its excess
///   over an iteration of compute, and the final round is fully
///   exposed.
/// * `pipeline`: a classic linear pipeline — fill through `d` stages
///   and `d-1` relays, then drain one iteration per period, where the
///   period is the slower of compute-per-iteration and the relay.
pub fn compose(
    shape: Shape,
    devices: usize,
    compute_ns: f64,
    iters: usize,
    round_ns: f64,
) -> Composed {
    if devices <= 1 || round_ns <= 0.0 {
        return Composed { makespan_ns: compute_ns, transfer_ns: 0.0 };
    }
    let iters = iters.max(1) as f64;
    let per_iter = compute_ns / iters;
    match shape {
        Shape::Pipeline => {
            let d = devices as f64;
            let period = per_iter.max(round_ns);
            let makespan_ns = d * per_iter
                + (d - 1.0) * round_ns
                + (iters - 1.0) * period;
            // Exposed comm = everything past the compute-only pipeline
            // ((d-1) extra stage fills + one iteration per drain step).
            let compute_only = (d - 1.0) * per_iter + compute_ns;
            Composed {
                makespan_ns,
                transfer_ns: makespan_ns - compute_only,
            }
        }
        _ => {
            let exposed = round_ns
                + (iters - 1.0) * (round_ns - per_iter).max(0.0);
            Composed {
                makespan_ns: compute_ns + exposed,
                transfer_ns: exposed,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(devices: usize, topology: Topology) -> Fabric {
        Fabric::for_set(DeviceSet { devices, topology })
    }

    #[test]
    fn topology_spellings_roundtrip() {
        for t in Topology::ALL {
            assert_eq!(Topology::parse(t.as_str()), Some(t));
        }
        assert_eq!(Topology::parse("mesh"), None);
        assert!(DeviceSet::default().is_default());
        assert!(
            DeviceSet::normalized(1, Topology::Ring).is_default(),
            "one device normalizes away its topology"
        );
        assert!(!DeviceSet::normalized(2, Topology::Ring).is_default());
    }

    #[test]
    fn hops_and_links_match_the_wiring() {
        let fc = fabric(4, Topology::FullyConnected);
        assert_eq!(fc.hops(0, 3), 1);
        assert_eq!(fc.link_count(), 12);
        let ring = fabric(4, Topology::Ring);
        assert_eq!(ring.hops(0, 1), 1);
        assert_eq!(ring.hops(0, 2), 2);
        assert_eq!(ring.hops(0, 3), 1, "minimal distance wraps");
        assert_eq!(ring.link_count(), 8);
        assert_eq!(fabric(2, Topology::Ring).link_count(), 2);
        assert_eq!(fabric(1, Topology::Ring).link_count(), 0);
    }

    #[test]
    fn allreduce_cost_grows_monotonically_with_devices() {
        let bytes = 512.0 * 512.0 * 4.0;
        for t in Topology::ALL {
            let mut prev = 0.0;
            for d in 1..=MAX_DEVICES {
                let ns = fabric(d, t).allreduce_ns(bytes);
                assert!(
                    ns > prev || d == 1,
                    "{t:?} d={d}: {ns} !> {prev}"
                );
                prev = ns;
            }
        }
        // The ring pays more latency steps than the direct exchange,
        // never less bandwidth.
        assert!(
            fabric(4, Topology::Ring).allreduce_ns(bytes)
                > fabric(4, Topology::FullyConnected).allreduce_ns(bytes)
        );
    }

    #[test]
    fn closed_forms_match_the_saturation_bound_on_their_schedules() {
        let bytes = 1.5e6;
        for t in Topology::ALL {
            for d in 2..=MAX_DEVICES {
                let f = fabric(d, t);
                let sched = f.shape_schedule(Shape::DataParallel, bytes);
                let stepped: f64 =
                    sched.iter().map(|s| f.round_ns(s)).sum();
                let closed = f.allreduce_ns(bytes);
                assert!(
                    (stepped - closed).abs() < 1e-6 * closed,
                    "{t:?} d={d}: stepped {stepped} vs closed {closed}"
                );
                let halo = f.shape_schedule(Shape::Halo, bytes);
                let stepped: f64 =
                    halo.iter().map(|s| f.round_ns(s)).sum();
                let closed = f.halo_ns(bytes);
                assert!(
                    (stepped - closed).abs() < 1e-6 * closed,
                    "halo {t:?} d={d}: {stepped} vs {closed}"
                );
            }
        }
    }

    #[test]
    fn compose_exposes_only_the_comm_excess() {
        // Comm fully hidden behind compute: only the last round shows.
        let c = compose(Shape::DataParallel, 4, 1000.0, 10, 50.0);
        assert_eq!(c.transfer_ns, 50.0);
        assert_eq!(c.makespan_ns, 1050.0);
        // Comm-bound: every round exposes its excess.
        let c = compose(Shape::DataParallel, 4, 1000.0, 10, 150.0);
        assert!((c.transfer_ns - (150.0 + 9.0 * 50.0)).abs() < 1e-9);
        // One device is the identity.
        let c = compose(Shape::DataParallel, 1, 1000.0, 10, 150.0);
        assert_eq!(c.makespan_ns, 1000.0);
        assert_eq!(c.transfer_ns, 0.0);
    }

    #[test]
    fn pipeline_compose_fills_and_drains() {
        // 4 stages, 10 iters, relay cheaper than a stage iteration:
        // makespan = 4*100 + 3*20 + 9*100.
        let c = compose(Shape::Pipeline, 4, 1000.0, 10, 20.0);
        assert!((c.makespan_ns - (400.0 + 60.0 + 900.0)).abs() < 1e-9);
        assert!(c.transfer_ns > 0.0);
        assert!(c.makespan_ns > 1000.0);
    }
}
