//! Report rendering: aligned text tables, CSV, and ASCII line plots for
//! the experiment drivers (`mi300a-char repro <id>` output).

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Render with per-column alignment (numbers right, text left).
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let numeric: Vec<bool> = (0..ncol)
            .map(|i| {
                self.rows.iter().all(|r| {
                    let c = r[i].trim_end_matches(['%', 'x']);
                    c.is_empty() || c.parse::<f64>().is_ok()
                })
            })
            .collect();
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                if numeric[i] {
                    line.push_str(&format!("{:>width$}", c, width = widths[i]));
                } else {
                    line.push_str(&format!("{:<width$}", c, width = widths[i]));
                }
                line.push_str(" | ");
            }
            line.trim_end().to_string()
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let sep: Vec<String> =
            widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (headers + rows).
    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// An ASCII line plot: one or more named series over a shared x axis.
pub fn ascii_plot(
    title: &str,
    x: &[f64],
    series: &[(&str, Vec<f64>)],
    height: usize,
) -> String {
    assert!(!x.is_empty() && !series.is_empty());
    let width = 64usize;
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter())
        .cloned()
        .fold(f64::MIN, f64::max);
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter())
        .cloned()
        .fold(f64::MAX, f64::min)
        .min(0.0);
    let yspan = (ymax - ymin).max(1e-12);
    let xmin = x[0];
    let xspan = (x[x.len() - 1] - xmin).max(1e-12);
    let marks = ['*', 'o', '+', 'x', '#', '@'];

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (i, &yv) in ys.iter().enumerate() {
            let cx = (((x[i] - xmin) / xspan) * (width - 1) as f64) as usize;
            let cy = (((yv - ymin) / yspan) * (height - 1) as f64) as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = format!("-- {title} --\n");
    for (r, row) in grid.iter().enumerate() {
        let yv = ymax - yspan * r as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>10.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>10} +{}\n{:>12}{:<.6} .. {:<.6}\n",
        "", "-".repeat(width), "x: ", xmin, xmin + xspan
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["fp8".into(), "13.7%".into()]);
        t.row(vec!["fp64".into(), "12.1%".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| fp8 "));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_wrong_arity() {
        Table::new("t", &["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["x,y".into()]);
        assert_eq!(t.csv(), "a\n\"x,y\"\n");
    }

    #[test]
    fn plot_contains_series_marks() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let p = ascii_plot(
            "t",
            &x,
            &[("a", vec![1.0, 2.0, 3.0, 4.0]), ("b", vec![4.0, 3.0, 2.0, 1.0])],
            8,
        );
        assert!(p.contains('*') && p.contains('o'));
        assert!(p.contains("-- t --"));
    }
}
