//! The replay engine: step a recorded launch timeline through a
//! discrete-event simulation that honors issue times.
//!
//! This is the `sim/engine.rs` contention machinery re-shaped for
//! traces. The synthetic engine runs each stream's iterations
//! back-to-back; here a stream *idles* between launches — a launch
//! starts at `max(issue_ns, previous completion on its stream)` — so
//! the timeline's gaps, bursts, and stream placement drive how much
//! work actually overlaps. Active launches processor-share the machine
//! under the same slowdown law the DES uses (`fill_rates`: LDS
//! saturation + L2 miss growth with the ACE profile's `k_lds`/`k_l2`
//! couplings, sparse streams exerting and feeling less pressure), with
//! per-launch work drawn from the solo [`CostModel`] times a
//! deterministic lognormal jitter whose spread grows with the kernel's
//! CSR irregularity.
//!
//! The jitter is precision-independent by design: a what-if transform
//! must change the answer only through the quantity it rewrites, so a
//! `precision_rewrite` re-costs every launch under identical placement
//! draws.

use super::format::TraceSpec;
use super::transform::Transform;
use crate::config::Config;
use crate::hw::lds::lds_utilization;
use crate::hw::L2Model;
use crate::sim::trace::Span;
use crate::sim::{ConcurrencyProfile, CostModel};
use crate::util::rng::Rng;

/// Work-remaining snap threshold, ns of solo work (mirrors the DES's
/// residual snap).
const EPS: f64 = 1e-6;

/// Per-launch jitter sigma: a base placement spread plus the kernel's
/// irregularity contribution (dense GEMM launches jitter a little,
/// sparse SpMM launches a lot).
fn jitter_sigma(irregularity: f64) -> f64 {
    0.05 + 0.35 * irregularity
}

/// One replayed launch, fully resolved (post-transform).
struct Launch {
    stream: usize,
    /// Index within its stream (the span's `iteration`).
    idx_in_stream: usize,
    issue_ns: f64,
    /// Jittered solo work, ns.
    work_ns: f64,
    label: String,
    // Slowdown-model statics (the DES's `StreamStatic` analog).
    size_max: usize,
    mem_w: f64,
    sparse_w: f64,
    working_set: f64,
    isolated_miss: f64,
}

/// The replayed timeline: exact per-launch spans plus the aggregate
/// read-outs the sim answer reports.
#[derive(Debug, Clone)]
pub struct ReplayRun {
    /// One span per launch, grouped by stream, launches in issue order.
    pub spans: Vec<Span>,
    /// Kernel label per span (Chrome-trace `args.label`).
    pub labels: Vec<String>,
    /// End of the last launch, ns (absolute timeline: includes leading
    /// and inter-launch idle).
    pub makespan_ns: f64,
    /// Sum of jittered solo works: the one-launch-at-a-time baseline.
    pub serial_ns: f64,
    /// Fraction of the makespan with >= 2 launches in flight.
    pub overlap_efficiency: f64,
    /// Busy ns per *used* stream (streams with no launches excluded),
    /// the fairness input.
    pub per_stream_busy_ns: Vec<f64>,
    /// Work-weighted L2 miss ratio at the mean concurrency level.
    pub l2_miss: f64,
    /// LDS utilization at the mean concurrency level.
    pub lds_util: f64,
    /// Discrete events processed.
    pub events: u64,
}

/// Replay `trace` under `transform`. Deterministic for a given seed.
pub fn replay(
    cfg: &Config,
    trace: &TraceSpec,
    transform: Transform,
    seed: u64,
) -> ReplayRun {
    let records = transform.apply(trace.records());
    // Transforms are validity-preserving (transform.rs tests pin it);
    // re-wrap to recompute stream extents after remaps.
    let spec = TraceSpec::from_records(records)
        .expect("transforms preserve trace validity");
    let records = spec.records();

    let cost = CostModel::new(cfg);
    let profile = ConcurrencyProfile::ace();
    let l2: &L2Model = cost.l2();
    let total_cus = cfg.total_cus();
    let lds_bytes = cfg.lds_bytes_per_cu() as usize;
    let lds_double_buffer = cfg.calib.lds_double_buffer;

    let mut rng = Rng::new(seed ^ 0x7ace_c0de);
    let stream_count = spec.stream_count();
    let mut per_stream_seen = vec![0usize; stream_count];
    let mut launches: Vec<Launch> = Vec::with_capacity(records.len());
    for (li, r) in records.iter().enumerate() {
        let k = r.kernel_desc();
        let mut lrng = rng.fork(li as u64 + 1);
        let jitter = lrng.lognormal_unit(jitter_sigma(k.irregularity()));
        let ws = k.working_set();
        let mem_w = if k.sparsity.is_sparse() {
            cfg.sparsity.mem_fraction
        } else {
            1.0
        };
        let idx = per_stream_seen[r.stream];
        per_stream_seen[r.stream] += 1;
        launches.push(Launch {
            stream: r.stream,
            idx_in_stream: idx,
            issue_ns: r.issue_ns as f64,
            work_ns: cost.solo_work_ns(&k) * jitter,
            label: k.label(),
            size_max: k.m.max(k.n),
            mem_w,
            sparse_w: if k.sparsity.is_sparse() {
                cfg.sparsity.mem_fraction.powi(2)
            } else {
                1.0
            },
            working_set: ws,
            isolated_miss: l2.isolated_miss(ws),
        });
    }

    // Per-stream launch order (records are per-stream monotone, so
    // record order within a stream is execution order).
    let mut queues: Vec<Vec<usize>> = vec![Vec::new(); stream_count];
    for (li, l) in launches.iter().enumerate() {
        queues[l.stream].push(li);
    }
    let mut next_in_queue = vec![0usize; stream_count];
    let mut stream_active: Vec<Option<usize>> = vec![None; stream_count];

    let mut remaining: Vec<f64> =
        launches.iter().map(|l| l.work_ns).collect();
    let mut start_ns = vec![0.0f64; launches.len()];
    let mut end_ns = vec![0.0f64; launches.len()];

    let mut t = 0.0f64;
    let mut overlap_ns = 0.0f64;
    let mut active_integral = 0.0f64;
    let mut active: Vec<usize> = Vec::with_capacity(stream_count);
    let mut rates: Vec<f64> = Vec::with_capacity(stream_count);
    let mut events = 0u64;
    let event_budget = 10_000 + 64 * launches.len() as u64;

    loop {
        events += 1;
        assert!(
            events < event_budget,
            "replay event budget exceeded (livelock?): t={t}"
        );

        // Start every launch that is ready now: its stream idle and its
        // issue time reached.
        for s in 0..stream_count {
            if stream_active[s].is_some() {
                continue;
            }
            while next_in_queue[s] < queues[s].len() {
                let li = queues[s][next_in_queue[s]];
                if launches[li].issue_ns > t + EPS {
                    break;
                }
                next_in_queue[s] += 1;
                stream_active[s] = Some(li);
                start_ns[li] = t;
                active.push(li);
                break; // one launch in flight per stream
            }
        }

        let pending_left =
            (0..stream_count).any(|s| next_in_queue[s] < queues[s].len());
        if active.is_empty() && !pending_left {
            break;
        }

        // Processor-sharing rates for the active set (the DES's
        // fill_rates law, gains fixed at 1: traces carry no
        // fragmentation pairing).
        rates.clear();
        if !active.is_empty() {
            let s = active.len();
            let max_n = active
                .iter()
                .map(|&li| launches[li].size_max)
                .max()
                .unwrap_or(512);
            let lds_sat = lds_utilization(
                max_n,
                s,
                total_cus,
                lds_bytes,
                lds_double_buffer,
            );
            let eff_streams: f64 =
                active.iter().map(|&li| launches[li].mem_w).sum();
            let eff = eff_streams.round().max(1.0) as usize;
            let conc = if s >= 2 { 1.0 } else { 0.0 };
            for &li in &active {
                let l = &launches[li];
                let grown = l2.miss_ratio(l.working_set, eff);
                let l2_growth = ((grown / l.isolated_miss) - 1.0).max(0.0)
                    * l.mem_w
                    / cfg.calib.l2_miss_stream_slope;
                let slowdown = 1.0
                    + profile.k_lds * lds_sat * l.sparse_w * conc
                    + profile.k_l2 * l2_growth;
                rates.push(1.0 / slowdown);
            }
        }

        // Next event: earliest completion or earliest future issue on
        // an idle stream.
        let mut t_next = f64::INFINITY;
        for (ai, &li) in active.iter().enumerate() {
            t_next = t_next.min(t + remaining[li] / rates[ai]);
        }
        for s in 0..stream_count {
            if stream_active[s].is_none() && next_in_queue[s] < queues[s].len()
            {
                let li = queues[s][next_in_queue[s]];
                t_next = t_next.min(launches[li].issue_ns.max(t));
            }
        }
        debug_assert!(t_next.is_finite());

        let dt = (t_next - t).max(0.0);
        if active.len() >= 2 {
            overlap_ns += dt;
        }
        active_integral += active.len() as f64 * dt;
        for (ai, &li) in active.iter().enumerate() {
            remaining[li] -= dt * rates[ai];
        }
        t = t_next;

        // Retire completed launches (their stream frees for the next
        // queued launch on the following loop turn).
        let mut ai = 0;
        while ai < active.len() {
            let li = active[ai];
            if remaining[li] <= EPS {
                end_ns[li] = t;
                stream_active[launches[li].stream] = None;
                active.swap_remove(ai);
            } else {
                ai += 1;
            }
        }
        // `rates` indices pair with `active` positionally; they are
        // rebuilt at the top of the next turn.
    }

    let makespan_ns = end_ns.iter().cloned().fold(0.0, f64::max);
    let serial_ns: f64 = launches.iter().map(|l| l.work_ns).sum();

    // Spans grouped by stream, launch order within each stream.
    let mut order: Vec<usize> = (0..launches.len()).collect();
    order.sort_by_key(|&li| (launches[li].stream, launches[li].idx_in_stream));
    let mut spans = Vec::with_capacity(launches.len());
    let mut labels = Vec::with_capacity(launches.len());
    for &li in &order {
        spans.push(Span {
            stream: launches[li].stream,
            iteration: launches[li].idx_in_stream,
            start_ns: start_ns[li],
            end_ns: end_ns[li],
        });
        labels.push(launches[li].label.clone());
    }

    let mut busy = vec![0.0f64; stream_count];
    for (li, l) in launches.iter().enumerate() {
        busy[l.stream] += end_ns[li] - start_ns[li];
    }
    let per_stream_busy_ns: Vec<f64> =
        spec.used_streams().iter().map(|&s| busy[s]).collect();

    // Aggregate cache behaviour at the mean concurrency level,
    // work-weighted across launches.
    let mean_conc = if makespan_ns > 0.0 {
        (active_integral / makespan_ns).round().max(1.0) as usize
    } else {
        1
    };
    let l2_miss = if serial_ns > 0.0 {
        launches
            .iter()
            .map(|l| l.work_ns * l2.miss_ratio(l.working_set, mean_conc))
            .sum::<f64>()
            / serial_ns
    } else {
        0.0
    };
    let max_size = launches.iter().map(|l| l.size_max).max().unwrap_or(512);
    let lds_util = lds_utilization(
        max_size,
        mean_conc,
        total_cus,
        lds_bytes,
        lds_double_buffer,
    );

    ReplayRun {
        spans,
        labels,
        makespan_ns,
        serial_ns,
        overlap_efficiency: if makespan_ns > 0.0 {
            overlap_ns / makespan_ns
        } else {
            0.0
        },
        per_stream_busy_ns,
        l2_miss,
        lds_util,
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::format::TraceRecord;
    use crate::sim::kernel::{KernelClass, SparsityMode};
    use crate::isa::Precision;

    fn rec(stream: usize, issue_ns: u64, n: usize, p: Precision) -> TraceRecord {
        TraceRecord {
            kernel: KernelClass::Gemm,
            n,
            precision: p,
            sparsity: SparsityMode::Dense,
            stream,
            issue_ns,
        }
    }

    fn two_stream_fp16() -> TraceSpec {
        TraceSpec::from_records(vec![
            rec(0, 0, 1024, Precision::F16),
            rec(1, 0, 512, Precision::F16),
            rec(0, 200_000, 1024, Precision::F16),
            rec(1, 400_000, 512, Precision::F16),
        ])
        .unwrap()
    }

    #[test]
    fn replay_is_deterministic_and_spans_cover_every_launch() {
        let cfg = Config::mi300a();
        let ts = two_stream_fp16();
        let a = replay(&cfg, &ts, Transform::Identity, cfg.seed);
        let b = replay(&cfg, &ts, Transform::Identity, cfg.seed);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.spans, b.spans);
        assert_eq!(a.spans.len(), 4);
        assert_eq!(a.labels.len(), 4);
        assert_eq!(a.per_stream_busy_ns.len(), 2);
        assert!(a.events > 0 && a.makespan_ns > 0.0);
        assert!((0.0..=1.0).contains(&a.overlap_efficiency));
    }

    #[test]
    fn launches_respect_issue_times_and_stream_order() {
        let cfg = Config::mi300a();
        let ts = two_stream_fp16();
        let run = replay(&cfg, &ts, Transform::Identity, cfg.seed);
        for (sp, r) in run
            .spans
            .iter()
            .map(|s| {
                // spans are stream-grouped; find the matching record.
                ts.records()
                    .iter()
                    .filter(|r| r.stream == s.stream)
                    .nth(s.iteration)
                    .map(|r| (s, r))
                    .unwrap()
            })
            .collect::<Vec<_>>()
        {
            assert!(
                sp.start_ns + 1e-9 >= r.issue_ns as f64,
                "stream {} launch {} started at {} before issue {}",
                sp.stream,
                sp.iteration,
                sp.start_ns,
                r.issue_ns
            );
            assert!(sp.end_ns > sp.start_ns);
        }
        // Per stream, spans never overlap (one launch in flight).
        for s in 0..2 {
            let mine: Vec<&Span> =
                run.spans.iter().filter(|x| x.stream == s).collect();
            for w in mine.windows(2) {
                assert!(w[1].start_ns + 1e-9 >= w[0].end_ns);
            }
        }
    }

    #[test]
    fn idle_gaps_stretch_the_makespan() {
        // The same work with issue times dilated 8x must take longer:
        // the timeline becomes issue-bound.
        let cfg = Config::mi300a();
        let ts = two_stream_fp16();
        let base = replay(&cfg, &ts, Transform::Identity, cfg.seed);
        let slow = replay(&cfg, &ts, Transform::Dilate(8), cfg.seed);
        assert!(
            slow.makespan_ns > base.makespan_ns,
            "dilate:8 {} !> identity {}",
            slow.makespan_ns,
            base.makespan_ns
        );
        // Serial work is untouched by a pure-time transform.
        assert_eq!(slow.serial_ns, base.serial_ns);
    }

    #[test]
    fn fp8_rewrite_strictly_beats_the_fp16_original() {
        let cfg = Config::mi300a();
        let ts = two_stream_fp16();
        let fp16 = replay(&cfg, &ts, Transform::Identity, cfg.seed);
        let fp8 = replay(
            &cfg,
            &ts,
            Transform::PrecisionRewrite(Precision::Fp8),
            cfg.seed,
        );
        assert!(
            fp8.makespan_ns < fp16.makespan_ns,
            "fp8 {} !< fp16 {}",
            fp8.makespan_ns,
            fp16.makespan_ns
        );
        assert!(fp8.serial_ns < fp16.serial_ns);
    }

    #[test]
    fn identity_transform_equals_untransformed() {
        // Transform::Identity and "no transform" are the same code
        // path; the byte-level twin of the wire-level acceptance test.
        let cfg = Config::mi300a();
        let ts = two_stream_fp16();
        let a = replay(&cfg, &ts, Transform::Identity, cfg.seed);
        let b = replay(&cfg, &ts, Transform::default(), cfg.seed);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.spans, b.spans);
    }

    #[test]
    fn stream_remap_onto_one_stream_serializes() {
        let cfg = Config::mi300a();
        let ts = two_stream_fp16();
        let merged = replay(&cfg, &ts, Transform::StreamRemap(1), cfg.seed);
        assert_eq!(merged.per_stream_busy_ns.len(), 1);
        assert_eq!(merged.overlap_efficiency, 0.0, "one stream: no overlap");
        assert!(merged.spans.iter().all(|s| s.stream == 0));
    }
}
