//! Trace replay: recorded kernel-launch timelines as a first-class
//! workload (DESIGN.md §6.12, docs/replay.md).
//!
//! The paper's case studies argue from *timelines* — sequences of
//! launches whose occupancy, precision, and stream placement determine
//! application-level throughput — so this subsystem turns the
//! simulator into a what-if tool for real MI300A applications:
//!
//! * [`format`] — JSON-lines trace records, strict typed-error decode
//!   (the `api/protocol.rs` discipline), and the validated
//!   [`TraceSpec`] (bounded, per-stream-monotone issue times, kernels
//!   resolved against `sim/kernel.rs`).
//! * [`transform`] — declarative what-if rewrites (`precision_rewrite`,
//!   `sparsity_enable`, `stream_remap`, `dilate`/`compress`), applied
//!   before replay and sweepable as the scenario `transform` axis.
//! * [`engine`] — the issue-time-honoring DES: streams idle between
//!   launches instead of iterating back-to-back, active launches
//!   processor-share under the `sim/engine.rs` slowdown law, and every
//!   launch comes back as an exact span for the Chrome-trace exporter.
//!
//! The scenario layer (`api/scenario.rs`) embeds a trace as the
//! `trace` spec field with `shape:"trace"`, so caching, batching,
//! jobs, cluster sharding, and auto routing all compose with replay
//! for free via the canonical per-point encoding. Only the DES answers
//! trace points; the analytic backend refuses them as typed
//! `unsupported_by_backend`.

pub mod engine;
pub mod format;
pub mod transform;

pub use engine::{replay, ReplayRun};
pub use format::{
    parse_jsonl, TraceError, TraceErrorKind, TraceRecord, TraceSpec,
    MAX_TRACE_LAUNCHES, MAX_TRACE_LINE_BYTES, MAX_TRACE_STREAMS,
    TRACE_N_RANGE,
};
pub use transform::{Transform, MAX_TIME_FACTOR};
