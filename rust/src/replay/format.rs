//! Trace format: JSON-lines kernel-launch records and the validated
//! [`TraceSpec`].
//!
//! A trace is the recorded launch timeline of a real application — one
//! record per kernel launch, each naming the kernel class, its GEMM
//! dimension, precision, structured-sparsity overlay, the stream it was
//! issued on, and the host-side issue timestamp in nanoseconds:
//!
//! ```text
//! {"kernel":"gemm","n":2048,"precision":"fp16","stream":0,"issue_ns":0}
//! {"kernel":"spmm","n":512,"precision":"fp8","stream":1,"issue_ns":1500}
//! ```
//!
//! Decoding follows the `api/protocol.rs` discipline: closed field
//! sets, typed errors, bounded record/line counts, and a canonical
//! re-encoding (all fields present, keys sorted) that the scenario
//! layer's fixpoint/cache-key machinery relies on. The module cannot
//! import `api` (the scenario layer imports *us*), so errors carry a
//! [`TraceErrorKind`] the caller maps onto the wire `ErrorCode`s.

use crate::isa::Precision;
use crate::sim::kernel::{KernelClass, KernelDesc, SparsityMode};
use crate::util::json::Json;

/// Most launches one trace may carry (also the JSON-lines line bound).
pub const MAX_TRACE_LAUNCHES: usize = 4096;

/// Exclusive stream-id bound — mirrors the service's `SIM_STREAMS` cap
/// (a scenario test pins the two together).
pub const MAX_TRACE_STREAMS: usize = 16;

/// Accepted per-record GEMM size range — mirrors the service's
/// `SIZE_RANGE` (pinned by the same scenario test).
pub const TRACE_N_RANGE: (usize, usize) = (1, 16384);

/// Longest accepted JSON-lines line, bytes (one record per line).
pub const MAX_TRACE_LINE_BYTES: usize = 4096;

/// Which wire error class a trace defect belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceErrorKind {
    /// Malformed or semantically invalid content (`bad_request`).
    BadRequest,
    /// Well-formed but out of the accepted bounds (`bad_range`).
    BadRange,
}

/// A typed trace defect: the wire error class plus a human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceError {
    pub kind: TraceErrorKind,
    pub msg: String,
}

impl TraceError {
    pub(crate) fn request(msg: impl Into<String>) -> TraceError {
        TraceError { kind: TraceErrorKind::BadRequest, msg: msg.into() }
    }

    pub(crate) fn range(msg: impl Into<String>) -> TraceError {
        TraceError { kind: TraceErrorKind::BadRange, msg: msg.into() }
    }
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

/// One recorded kernel launch.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TraceRecord {
    /// Kernel class, resolved against `sim/kernel.rs` (default `gemm`).
    pub kernel: KernelClass,
    /// GEMM/SpMM dimension (N of an NxNxN launch). Required.
    pub n: usize,
    /// Operand precision (default `fp8`).
    pub precision: Precision,
    /// Structured 2:4 overlay (default `dense`).
    pub sparsity: SparsityMode,
    /// Stream the launch was issued on. Required, `< MAX_TRACE_STREAMS`.
    pub stream: usize,
    /// Host-side issue timestamp, ns from trace start. Required;
    /// non-decreasing per stream.
    pub issue_ns: u64,
}

/// The closed record field set, sorted (protocol discipline: any other
/// key is a typed `bad_request`).
pub const RECORD_FIELDS: &[&str] =
    &["issue_ns", "kernel", "n", "precision", "sparsity", "stream"];

fn rec_usize(v: &Json, field: &str) -> Result<usize, TraceError> {
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
        _ => Err(TraceError::request(format!(
            "trace record field {field:?} must be a non-negative integer"
        ))),
    }
}

impl TraceRecord {
    /// Decode one record object. Strict: closed field set, typed
    /// messages, no coercions.
    pub fn from_json(v: &Json) -> Result<TraceRecord, TraceError> {
        let m = match v {
            Json::Obj(m) => m,
            _ => {
                return Err(TraceError::request(
                    "trace records must be objects",
                ))
            }
        };
        for k in m.keys() {
            if !RECORD_FIELDS.contains(&k.as_str()) {
                return Err(TraceError::request(format!(
                    "unknown trace record field {k:?} (accepted: \
                     {RECORD_FIELDS:?})"
                )));
            }
        }
        let kernel = match m.get("kernel") {
            None => KernelClass::Gemm,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    TraceError::request(
                        "trace record field \"kernel\" must be a string",
                    )
                })?;
                KernelClass::parse(s).ok_or_else(|| {
                    TraceError::request(format!(
                        "unknown trace kernel {s:?} (accepted: gemm, spmm)"
                    ))
                })?
            }
        };
        let n = rec_usize(
            m.get("n").ok_or_else(|| {
                TraceError::request("trace record missing field \"n\"")
            })?,
            "n",
        )?;
        let precision = match m.get("precision") {
            None => Precision::Fp8,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    TraceError::request(
                        "trace record field \"precision\" must be a string",
                    )
                })?;
                Precision::parse(s).ok_or_else(|| {
                    TraceError::request(format!(
                        "unknown trace precision {s:?}"
                    ))
                })?
            }
        };
        let sparsity = match m.get("sparsity") {
            None => SparsityMode::Dense,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    TraceError::request(
                        "trace record field \"sparsity\" must be a string",
                    )
                })?;
                SparsityMode::parse(s).ok_or_else(|| {
                    TraceError::request(format!(
                        "unknown trace sparsity {s:?}"
                    ))
                })?
            }
        };
        let stream = rec_usize(
            m.get("stream").ok_or_else(|| {
                TraceError::request("trace record missing field \"stream\"")
            })?,
            "stream",
        )?;
        let issue_ns = rec_usize(
            m.get("issue_ns").ok_or_else(|| {
                TraceError::request(
                    "trace record missing field \"issue_ns\"",
                )
            })?,
            "issue_ns",
        )? as u64;
        Ok(TraceRecord { kernel, n, precision, sparsity, stream, issue_ns })
    }

    /// Canonical encoding: every field present, keys sorted. The
    /// scenario fixpoint (`encode(decode(x))` stable after one round)
    /// and the cache key both ride on this.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("issue_ns", Json::Num(self.issue_ns as f64)),
            ("kernel", Json::Str(self.kernel.name().into())),
            ("n", Json::Num(self.n as f64)),
            (
                "precision",
                Json::Str(self.precision.name().to_ascii_lowercase()),
            ),
            ("sparsity", Json::Str(self.sparsity.name().into())),
            ("stream", Json::Num(self.stream as f64)),
        ])
    }

    /// Resolve this record against `sim/kernel.rs`: a one-iteration
    /// kernel descriptor the replay engine costs.
    pub fn kernel_desc(&self) -> KernelDesc {
        let k = match self.kernel {
            KernelClass::Gemm => KernelDesc::gemm(self.n, self.precision),
            KernelClass::Spmm => KernelDesc::spmm(
                self.n,
                self.precision,
                crate::sim::kernel::DEFAULT_SPMM_DENSITY_PCT,
            ),
        };
        k.with_sparsity(self.sparsity).with_iters(1)
    }
}

/// A validated launch timeline: bounded, stream ids in range, issue
/// times non-decreasing per stream.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    records: Vec<TraceRecord>,
}

impl TraceSpec {
    /// Validate and wrap a record list (the only constructor).
    pub fn from_records(
        records: Vec<TraceRecord>,
    ) -> Result<TraceSpec, TraceError> {
        if records.is_empty() {
            return Err(TraceError::request(
                "trace must contain at least one record",
            ));
        }
        if records.len() > MAX_TRACE_LAUNCHES {
            return Err(TraceError::range(format!(
                "trace has {} launches (max {MAX_TRACE_LAUNCHES})",
                records.len()
            )));
        }
        let mut last_issue = [None::<u64>; MAX_TRACE_STREAMS];
        for (i, r) in records.iter().enumerate() {
            if r.stream >= MAX_TRACE_STREAMS {
                return Err(TraceError::range(format!(
                    "trace record {i}: stream {} out of range (max {})",
                    r.stream,
                    MAX_TRACE_STREAMS - 1
                )));
            }
            if r.n < TRACE_N_RANGE.0 || r.n > TRACE_N_RANGE.1 {
                return Err(TraceError::range(format!(
                    "trace record {i}: n {} out of range {:?}",
                    r.n, TRACE_N_RANGE
                )));
            }
            if let Some(prev) = last_issue[r.stream] {
                if r.issue_ns < prev {
                    return Err(TraceError::request(format!(
                        "trace record {i}: issue_ns {} on stream {} \
                         precedes the stream's previous launch at {prev} \
                         (per-stream issue times must be non-decreasing)",
                        r.issue_ns, r.stream
                    )));
                }
            }
            last_issue[r.stream] = Some(r.issue_ns);
        }
        Ok(TraceSpec { records })
    }

    pub fn records(&self) -> &[TraceRecord] {
        &self.records
    }

    /// Highest stream id + 1.
    pub fn stream_count(&self) -> usize {
        self.records.iter().map(|r| r.stream).max().unwrap_or(0) + 1
    }

    /// Stream ids that actually carry launches, ascending.
    pub fn used_streams(&self) -> Vec<usize> {
        let mut used = [false; MAX_TRACE_STREAMS];
        for r in &self.records {
            used[r.stream] = true;
        }
        (0..MAX_TRACE_STREAMS).filter(|&s| used[s]).collect()
    }

    /// Largest kernel dimension in the trace (the scenario layer's
    /// headline `n` for a trace-shaped spec).
    pub fn max_n(&self) -> usize {
        self.records.iter().map(|r| r.n).max().unwrap_or(1)
    }

    /// Dominant precision: the one carrying the most dense-equivalent
    /// FLOPs (the scenario layer's headline `precision`).
    pub fn dominant_precision(&self) -> Precision {
        let mut by_prec: Vec<(Precision, f64)> = Vec::new();
        for r in &self.records {
            let f = 2.0 * (r.n as f64).powi(3);
            match by_prec.iter_mut().find(|(p, _)| *p == r.precision) {
                Some((_, acc)) => *acc += f,
                None => by_prec.push((r.precision, f)),
            }
        }
        by_prec
            .into_iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(p, _)| p)
            .unwrap_or(Precision::Fp8)
    }
}

/// Parse a JSON-lines trace file body (the CLI `replay --trace` path).
/// Blank lines are skipped; line length and line count are bounded.
pub fn parse_jsonl(text: &str) -> Result<Vec<TraceRecord>, TraceError> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.len() > MAX_TRACE_LINE_BYTES {
            return Err(TraceError::range(format!(
                "trace line {}: {} bytes (max {MAX_TRACE_LINE_BYTES})",
                ln + 1,
                line.len()
            )));
        }
        if out.len() >= MAX_TRACE_LAUNCHES {
            return Err(TraceError::range(format!(
                "trace exceeds {MAX_TRACE_LAUNCHES} records"
            )));
        }
        let v = Json::parse(line).map_err(|e| {
            TraceError::request(format!("trace line {}: {e}", ln + 1))
        })?;
        out.push(TraceRecord::from_json(&v).map_err(|e| {
            TraceError { kind: e.kind, msg: format!("trace line {}: {}", ln + 1, e.msg) }
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(stream: usize, issue_ns: u64, n: usize) -> TraceRecord {
        TraceRecord {
            kernel: KernelClass::Gemm,
            n,
            precision: Precision::Fp8,
            sparsity: SparsityMode::Dense,
            stream,
            issue_ns,
        }
    }

    #[test]
    fn record_roundtrips_canonically() {
        let r = rec(2, 1500, 512);
        let j = r.to_json();
        assert_eq!(TraceRecord::from_json(&j).unwrap(), r);
        // Canonical text is stable and sorted.
        assert_eq!(
            j.to_string(),
            r#"{"issue_ns":1500,"kernel":"gemm","n":512,"precision":"fp8","sparsity":"dense","stream":2}"#
        );
        // Defaults fill in for omitted optional fields.
        let sparse = Json::parse(r#"{"n":512,"stream":0,"issue_ns":0}"#)
            .unwrap();
        let d = TraceRecord::from_json(&sparse).unwrap();
        assert_eq!(d.kernel, KernelClass::Gemm);
        assert_eq!(d.precision, Precision::Fp8);
        assert_eq!(d.sparsity, SparsityMode::Dense);
    }

    #[test]
    fn decode_rejects_malformed_records() {
        let cases: Vec<(&str, TraceErrorKind)> = vec![
            (r#"{"n":512,"stream":0}"#, TraceErrorKind::BadRequest),
            (r#"{"stream":0,"issue_ns":0}"#, TraceErrorKind::BadRequest),
            (
                r#"{"n":512,"stream":0,"issue_ns":0,"warp":1}"#,
                TraceErrorKind::BadRequest,
            ),
            (
                r#"{"n":512,"stream":0,"issue_ns":-5}"#,
                TraceErrorKind::BadRequest,
            ),
            (
                r#"{"n":512,"stream":0,"issue_ns":0,"kernel":"conv"}"#,
                TraceErrorKind::BadRequest,
            ),
            (
                r#"{"n":512,"stream":0,"issue_ns":0,"precision":"int4"}"#,
                TraceErrorKind::BadRequest,
            ),
            (r#"[1,2]"#, TraceErrorKind::BadRequest),
        ];
        for (text, kind) in cases {
            let v = Json::parse(text).unwrap();
            let e = TraceRecord::from_json(&v).unwrap_err();
            assert_eq!(e.kind, kind, "{text}: {}", e.msg);
        }
    }

    #[test]
    fn spec_validates_bounds_and_monotonicity() {
        // Good: interleaved streams, each non-decreasing.
        let ok = TraceSpec::from_records(vec![
            rec(0, 0, 512),
            rec(1, 0, 512),
            rec(0, 100, 512),
            rec(1, 50, 512),
        ])
        .unwrap();
        assert_eq!(ok.stream_count(), 2);
        assert_eq!(ok.used_streams(), vec![0, 1]);
        assert_eq!(ok.max_n(), 512);

        // Non-monotone within one stream.
        let e = TraceSpec::from_records(vec![rec(0, 100, 512), rec(0, 50, 512)])
            .unwrap_err();
        assert_eq!(e.kind, TraceErrorKind::BadRequest);

        // Stream out of range.
        let e = TraceSpec::from_records(vec![rec(16, 0, 512)]).unwrap_err();
        assert_eq!(e.kind, TraceErrorKind::BadRange);

        // n out of range.
        let e = TraceSpec::from_records(vec![rec(0, 0, 100_000)]).unwrap_err();
        assert_eq!(e.kind, TraceErrorKind::BadRange);

        // Empty.
        let e = TraceSpec::from_records(vec![]).unwrap_err();
        assert_eq!(e.kind, TraceErrorKind::BadRequest);

        // Too many launches.
        let many = vec![rec(0, 0, 512); MAX_TRACE_LAUNCHES + 1];
        let e = TraceSpec::from_records(many).unwrap_err();
        assert_eq!(e.kind, TraceErrorKind::BadRange);
    }

    #[test]
    fn jsonl_parses_and_bounds_lines() {
        let text = "\n{\"n\":512,\"stream\":0,\"issue_ns\":0}\n\
                    {\"n\":256,\"stream\":1,\"issue_ns\":10,\"kernel\":\"spmm\"}\n";
        let rs = parse_jsonl(text).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].kernel, KernelClass::Spmm);
        // Parse errors carry the 1-based line number.
        let e = parse_jsonl("{\"n\":512,\"stream\":0,\"issue_ns\":0}\nnope")
            .unwrap_err();
        assert!(e.msg.contains("line 2"), "{}", e.msg);
    }

    #[test]
    fn dominant_precision_is_flop_weighted() {
        let ts = TraceSpec::from_records(vec![
            TraceRecord { precision: Precision::F16, ..rec(0, 0, 2048) },
            rec(1, 0, 256),
            rec(1, 10, 256),
        ])
        .unwrap();
        // One 2048^3 fp16 launch dwarfs two 256^3 fp8 launches.
        assert_eq!(ts.dominant_precision(), Precision::F16);
    }
}
