//! What-if transforms: declarative trace rewrites applied before
//! replay, sweepable as the scenario layer's `transform` axis.
//!
//! Each transform is a pure function over the record list with a
//! canonical wire spelling (`name()`/`parse()` are exact inverses on
//! canonical spellings), so a transform rides inside the per-point
//! cache key and shards across a cluster like any other axis:
//!
//! | spelling                 | rewrite                                  |
//! |--------------------------|------------------------------------------|
//! | `identity`               | no-op (the recorded timeline)            |
//! | `precision_rewrite:fp8`  | every launch re-cast to the precision    |
//! | `sparsity_enable`        | 2:4 (`lhs`) on dense GEMM launches       |
//! | `stream_remap:K`         | compact onto K streams (`stream % K`)    |
//! | `dilate:K`               | issue times multiplied by integer K      |
//! | `compress:K`             | issue times divided by integer K         |
//!
//! `apply` always yields a timeline that still satisfies every
//! [`TraceSpec`](super::format::TraceSpec) invariant: `dilate`/
//! `compress` preserve per-stream monotonicity (monotone maps), and
//! `stream_remap` re-sorts by issue time after merging streams.

use super::format::{TraceRecord, MAX_TRACE_STREAMS};
use crate::isa::Precision;
use crate::sim::kernel::{KernelClass, SparsityMode};

/// Largest accepted `dilate`/`compress` factor.
pub const MAX_TIME_FACTOR: usize = 1024;

/// A declarative trace rewrite (see the module table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Transform {
    Identity,
    PrecisionRewrite(Precision),
    SparsityEnable,
    StreamRemap(usize),
    Dilate(usize),
    Compress(usize),
}

impl Default for Transform {
    fn default() -> Transform {
        Transform::Identity
    }
}

impl Transform {
    /// Canonical wire spelling.
    pub fn name(&self) -> String {
        match self {
            Transform::Identity => "identity".into(),
            Transform::PrecisionRewrite(p) => {
                format!("precision_rewrite:{}", p.name().to_ascii_lowercase())
            }
            Transform::SparsityEnable => "sparsity_enable".into(),
            Transform::StreamRemap(k) => format!("stream_remap:{k}"),
            Transform::Dilate(f) => format!("dilate:{f}"),
            Transform::Compress(f) => format!("compress:{f}"),
        }
    }

    /// Parse a wire spelling; `None` for unknown verbs or out-of-range
    /// parameters (callers answer with a typed `bad_request` naming the
    /// accepted forms).
    pub fn parse(s: &str) -> Option<Transform> {
        if s == "identity" {
            return Some(Transform::Identity);
        }
        if s == "sparsity_enable" {
            return Some(Transform::SparsityEnable);
        }
        if let Some(p) = s.strip_prefix("precision_rewrite:") {
            return Precision::parse(p).map(Transform::PrecisionRewrite);
        }
        let factor = |p: &str, max: usize| -> Option<usize> {
            // Plain decimal only: no signs, leading zeros allowed.
            if p.is_empty() || !p.bytes().all(|b| b.is_ascii_digit()) {
                return None;
            }
            let v: usize = p.parse().ok()?;
            (1..=max).contains(&v).then_some(v)
        };
        if let Some(p) = s.strip_prefix("stream_remap:") {
            return factor(p, MAX_TRACE_STREAMS).map(Transform::StreamRemap);
        }
        if let Some(p) = s.strip_prefix("dilate:") {
            return factor(p, MAX_TIME_FACTOR).map(Transform::Dilate);
        }
        if let Some(p) = s.strip_prefix("compress:") {
            return factor(p, MAX_TIME_FACTOR).map(Transform::Compress);
        }
        None
    }

    /// Rewrite a timeline. Total: the result always re-validates as a
    /// `TraceSpec` (counts and bounds unchanged or shrunk, per-stream
    /// issue order restored after stream merges).
    pub fn apply(&self, records: &[TraceRecord]) -> Vec<TraceRecord> {
        let mut out: Vec<TraceRecord> = records.to_vec();
        match *self {
            Transform::Identity => {}
            Transform::PrecisionRewrite(p) => {
                for r in &mut out {
                    r.precision = p;
                }
            }
            Transform::SparsityEnable => {
                for r in &mut out {
                    if r.kernel == KernelClass::Gemm
                        && r.sparsity == SparsityMode::Dense
                    {
                        r.sparsity = SparsityMode::SparseLhs;
                    }
                }
            }
            Transform::StreamRemap(k) => {
                for r in &mut out {
                    r.stream %= k;
                }
                // Merging monotone per-stream sequences can interleave
                // out of order on the shared stream; a stable sort by
                // issue time restores per-stream monotonicity.
                out.sort_by_key(|r| r.issue_ns);
            }
            Transform::Dilate(f) => {
                for r in &mut out {
                    r.issue_ns = r.issue_ns.saturating_mul(f as u64);
                }
            }
            Transform::Compress(f) => {
                for r in &mut out {
                    r.issue_ns /= f as u64;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::format::TraceSpec;

    fn rec(stream: usize, issue_ns: u64) -> TraceRecord {
        TraceRecord {
            kernel: KernelClass::Gemm,
            n: 512,
            precision: Precision::F16,
            sparsity: SparsityMode::Dense,
            stream,
            issue_ns,
        }
    }

    #[test]
    fn spellings_roundtrip() {
        for t in [
            Transform::Identity,
            Transform::PrecisionRewrite(Precision::Fp8),
            Transform::PrecisionRewrite(Precision::Bf16),
            Transform::SparsityEnable,
            Transform::StreamRemap(2),
            Transform::Dilate(4),
            Transform::Compress(1024),
        ] {
            assert_eq!(Transform::parse(&t.name()), Some(t), "{}", t.name());
        }
        for bad in [
            "reverse",
            "precision_rewrite:int4",
            "stream_remap:0",
            "stream_remap:17",
            "dilate:0",
            "dilate:4096",
            "compress:-1",
            "dilate:2.5",
            "",
        ] {
            assert_eq!(Transform::parse(bad), None, "{bad:?}");
        }
        // Aliases canonicalize in one round.
        let t = Transform::parse("precision_rewrite:e4m3").unwrap();
        assert_eq!(t.name(), "precision_rewrite:fp8");
    }

    #[test]
    fn rewrites_do_what_the_table_says() {
        let recs = vec![rec(0, 0), rec(1, 100), rec(0, 200)];
        let fp8 = Transform::PrecisionRewrite(Precision::Fp8).apply(&recs);
        assert!(fp8.iter().all(|r| r.precision == Precision::Fp8));

        let sp = Transform::SparsityEnable.apply(&recs);
        assert!(sp.iter().all(|r| r.sparsity == SparsityMode::SparseLhs));
        // ...but an spmm launch is left alone.
        let mut spmm = recs.clone();
        spmm[1].kernel = KernelClass::Spmm;
        let sp2 = Transform::SparsityEnable.apply(&spmm);
        assert_eq!(sp2[1].sparsity, SparsityMode::Dense);

        let d = Transform::Dilate(3).apply(&recs);
        assert_eq!(
            d.iter().map(|r| r.issue_ns).collect::<Vec<_>>(),
            vec![0, 300, 600]
        );
        let c = Transform::Compress(2).apply(&d);
        assert_eq!(
            c.iter().map(|r| r.issue_ns).collect::<Vec<_>>(),
            vec![0, 150, 300]
        );
    }

    #[test]
    fn every_transform_yields_a_valid_trace() {
        // Interleaved two-stream timeline whose merge order is hostile:
        // stream 1's launches land between stream 0's.
        let recs = vec![rec(0, 0), rec(1, 50), rec(0, 100), rec(1, 150)];
        for t in [
            Transform::Identity,
            Transform::PrecisionRewrite(Precision::Fp8),
            Transform::SparsityEnable,
            Transform::StreamRemap(1),
            Transform::StreamRemap(2),
            Transform::Dilate(1024),
            Transform::Compress(1024),
        ] {
            let out = t.apply(&recs);
            assert_eq!(out.len(), recs.len(), "{}", t.name());
            TraceSpec::from_records(out)
                .unwrap_or_else(|e| panic!("{}: {}", t.name(), e.msg));
        }
        // The remap actually merged the streams.
        let merged = Transform::StreamRemap(1).apply(&recs);
        assert!(merged.iter().all(|r| r.stream == 0));
        assert_eq!(
            merged.iter().map(|r| r.issue_ns).collect::<Vec<_>>(),
            vec![0, 50, 100, 150]
        );
    }
}
