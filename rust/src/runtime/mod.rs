//! PJRT runtime: loads the AOT'd HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only real-compute path — Python never runs at serve time.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod manifest;

/// Real PJRT executor: requires the external `xla` bindings.
#[cfg(feature = "pjrt")]
pub mod executor;

/// Std-only stub keeping the same API surface (default build; see
/// `executor_stub.rs` and the `pjrt` feature in Cargo.toml).
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use executor::{Executor, Input, LoadedEntry};
pub use manifest::{DType, EntrySpec, Manifest, TensorSpec};
