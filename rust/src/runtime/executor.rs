//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. All entries are lowered with
//! `return_tuple=True`, so outputs unwrap with `to_tuple1`.

use super::manifest::{DType, EntrySpec, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// One compiled entry.
pub struct LoadedEntry {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Typed input for execution.
pub enum Input {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LoadedEntry {
    /// Execute with raw buffers (one per input, row-major, matching the
    /// manifest specs). Returns the first (sole) output as f32.
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<f32>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: {} inputs given, {} expected",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            ));
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.spec.inputs)
            .enumerate()
            .map(|(i, (input, spec))| {
                let dims: Vec<i64> =
                    spec.shape.iter().map(|&d| d as i64).collect();
                let lit = match (input, spec.dtype) {
                    (Input::F32(v), DType::F32) => {
                        if v.len() != spec.elements() {
                            return Err(anyhow!(
                                "input {i}: {} elements, want {}",
                                v.len(),
                                spec.elements()
                            ));
                        }
                        xla::Literal::vec1(v).reshape(&dims)?
                    }
                    (Input::I32(v), DType::I32) => {
                        if v.len() != spec.elements() {
                            return Err(anyhow!(
                                "input {i}: {} elements, want {}",
                                v.len(),
                                spec.elements()
                            ));
                        }
                        xla::Literal::vec1(v).reshape(&dims)?
                    }
                    _ => return Err(anyhow!("input {i}: dtype mismatch")),
                };
                Ok(lit)
            })
            .collect::<Result<Vec<_>>>()?;

        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrap 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The executor: a PJRT CPU client plus lazily-compiled entries.
pub struct Executor {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    loaded: HashMap<String, LoadedEntry>,
}

impl Executor {
    /// Create from an artifacts directory (compiles nothing yet).
    pub fn new(artifacts_dir: &Path) -> Result<Executor> {
        let manifest = Manifest::load(artifacts_dir)
            .map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Executor { client, manifest, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return an entry by name.
    pub fn load(&mut self, name: &str) -> Result<&LoadedEntry> {
        if !self.loaded.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown entry {name:?}"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.path
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.loaded.insert(name.to_string(), LoadedEntry { spec, exe });
        }
        Ok(&self.loaded[name])
    }

    /// Convenience: run an entry with all-f32 inputs.
    pub fn run_f32(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let entry = self.load(name)?;
        let wrapped: Vec<Input> =
            inputs.iter().map(|v| Input::F32(v.clone())).collect();
        entry.run(&wrapped)
    }
}
