//! Offline stub for the PJRT executor (default build, `pjrt` feature
//! disabled).
//!
//! The real executor (`executor.rs`) links against the external `xla`
//! PJRT bindings, which are only available on machines with the vendored
//! toolchain. This stub keeps the exact API surface — `Executor`,
//! `LoadedEntry`, `Input` — so every caller (serve loop, CLI, examples,
//! integration tests) compiles unchanged; any attempt to actually
//! execute an artifact returns a structured error instead.
//!
//! The manifest still loads for real, so `mi300a-char list` and entry
//! introspection work without the feature.

use super::manifest::{EntrySpec, Manifest};
use std::fmt;
use std::path::Path;

/// Error type mirroring the real executor's `anyhow::Error` surface:
/// `Display`, `Debug`, and `std::error::Error`.
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn unavailable(what: &str) -> RuntimeError {
    RuntimeError(format!(
        "PJRT runtime unavailable for {what:?}: this binary was built \
         without the `pjrt` feature (rebuild with --features pjrt on a \
         machine with the xla toolchain)"
    ))
}

/// Typed input for execution (mirrors the real executor).
pub enum Input {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// One "compiled" entry. Never actually constructed by the stub, but
/// the type must exist for callers that name it.
pub struct LoadedEntry {
    pub spec: EntrySpec,
}

impl LoadedEntry {
    pub fn run(&self, _inputs: &[Input]) -> Result<Vec<f32>> {
        Err(unavailable(&self.spec.name))
    }
}

/// The stub executor: loads the manifest for real, refuses to execute.
pub struct Executor {
    pub manifest: Manifest,
}

impl Executor {
    /// Create from an artifacts directory (parses the manifest; no
    /// compilation happens in the stub).
    pub fn new(artifacts_dir: &Path) -> Result<Executor> {
        let manifest = Manifest::load(artifacts_dir)
            .map_err(|e| RuntimeError(format!("manifest: {e}")))?;
        Ok(Executor { manifest })
    }

    pub fn platform(&self) -> String {
        "stub (pjrt feature disabled)".to_string()
    }

    /// Always errors: compilation needs the PJRT client.
    pub fn load(&mut self, name: &str) -> Result<&LoadedEntry> {
        Err(unavailable(name))
    }

    /// Always errors: execution needs the PJRT client.
    pub fn run_f32(
        &mut self,
        name: &str,
        _inputs: &[Vec<f32>],
    ) -> Result<Vec<f32>> {
        Err(unavailable(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_refuses_execution_with_clear_error() {
        let dir = std::env::temp_dir().join("mi300a_stub_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","entries":[]}"#,
        )
        .unwrap();
        let mut exec = Executor::new(&dir).unwrap();
        assert!(exec.platform().contains("stub"));
        let err = exec.run_f32("gemm_fp8_128", &[]).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_surfaces_manifest_errors() {
        let dir = std::env::temp_dir().join("mi300a_stub_missing_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Executor::new(&dir).is_err());
    }
}
