//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (`artifacts/manifest.json`).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "float32" => Some(DType::F32),
            "int32" => Some(DType::I32),
            _ => None,
        }
    }
}

/// Shape + dtype of one input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT'd entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub path: PathBuf,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<EntrySpec>,
}

fn tensor_specs(v: &Json, key: &str) -> Result<Vec<TensorSpec>, String> {
    v.get(key)
        .and_then(|j| j.as_arr())
        .ok_or_else(|| format!("missing {key}"))?
        .iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(|j| j.as_arr())
                .ok_or("missing shape")?
                .iter()
                .map(|d| d.as_usize().ok_or("bad dim"))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = t
                .get("dtype")
                .and_then(|j| j.as_str())
                .and_then(DType::parse)
                .ok_or("bad dtype")?;
            Ok(TensorSpec { shape, dtype })
        })
        .collect::<Result<Vec<_>, &str>>()
        .map_err(|e| e.to_string())
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| e.to_string())?;
        if v.get("format").and_then(|j| j.as_str()) != Some("hlo-text") {
            return Err("manifest format is not hlo-text".into());
        }
        let entries = v
            .get("entries")
            .and_then(|j| j.as_arr())
            .ok_or("missing entries")?
            .iter()
            .map(|e| {
                Ok(EntrySpec {
                    name: e
                        .get("name")
                        .and_then(|j| j.as_str())
                        .ok_or("missing name")?
                        .to_string(),
                    path: dir.join(
                        e.get("path")
                            .and_then(|j| j.as_str())
                            .ok_or("missing path")?,
                    ),
                    sha256: e
                        .get("sha256")
                        .and_then(|j| j.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    inputs: tensor_specs(e, "inputs")?,
                    outputs: tensor_specs(e, "outputs")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Default artifacts dir: `$MI300A_CHAR_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MI300A_CHAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("mi300a_manifest_test");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","entries":[
              {"name":"gemm","path":"gemm.hlo.txt","sha256":"x",
               "inputs":[{"shape":[4,4],"dtype":"float32"},
                          {"shape":[4,4],"dtype":"int32"}],
               "outputs":[{"shape":[4,4],"dtype":"float32"}]}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("gemm").unwrap();
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(e.inputs[1].dtype, DType::I32);
        assert_eq!(e.inputs[0].elements(), 16);
        assert!(e.path.ends_with("gemm.hlo.txt"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = std::env::temp_dir().join("mi300a_manifest_bad");
        write_manifest(&dir, r#"{"format":"proto","entries":[]}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("gemm_fp8_128").is_some());
            for e in &m.entries {
                assert!(e.path.exists(), "artifact missing: {}", e.name);
            }
        }
    }
}
