//! Thread-per-connection serving (`--io-model threads`): the portable
//! fallback io model, and the reference implementation the epoll
//! reactor must match byte for byte.
//!
//! One OS thread per accepted connection over the shared
//! `Arc<Service>`; a pusher thread per watched submit forwards progress
//! frames from the job table's channel watcher. Request lines are read
//! through a [`MAX_LINE_BYTES`]-capped `read_until`, so an endless line
//! without a newline costs bounded memory and earns a typed
//! `bad_request` instead of an OOM. Finished connection threads are
//! reaped by *joining* them (each thread reports its id on a completion
//! channel drained in the accept loop), so a long-lived server holds
//! O(live-connections) handles — the old `retain(|h|
//! !h.is_finished())` dropped finished handles without joining and
//! still grew under churn between reaps.

use super::{line_cap_error, Dispatch, MAX_LINE_BYTES};
use crate::api::{LegacyCommand, Request, Response};
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Accept loop: spawn one handler thread per connection, joining
/// finished ones as their ids arrive on the completion channel.
pub(super) fn run<D: Dispatch>(
    listener: TcpListener,
    svc: Arc<D>,
    max_conns: Option<usize>,
) -> std::io::Result<()> {
    let (done_tx, done_rx) = mpsc::channel::<u64>();
    let mut conns: HashMap<u64, thread::JoinHandle<()>> = HashMap::new();
    let mut served = 0u64;
    for conn in listener.incoming() {
        let stream = conn?;
        let svc = Arc::clone(&svc);
        let done = done_tx.clone();
        let id = served;
        conns.insert(
            id,
            thread::spawn(move || {
                if let Err(e) = handle(&svc, stream) {
                    eprintln!("connection error: {e}");
                }
                // The send target outlives the thread (the accept loop
                // owns the receiver); failure only means the server is
                // already past its accept loop and about to join us.
                let _ = done.send(id);
            }),
        );
        // Reap by join: each finished handler's id is waiting on the
        // channel, and joining an exited thread is immediate.
        while let Ok(finished) = done_rx.try_recv() {
            if let Some(h) = conns.remove(&finished) {
                let _ = h.join();
            }
        }
        served += 1;
        if let Some(max) = max_conns {
            if served as usize >= max {
                break;
            }
        }
    }
    for (_, h) in conns {
        let _ = h.join();
    }
    // Dropping the service (last Arc) shuts its executor and job
    // workers down.
    Ok(())
}

/// Write one line under the shared writer lock (responses and pushed
/// progress frames share it, so lines never interleave mid-line).
fn write_line(
    writer: &Arc<Mutex<TcpStream>>,
    v: &Json,
) -> std::io::Result<()> {
    let mut guard = writer.lock().unwrap_or_else(|e| e.into_inner());
    writeln!(&mut *guard, "{v}")
}

/// Read one `\n`-terminated line of at most [`MAX_LINE_BYTES`] content
/// bytes. `Ok(None)` is EOF. `Err(line_too_long…)` means the cap
/// tripped: the caller answers the typed rejection after the rest of
/// the oversized line has been discarded here.
fn read_bounded_line<R: BufRead>(
    reader: &mut R,
    line: &mut Vec<u8>,
) -> std::io::Result<Option<bool>> {
    line.clear();
    let n = reader
        .by_ref()
        .take(MAX_LINE_BYTES as u64 + 1)
        .read_until(b'\n', line)?;
    if n == 0 {
        return Ok(None); // EOF
    }
    if line.last() != Some(&b'\n') && line.len() > MAX_LINE_BYTES {
        // Cap tripped mid-line: discard up to the newline (or EOF) in
        // bounded chunks so the rejection leaves the framing aligned.
        let mut chunk = Vec::with_capacity(64 << 10);
        loop {
            chunk.clear();
            let m = reader
                .by_ref()
                .take(64 << 10)
                .read_until(b'\n', &mut chunk)?;
            if m == 0 || chunk.last() == Some(&b'\n') {
                break;
            }
        }
        return Ok(Some(false)); // a line arrived but was over the cap
    }
    Ok(Some(true))
}

/// One connection: frame lines, route through the dispatcher, write
/// one response line per request line (plus pushed progress frames for
/// watched submits).
fn handle<D: Dispatch>(svc: &D, stream: TcpStream) -> std::io::Result<()> {
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = BufReader::new(stream);
    let mut pushers: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut line: Vec<u8> = Vec::new();
    loop {
        match read_bounded_line(&mut reader, &mut line)? {
            None => break, // EOF
            Some(false) => {
                write_line(&writer, &line_cap_error().to_json(None))?;
                continue;
            }
            Some(true) => {}
        }
        let text = match std::str::from_utf8(&line) {
            Ok(s) => s.trim(),
            Err(_) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "request line is not valid UTF-8",
                ))
            }
        };
        if text.is_empty() {
            continue;
        }
        if text.starts_with('{') {
            let (resp, id, watch) = dispatch_json(svc, text);
            write_line(&writer, &resp.to_json(id))?;
            if let Some(rx) = watch {
                // Forward progress frames for this submit. The receiver
                // closes at the job's terminal state; a write failure
                // just means the client went away.
                let w = Arc::clone(&writer);
                pushers.push(thread::spawn(move || {
                    while let Ok(view) = rx.recv() {
                        let frame = Response::Progress(view).to_json(id);
                        if write_line(&w, &frame).is_err() {
                            break;
                        }
                    }
                }));
            }
            // Reap pushers whose jobs already finished, so a long-lived
            // connection submitting many watched jobs does not
            // accumulate exited threads.
            pushers.retain(|h| !h.is_finished());
        } else {
            match crate::api::parse_legacy(text) {
                Ok(LegacyCommand::Quit) => break,
                Ok(LegacyCommand::Request(req)) => {
                    write_line(&writer, &svc.handle(&req).to_json(None))?
                }
                Err(e) => {
                    write_line(&writer, &Response::from(e).to_json(None))?
                }
            }
        }
    }
    // Drain the frame forwarders (each ends at its job's terminal
    // state) so "fully served" includes the pushes.
    for h in pushers {
        let _ = h.join();
    }
    Ok(())
}

/// Decode one JSON request line and route it, honoring the envelope's
/// `cache` flag; decode failures become typed error responses, still
/// tagged with the request's `id` whenever the envelope was readable
/// enough to salvage it. A top-level `submit` with `"progress":true`
/// additionally returns the job's watcher receiver for the caller to
/// forward.
fn dispatch_json<D: Dispatch>(
    svc: &D,
    text: &str,
) -> (
    Response,
    Option<u64>,
    Option<std::sync::mpsc::Receiver<crate::api::JobView>>,
) {
    let v = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (
                Response::from(crate::api::ApiError::bad_request(format!(
                    "unparseable request: {e}"
                ))),
                None,
                None,
            )
        }
    };
    match Request::decode(&v) {
        Ok((Request::Submit { spec, progress: true }, env)) => {
            let (resp, rx) = svc.submit_watched(&spec, &env);
            (resp, env.id, rx)
        }
        Ok((req, env)) => (svc.handle_env(&req, &env), env.id, None),
        Err((e, id)) => (Response::from(e), id, None),
    }
}
