//! The epoll io model (`--io-model epoll`, Linux default): one reactor
//! thread multiplexing every connection, request execution on a
//! [`TaskPool`], progress frames queued back to the reactor.
//!
//! ## Structure
//!
//! * **Tokens**: `0` is the listener, `1` the wake eventfd, connections
//!   count up from `2` (monotonic, never reused — a stale completion
//!   for a closed connection is simply ignored).
//! * **Reads**: level-triggered `EPOLLIN`; bytes accumulate in a
//!   per-connection buffer, complete lines move to that connection's
//!   request queue. A line over [`MAX_LINE_BYTES`] is replaced by a
//!   `TooLong` marker *in order* (the typed rejection is written in the
//!   line's response position) and the remainder discarded. `QUIT`
//!   (and EOF) stop reading; queued work still completes.
//! * **Execution**: at most **one in-flight request per connection**,
//!   dispatched to the shared pool — responses come back in request
//!   order exactly like the thread model's sequential loop, while
//!   different connections execute in parallel across the pool.
//!   Completions are queued to the reactor and flushed via an eventfd
//!   wake.
//! * **Progress push**: a watched submit registers a callback watcher
//!   ([`Dispatch::submit_watched_with`]) wrapping a [`Forwarder`]. The
//!   forwarder *buffers* frames until the reactor has written the
//!   submit's response line (a job can finish before its response is
//!   even queued), then goes live: each further frame is queued to the
//!   reactor and written when the socket allows. No thread per watched
//!   submit.
//! * **Writes**: per-connection bounded write buffer; `EPOLLOUT`
//!   interest only while bytes are pending (level-triggered `EPOLLOUT`
//!   with an empty buffer would spin). A consumer slower than
//!   [`MAX_WBUF_BYTES`] of backlog is disconnected.
//! * **Close**: a connection closes when it is quitting (QUIT/EOF/
//!   error) *and* fully served — no in-flight request, no queued
//!   requests, no live watchers, no unflushed bytes — matching the
//!   thread model's "handler returned and pushers drained".
//!
//! The reactor itself never parses JSON or runs the engine; its work
//! per event is O(bytes moved).

use super::sys::{
    Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT,
};
use super::{line_cap_error, Dispatch, MAX_LINE_BYTES};
use crate::api::{JobView, LegacyCommand, Request, Response};
use crate::util::json::Json;
use crate::util::pool::TaskPool;
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::{Arc, Mutex};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Backpressure: past this many decoded-but-unexecuted request lines,
/// the reactor stops reading a connection (drops `EPOLLIN`) until the
/// queue drains — the bound the thread model gets implicitly from its
/// one-line-at-a-time loop.
const MAX_PIPELINED: usize = 1024;
/// Slow-consumer bound: a connection whose unflushed output exceeds
/// this is disconnected rather than buffered without limit.
const MAX_WBUF_BYTES: usize = 8 << 20;
/// Per-syscall read chunk.
const READ_CHUNK: usize = 64 << 10;

/// One framed unit from a connection, queued in arrival order.
enum QItem {
    /// A complete, cap-respecting, non-empty request line.
    Line(String),
    /// Placeholder for a line over the cap: answered with the typed
    /// rejection in this position.
    TooLong,
}

/// Cross-thread completions, queued by pool workers and job watchers,
/// drained by the reactor on an eventfd wake.
enum Event {
    /// A dispatched request finished: its response line (None only for
    /// the defensive legacy-QUIT arm) and, for an accepted watched
    /// submit, the forwarder to bring live.
    Done {
        token: u64,
        line: Option<String>,
        forwarder: Option<Arc<Forwarder>>,
    },
    /// A live forwarder's progress frame.
    Frame { token: u64, id: Option<u64>, view: JobView },
}

struct Shared {
    queue: Mutex<VecDeque<Event>>,
    wake: EventFd,
}

impl Shared {
    fn push(&self, ev: Event) {
        self.queue
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push_back(ev);
        self.wake.signal();
    }
}

enum FwdState {
    /// Frames arriving before the submit's response line is written
    /// (the job table delivers the queued snapshot synchronously at
    /// registration, and a fast job can finish entirely in between).
    Buffering(Vec<JobView>),
    Live,
}

/// The reactor-side watcher for one watched submit: job-table
/// callbacks land here (on job-worker threads) and are turned into
/// ordered [`Event::Frame`]s for the submitting connection.
struct Forwarder {
    token: u64,
    /// The submitting request's `id`, echoed on every frame.
    id: Option<u64>,
    shared: Arc<Shared>,
    state: Mutex<FwdState>,
}

impl Forwarder {
    fn on_frame(&self, view: JobView) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        match &mut *st {
            FwdState::Buffering(buf) => buf.push(view),
            // Queue while holding the state lock so frames from
            // different job-worker threads cannot reorder between the
            // state check and the queue push.
            FwdState::Live => self.shared.push(Event::Frame {
                token: self.token,
                id: self.id,
                view,
            }),
        }
    }

    /// Flip to live, returning everything buffered so far (written by
    /// the reactor immediately after the submit's response line).
    fn go_live(&self) -> Vec<JobView> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        match std::mem::replace(&mut *st, FwdState::Live) {
            FwdState::Buffering(buf) => buf,
            FwdState::Live => Vec::new(),
        }
    }
}

struct Conn {
    stream: TcpStream,
    /// Unframed inbound bytes (bounded by the line cap + one chunk).
    rbuf: Vec<u8>,
    /// Unflushed outbound bytes (bounded by [`MAX_WBUF_BYTES`]).
    wbuf: VecDeque<u8>,
    /// Framed lines awaiting dispatch, in arrival order.
    reqq: VecDeque<QItem>,
    /// Whether a request line is currently executing on the pool (at
    /// most one per connection — the ordering guarantee).
    inflight: bool,
    /// Live progress watchers whose terminal frame has not been
    /// written yet; the connection is not "fully served" before 0.
    watchers: usize,
    /// No more reads: QUIT or EOF seen. Queued work still completes.
    quitting: bool,
    /// Mid-oversized-line: drop bytes until the next newline.
    discarding: bool,
    /// The connection failed (io error / slow consumer / hangup):
    /// close as soon as the event is processed.
    dead: bool,
    /// Currently-registered epoll interest bits.
    interest: u32,
}

/// Reactor accept-and-serve loop; returns after `max_conns` accepted
/// connections have been fully served (None = forever).
pub(super) fn run<D: Dispatch>(
    listener: TcpListener,
    svc: Arc<D>,
    max_conns: Option<usize>,
) -> io::Result<()> {
    // Declaration order is drop order in reverse: the pool drops first
    // (joins in-flight request tasks, so nothing touches `svc` or
    // `shared` from a pool worker afterwards), then `svc` (its job
    // workers stop, so no more watcher callbacks), then `shared` and
    // the epoll fd close.
    let epoll = Epoll::new()?;
    let shared = Arc::new(Shared {
        queue: Mutex::new(VecDeque::new()),
        wake: EventFd::new()?,
    });
    let svc = svc;
    let pool = TaskPool::new(crate::util::pool::default_workers());

    listener.set_nonblocking(true)?;
    epoll.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
    epoll.add(shared.wake.raw(), EPOLLIN, TOKEN_WAKE)?;

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut accepted = 0usize;
    let mut accepting = true;
    let mut events = vec![EpollEvent { events: 0, token: 0 }; 256];
    let mut scratch = vec![0u8; READ_CHUNK];

    loop {
        let n = epoll.wait(&mut events, -1)?;
        for slot in 0..n {
            let ev = events[slot];
            match ev.token {
                TOKEN_LISTENER => {
                    accept_ready(
                        &listener,
                        &epoll,
                        &mut conns,
                        &mut next_token,
                        &mut accepted,
                        &mut accepting,
                        max_conns,
                    )?;
                }
                TOKEN_WAKE => {
                    shared.wake.drain();
                    loop {
                        let queued = {
                            let mut q = shared
                                .queue
                                .lock()
                                .unwrap_or_else(|e| e.into_inner());
                            q.pop_front()
                        };
                        let Some(event) = queued else { break };
                        handle_completion(
                            event, &mut conns, &epoll, &svc, &pool, &shared,
                        );
                    }
                }
                token => {
                    if let Some(conn) = conns.get_mut(&token) {
                        if ev.events & (EPOLLERR | EPOLLHUP) != 0 {
                            conn.dead = true;
                        }
                        if ev.events & EPOLLIN != 0 && !conn.dead {
                            read_ready(conn, &mut scratch);
                        }
                        if ev.events & EPOLLOUT != 0 && !conn.dead {
                            flush(conn);
                        }
                        pump(conn, token, &svc, &pool, &shared);
                    }
                    settle(&epoll, &mut conns, token);
                }
            }
        }
        if !accepting && conns.is_empty() {
            break;
        }
    }
    Ok(())
}

/// Accept until `WouldBlock`; after `max_conns` accepts, deregister the
/// listener so the loop can wind down once live connections finish.
fn accept_ready(
    listener: &TcpListener,
    epoll: &Epoll,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    accepted: &mut usize,
    accepting: &mut bool,
    max_conns: Option<usize>,
) -> io::Result<()> {
    while *accepting {
        match listener.accept() {
            Ok((stream, _)) => {
                *accepted += 1;
                let token = *next_token;
                *next_token += 1;
                if stream.set_nonblocking(true).is_ok()
                    && epoll.add(stream.as_raw_fd(), EPOLLIN, token).is_ok()
                {
                    conns.insert(
                        token,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf: VecDeque::new(),
                            reqq: VecDeque::new(),
                            inflight: false,
                            watchers: 0,
                            quitting: false,
                            discarding: false,
                            dead: false,
                            interest: EPOLLIN,
                        },
                    );
                }
                if max_conns.map_or(false, |m| *accepted >= m) {
                    *accepting = false;
                    let _ = epoll.delete(listener.as_raw_fd());
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Drain the socket into the line framer (one bounded chunk at a time
/// so an oversized line never accumulates more than a chunk).
fn read_ready(conn: &mut Conn, scratch: &mut [u8]) {
    while !conn.quitting && !conn.dead {
        match conn.stream.read(scratch) {
            Ok(0) => {
                // Match BufReader::lines: a final partial line without
                // a newline is still a request.
                if !conn.discarding && !conn.rbuf.is_empty() {
                    conn.rbuf.push(b'\n');
                    extract_lines(conn);
                }
                conn.quitting = true;
                conn.rbuf.clear();
                break;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&scratch[..n]);
                extract_lines(conn);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                eprintln!("connection error: {e}");
                conn.dead = true;
                break;
            }
        }
    }
}

/// Move complete lines from `rbuf` to the request queue, enforcing the
/// line cap and the QUIT/empty-line/UTF-8 framing rules.
fn extract_lines(conn: &mut Conn) {
    loop {
        if conn.quitting {
            conn.rbuf.clear();
            return;
        }
        let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') else {
            if !conn.discarding && conn.rbuf.len() > MAX_LINE_BYTES {
                // Cap tripped mid-line: queue the rejection in this
                // line's position, then discard to the newline.
                conn.reqq.push_back(QItem::TooLong);
                conn.discarding = true;
            }
            if conn.discarding {
                conn.rbuf.clear();
            }
            return;
        };
        let line: Vec<u8> = conn.rbuf.drain(..=pos).collect();
        if conn.discarding {
            // The tail of an oversized line; its rejection is already
            // queued.
            conn.discarding = false;
            continue;
        }
        let content = &line[..line.len() - 1];
        if content.len() > MAX_LINE_BYTES {
            conn.reqq.push_back(QItem::TooLong);
            continue;
        }
        match std::str::from_utf8(content) {
            Ok(s) => {
                let text = s.trim();
                if text.is_empty() {
                    continue;
                }
                if text == "QUIT" || text == "quit" {
                    conn.quitting = true;
                    conn.rbuf.clear();
                    return;
                }
                conn.reqq.push_back(QItem::Line(text.to_string()));
            }
            Err(_) => {
                eprintln!(
                    "connection error: request line is not valid UTF-8"
                );
                conn.dead = true;
                return;
            }
        }
    }
}

/// Dispatch the connection's next queued line if none is in flight —
/// the one-at-a-time rule that keeps responses in request order.
fn pump<D: Dispatch>(
    conn: &mut Conn,
    token: u64,
    svc: &Arc<D>,
    pool: &TaskPool,
    shared: &Arc<Shared>,
) {
    while !conn.inflight && !conn.dead {
        match conn.reqq.pop_front() {
            Some(QItem::TooLong) => {
                let line = line_cap_error().to_json(None).to_string();
                queue_line(conn, &line);
            }
            Some(QItem::Line(text)) => {
                conn.inflight = true;
                let svc = Arc::clone(svc);
                let shared = Arc::clone(shared);
                pool.execute(move || {
                    let (line, forwarder) =
                        process_line(&svc, &shared, token, &text);
                    shared.push(Event::Done { token, line, forwarder });
                });
                break;
            }
            None => break,
        }
    }
}

/// Runs on a pool worker: parse, route through the service, serialize.
/// A watched submit registers its forwarder (buffering) and hands it
/// back for the reactor to bring live after the response line.
fn process_line<D: Dispatch>(
    svc: &D,
    shared: &Arc<Shared>,
    token: u64,
    text: &str,
) -> (Option<String>, Option<Arc<Forwarder>>) {
    if text.starts_with('{') {
        let v = match Json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                let resp =
                    Response::from(crate::api::ApiError::bad_request(
                        format!("unparseable request: {e}"),
                    ));
                return (Some(resp.to_json(None).to_string()), None);
            }
        };
        match Request::decode(&v) {
            Ok((Request::Submit { spec, progress: true }, env)) => {
                let fwd = Arc::new(Forwarder {
                    token,
                    id: env.id,
                    shared: Arc::clone(shared),
                    state: Mutex::new(FwdState::Buffering(Vec::new())),
                });
                let cb = {
                    let fwd = Arc::clone(&fwd);
                    Box::new(move |view: JobView| fwd.on_frame(view))
                        as Box<dyn Fn(JobView) + Send>
                };
                let resp = svc.submit_watched_with(&spec, &env, cb);
                let accepted = matches!(resp, Response::Job(_));
                let line = resp.to_json(env.id).to_string();
                (Some(line), if accepted { Some(fwd) } else { None })
            }
            Ok((req, env)) => (
                Some(svc.handle_env(&req, &env).to_json(env.id).to_string()),
                None,
            ),
            Err((e, id)) => {
                (Some(Response::from(e).to_json(id).to_string()), None)
            }
        }
    } else {
        match crate::api::parse_legacy(text) {
            // QUIT is consumed by the framing layer; this arm is
            // defensive.
            Ok(LegacyCommand::Quit) => (None, None),
            Ok(LegacyCommand::Request(req)) => {
                (Some(svc.handle(&req).to_json(None).to_string()), None)
            }
            Err(e) => {
                (Some(Response::from(e).to_json(None).to_string()), None)
            }
        }
    }
}

/// Apply one cross-thread completion to its connection (ignored if the
/// connection already closed — tokens are never reused).
fn handle_completion<D: Dispatch>(
    event: Event,
    conns: &mut HashMap<u64, Conn>,
    epoll: &Epoll,
    svc: &Arc<D>,
    pool: &TaskPool,
    shared: &Arc<Shared>,
) {
    match event {
        Event::Done { token, line, forwarder } => {
            let Some(conn) = conns.get_mut(&token) else { return };
            conn.inflight = false;
            if let Some(line) = line {
                queue_line(conn, &line);
            }
            if let Some(fwd) = forwarder {
                // Response line first, then the buffered frames, then
                // live — preserving the thread model's byte order (the
                // snapshot frame never precedes the submit response).
                let buffered = fwd.go_live();
                let mut terminal = false;
                for view in buffered {
                    terminal |= view.state.terminal();
                    let frame =
                        Response::Progress(view).to_json(fwd.id).to_string();
                    queue_line(conn, &frame);
                }
                if !terminal {
                    conn.watchers += 1;
                }
            }
            pump(conn, token, svc, pool, shared);
            settle(epoll, conns, token);
        }
        Event::Frame { token, id, view } => {
            let Some(conn) = conns.get_mut(&token) else { return };
            let frame = Response::Progress(view).to_json(id).to_string();
            queue_line(conn, &frame);
            if view.state.terminal() && conn.watchers > 0 {
                conn.watchers -= 1;
            }
            settle(epoll, conns, token);
        }
    }
}

/// Append one response/frame line and flush what the socket will take
/// now; over-cap backlog marks the consumer dead.
fn queue_line(conn: &mut Conn, line: &str) {
    if conn.dead {
        return;
    }
    conn.wbuf.extend(line.as_bytes().iter().copied());
    conn.wbuf.push_back(b'\n');
    flush(conn);
    if conn.wbuf.len() > MAX_WBUF_BYTES {
        eprintln!(
            "connection error: write backlog over {MAX_WBUF_BYTES} bytes \
             (slow consumer)"
        );
        conn.dead = true;
    }
}

/// Write buffered bytes until the socket would block (or fails).
fn flush(conn: &mut Conn) {
    while !conn.wbuf.is_empty() {
        let (front, _) = conn.wbuf.as_slices();
        match conn.stream.write(front) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => {
                conn.wbuf.drain(..n);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                eprintln!("connection error: {e}");
                conn.dead = true;
                return;
            }
        }
    }
}

/// Recompute a connection's epoll interest, and close it once it is
/// dead or fully served after QUIT/EOF.
fn settle(epoll: &Epoll, conns: &mut HashMap<u64, Conn>, token: u64) {
    let close = {
        let Some(conn) = conns.get_mut(&token) else { return };
        let fully_served = !conn.inflight
            && conn.reqq.is_empty()
            && conn.watchers == 0
            && conn.wbuf.is_empty();
        if conn.dead || (conn.quitting && fully_served) {
            true
        } else {
            let mut want = 0u32;
            if !conn.quitting && conn.reqq.len() < MAX_PIPELINED {
                want |= EPOLLIN;
            }
            if !conn.wbuf.is_empty() {
                want |= EPOLLOUT;
            }
            if want != conn.interest {
                let _ =
                    epoll.modify(conn.stream.as_raw_fd(), want, token);
                conn.interest = want;
            }
            false
        }
    };
    if close {
        if let Some(conn) = conns.remove(&token) {
            let _ = epoll.delete(conn.stream.as_raw_fd());
            // Dropping the stream closes the fd; in-flight completions
            // for this token are ignored when they arrive.
        }
    }
}
