//! `mi300a-char serve` — a thin TCP transport over [`crate::api`].
//!
//! Framing: one message per line. A line starting with `{` is a
//! versioned JSON request (DESIGN.md §6); its optional `id` is echoed on
//! the response so clients can pipeline many requests on one
//! connection, its optional `"cache":false` envelope flag bypasses the
//! service's result cache, its optional `"backend"` envelope key
//! selects the execution backend for scenario-backed requests
//! (DESIGN.md §6.8; `serve --backend` / [`serve_opts`] set the
//! instance default), and a `batch` request answers its items in one
//! envelope. Any other non-empty line goes through the legacy text
//! shim (`SIM`/`PLAN`/`SPARSITY`/`RUN`/`LIST`/`CONFIG`/`STATS`/
//! `BACKENDS`/`QUIT`), which desugars into the same typed requests —
//! the response line is byte-identical to the JSON form without an
//! `id` (enforced by tests/serve_integration.rs). Request lines are
//! capped at [`MAX_LINE_BYTES`]; a longer line is answered with a
//! typed `bad_request` (and the rest of the line is discarded) instead
//! of growing the server's memory without bound.
//!
//! ## Progress push (DESIGN.md §6.7)
//!
//! A top-level `submit` with `"progress":true` registers a watcher on
//! the job atomically with the enqueue. After the `job` response line,
//! the connection pushes `{"type":"progress",…}` frames — each tagged
//! with the *submitting request's* `id` — interleaved with other
//! response lines as the job advances: one snapshot at registration (so
//! at least one frame always arrives), one on the queued→running
//! transition, one per completed sweep point, and one at the terminal
//! state, after which the stream of frames ends. Every line is written
//! atomically (one writer lock per connection in the threads model, the
//! single reactor thread in the epoll model), so pipelined responses
//! and frames never interleave mid-line; clients attribute frames by
//! `id` and skip the rest (the native [`crate::api::Client`] does this
//! automatically).
//!
//! All business logic lives in [`crate::api::Service`]: this module
//! only accepts connections, frames lines, and serializes responses.
//! Repeat requests across *all* connections share the service's result
//! cache ([`crate::api::cache`]); start with [`serve_with`] and
//! [`crate::api::CachePolicy::disabled`] (the CLI's `--no-cache`) for
//! measurement runs. Jobs are service-wide too: a job submitted on one
//! connection can be polled, fetched, or cancelled from any other.
//!
//! ## Concurrency
//!
//! Two io models ([`IoModel`], the CLI's `serve --io-model`) share one
//! protocol implementation; the model is observable only through
//! resource usage and benchmarks (`mi300a-char loadgen`,
//! `docs/performance.md`), never through response bytes:
//!
//! * **`epoll`** (Linux, the default there): a single reactor thread
//!   multiplexes every connection through a readiness-based event loop
//!   (raw `epoll` via std-only syscalls — no external deps). An idle
//!   connection costs one fd plus bounded buffers instead of an OS
//!   thread stack, which is what lets one node hold thousands of
//!   job-polling clients. Request execution never runs on the reactor:
//!   each decoded line is dispatched to a shared
//!   [`crate::util::pool::TaskPool`], so a slow DES point parks a pool
//!   worker — the way a long kernel occupies one ACE queue — while the
//!   reactor keeps accepting, framing, and flushing. Progress frames
//!   are queued to the reactor (an eventfd wake) and written when the
//!   socket is writable; a watched submit costs no thread.
//! * **`threads`** (every platform, the non-Linux default): one OS
//!   thread per connection over the shared `Arc<Service>`, with a
//!   pusher thread per watched submit. Finished connection threads are
//!   reaped by join (a completion channel), so a long-lived server
//!   holds O(live-connections) state.
//!
//! In both models `sim`/`plan`/`sparsity`/`scenario` requests are pure
//! functions of the immutable config and scale across cores, the way
//! the paper's ACEs scale independent streams. The one non-`Sync`
//! resource — the PJRT executor — is isolated inside the service on a
//! single mpsc worker thread, so `run` requests serialize through it
//! (exactly like launches serialize through a command lane) without
//! blocking the simulator paths. Responses are deterministic per
//! request for a fixed config/seed, so concurrent clients observe
//! byte-identical answers to a single client — at any connection count,
//! under either io model.

#[cfg(target_os = "linux")]
mod reactor;
#[cfg(target_os = "linux")]
mod sys;
mod threads;

use crate::api::{
    CachePolicy, JobView, Request, RequestEnvelope, Response, ScenarioSpec,
    Service,
};
use crate::config::Config;
use std::net::TcpListener;
use std::sync::{mpsc, Arc};

/// What the io models need from a request handler: the four entry
/// points [`Service`] exposes to its transports. The serve loops are
/// generic over this trait rather than over `Service` itself, so a
/// [`crate::cluster::Coordinator`] (DESIGN.md §6.9) serves through the
/// identical framing, line-cap, and progress-push machinery under
/// either io model — transports cannot tell a coordinator from a
/// standalone service, and neither can clients.
pub trait Dispatch: Send + Sync + 'static {
    /// Answer one typed request under the default envelope (the legacy
    /// text shim's path).
    fn handle(&self, req: &Request) -> Response;

    /// Answer one typed request honoring the envelope options (`cache`
    /// escape hatch, `backend` selector).
    fn handle_env(&self, req: &Request, env: &RequestEnvelope) -> Response;

    /// Enqueue a watched submit, returning the response plus — when the
    /// job was accepted — the progress-frame receiver (the threads io
    /// model forwards it from a pusher thread).
    fn submit_watched(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
    ) -> (Response, Option<mpsc::Receiver<JobView>>);

    /// Enqueue a watched submit with a callback watcher (the epoll io
    /// model's thread-free progress push).
    fn submit_watched_with(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
        on_frame: Box<dyn Fn(JobView) + Send>,
    ) -> Response;
}

impl Dispatch for Service {
    fn handle(&self, req: &Request) -> Response {
        Service::handle(self, req)
    }

    fn handle_env(&self, req: &Request, env: &RequestEnvelope) -> Response {
        Service::handle_env(self, req, env)
    }

    fn submit_watched(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
    ) -> (Response, Option<mpsc::Receiver<JobView>>) {
        Service::submit_watched(self, spec, env)
    }

    fn submit_watched_with(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
        on_frame: Box<dyn Fn(JobView) + Send>,
    ) -> Response {
        Service::submit_watched_with(self, spec, env, on_frame)
    }
}

/// Maximum accepted request-line length in bytes (1 MiB), newline
/// excluded. A longer line is answered with a typed `bad_request` and
/// discarded up to its newline; the connection stays usable. Both io
/// models enforce the same cap (tests/serve_integration.rs).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// How a serving instance waits for socket readiness (the CLI's
/// `serve --io-model {epoll,threads}`). The protocol — framing,
/// response bytes, progress-frame order, the legacy shim — is identical
/// under both; only the concurrency structure differs (see the module
/// docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoModel {
    /// Readiness-based event loop over raw `epoll` (Linux only; the
    /// default there): one reactor thread, execution on a task pool,
    /// O(1) threads regardless of connection count.
    Epoll,
    /// One OS thread per connection (available everywhere; the default
    /// off Linux).
    Threads,
}

impl IoModel {
    pub const ALL: [IoModel; 2] = [IoModel::Epoll, IoModel::Threads];

    /// Wire/CLI spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            IoModel::Epoll => "epoll",
            IoModel::Threads => "threads",
        }
    }

    /// Inverse of [`IoModel::as_str`].
    pub fn parse(s: &str) -> Option<IoModel> {
        IoModel::ALL.iter().copied().find(|m| m.as_str() == s)
    }

    /// Whether this model can run on the compiled-for platform.
    pub fn available(self) -> bool {
        match self {
            IoModel::Epoll => cfg!(target_os = "linux"),
            IoModel::Threads => true,
        }
    }

    /// The platform default: `epoll` on Linux, `threads` elsewhere.
    pub fn default_for_platform() -> IoModel {
        if cfg!(target_os = "linux") {
            IoModel::Epoll
        } else {
            IoModel::Threads
        }
    }
}

/// Serve on `addr` (e.g. "127.0.0.1:0") with the default cache policy;
/// returns after `max_conns` connections have been accepted and fully
/// served (None = forever). Prints the bound address on stdout so
/// callers/tests can discover the ephemeral port.
pub fn serve(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
) -> std::io::Result<()> {
    serve_with(cfg, addr, max_conns, CachePolicy::default())
}

/// [`serve`] with an explicit result-cache policy (`--no-cache` passes
/// [`CachePolicy::disabled`]).
pub fn serve_with(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
    policy: CachePolicy,
) -> std::io::Result<()> {
    serve_opts(cfg, addr, max_conns, policy, crate::backend::DEFAULT)
}

/// [`serve_with`] plus the instance's default execution backend
/// (the CLI's `serve --backend`; DESIGN.md §6.8) — what answers
/// requests that carry no `"backend"` selector of their own.
pub fn serve_opts(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
    policy: CachePolicy,
    default_backend: crate::backend::BackendId,
) -> std::io::Result<()> {
    serve_io(
        cfg,
        addr,
        max_conns,
        policy,
        default_backend,
        IoModel::default_for_platform(),
    )
}

/// [`serve_opts`] with an explicit io model (the CLI's
/// `serve --io-model`). Requesting [`IoModel::Epoll`] off Linux is an
/// `Unsupported` error rather than a silent fallback.
pub fn serve_io(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
    policy: CachePolicy,
    default_backend: crate::backend::BackendId,
    io: IoModel,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("serving on {}", listener.local_addr()?);
    let svc =
        Arc::new(Service::with_default_backend(cfg, policy, default_backend));
    serve_on(listener, svc, max_conns, io)
}

/// Serve an already-bound listener with an already-built dispatcher —
/// the embedding entry point ([`crate::loadgen`] self-hosts a
/// `Service` through it so it can learn the ephemeral port without
/// parsing stdout; [`crate::cluster`] serves its `Coordinator` the
/// same way). Returns after `max_conns` connections have been accepted
/// and fully served (None = forever).
pub fn serve_on<D: Dispatch>(
    listener: TcpListener,
    svc: Arc<D>,
    max_conns: Option<usize>,
    io: IoModel,
) -> std::io::Result<()> {
    match io {
        IoModel::Threads => threads::run(listener, svc, max_conns),
        IoModel::Epoll => {
            #[cfg(target_os = "linux")]
            {
                reactor::run(listener, svc, max_conns)
            }
            #[cfg(not(target_os = "linux"))]
            {
                drop((listener, svc, max_conns));
                Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "the epoll io model requires Linux; \
                     use --io-model threads",
                ))
            }
        }
    }
}

/// The typed rejection for a request line over [`MAX_LINE_BYTES`],
/// shared by both io models so the response bytes match.
pub(crate) fn line_cap_error() -> Response {
    Response::from(crate::api::ApiError::bad_request(format!(
        "request line longer than {MAX_LINE_BYTES} bytes \
         (the serve framing cap)"
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_model_spellings_round_trip() {
        for m in IoModel::ALL {
            assert_eq!(IoModel::parse(m.as_str()), Some(m));
        }
        assert_eq!(IoModel::parse("select"), None);
        assert!(IoModel::Threads.available());
        assert!(IoModel::default_for_platform().available());
        #[cfg(target_os = "linux")]
        assert_eq!(IoModel::default_for_platform(), IoModel::Epoll);
    }

    #[test]
    fn line_cap_rejection_is_a_typed_bad_request() {
        let line = line_cap_error().to_json(None).to_string();
        assert!(line.contains("\"bad_request\""), "{line}");
        assert!(line.contains(&MAX_LINE_BYTES.to_string()), "{line}");
    }
}
