//! Raw `epoll`/`eventfd` bindings (Linux only; offline build: no libc
//! crate, so the handful of syscall wrappers the reactor needs are
//! declared here against the C symbols std already links).
//!
//! Everything is wrapped in owning types ([`Epoll`], [`EventFd`]) whose
//! `Drop` closes the fd; the only raw surface the reactor touches is
//! the `u64` token carried in each event.

use std::io;
use std::os::unix::io::RawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;

/// Mirror of the kernel's `struct epoll_event`. Packed on x86-64 (the
/// kernel ABI there has no padding between the fields); read the fields
/// by value only — never take a reference into one.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Debug, Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    pub token: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(
        epfd: i32,
        op: i32,
        fd: i32,
        event: *mut EpollEvent,
    ) -> i32;
    fn epoll_wait(
        epfd: i32,
        events: *mut EpollEvent,
        maxevents: i32,
        timeout: i32,
    ) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An owned epoll instance.
pub struct Epoll {
    epfd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, token };
        cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Register `fd` with a level-triggered interest set and a token
    /// returned verbatim in its events.
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change an already-registered fd's interest set.
    pub fn modify(
        &self,
        fd: RawFd,
        events: u32,
        token: u64,
    ) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Deregister `fd` (idempotent enough for shutdown paths: the
    /// caller ignores the error if the fd already closed).
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block until at least one registered fd is ready (negative
    /// `timeout_ms` = forever), retrying on `EINTR`. Returns how many
    /// of `events`' leading entries were filled.
    pub fn wait(
        &self,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    events.as_mut_ptr(),
                    events.len() as i32,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.epfd);
        }
    }
}

/// An owned nonblocking eventfd: the reactor's cross-thread wake-up.
/// Pool workers and job-table watcher callbacks `signal()` it after
/// queuing a completion event; the reactor `drain()`s it when its token
/// fires.
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Add 1 to the counter, waking any epoll_wait watching it. A full
    /// counter (`EAGAIN`) is fine — the fd is already readable, which
    /// is all a wake-up needs.
    pub fn signal(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, one.to_ne_bytes().as_ptr(), 8);
        }
    }

    /// Reset the counter so level-triggered epoll stops reporting it.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        unsafe {
            read(self.fd, buf.as_mut_ptr(), 8);
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eventfd_signals_and_drains_through_epoll() {
        let ep = Epoll::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.raw(), EPOLLIN, 42).unwrap();
        let mut events = [EpollEvent { events: 0, token: 0 }; 4];
        // Nothing signalled yet: a zero-timeout wait returns empty.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        ev.signal();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let got = events[0];
        assert_eq!(got.token, 42);
        assert_ne!(got.events & EPOLLIN, 0);
        // Draining resets the level-triggered readiness.
        ev.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        ep.delete(ev.raw()).unwrap();
    }
}
