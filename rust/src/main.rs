//! `mi300a-char` — leader entrypoint and CLI.
//!
//! Subcommands:
//!   repro <id|all>      regenerate a paper table/figure (DESIGN.md §5)
//!   run <entry>         execute one AOT'd artifact via PJRT
//!   plan                show a coordinator execution plan for a pool
//!   config              dump the active configuration
//!   list                list experiments and artifacts

use mi300a_char::config::Config;
use mi300a_char::coordinator::{Coordinator, Objective};
use mi300a_char::experiments;
use mi300a_char::isa::Precision;
use mi300a_char::runtime::{Executor, Manifest};
use mi300a_char::sim::KernelDesc;
use mi300a_char::util::cli::Args;
use mi300a_char::util::pool;

const USAGE: &str = "\
mi300a-char — execution-centric MI300A characterization (simulated substrate)

USAGE:
  mi300a-char repro <id|all> [--seed N] [--set section.field=value]
                             [--json] [--out-dir DIR] [--threads N]
  mi300a-char run <entry> [--artifacts DIR]
  mi300a-char plan [--objective latency|throughput|isolation]
                   [--streams N] [--size N] [--precision P]
  mi300a-char serve [--addr HOST:PORT] [--max-conns N]
  mi300a-char config [--set section.field=value]
  mi300a-char list

Experiment ids: table1 table2 table3 fig2..fig16 (see DESIGN.md §5).
";

fn build_config(args: &Args) -> Config {
    let mut cfg = if let Some(path) = args.get("config") {
        Config::load(std::path::Path::new(path)).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        })
    } else {
        Config::mi300a()
    };
    cfg.seed = args.get_u64("seed", cfg.seed);
    if let Some(spec) = args.get("set") {
        if let Err(e) = cfg.set(spec) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    cfg
}

fn cmd_repro(args: &Args) -> i32 {
    let cfg = build_config(args);
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let out_dir = args.get("out-dir").map(std::path::PathBuf::from);
    if let Some(d) = &out_dir {
        let _ = std::fs::create_dir_all(d);
    }
    let emit = |id: &str, report: &experiments::ExperimentReport| {
        if args.flag("json") {
            println!("{}", report.json.to_string_pretty());
        } else {
            println!("{}", report.render());
        }
        if let Some(d) = &out_dir {
            let _ = std::fs::write(
                d.join(format!("{id}.json")),
                report.json.to_string_pretty(),
            );
            let _ = std::fs::write(
                d.join(format!("{id}.txt")),
                report.render(),
            );
        }
    };
    if which == "all" {
        // Drivers fan out across the pool; reports print in paper order
        // and are byte-identical to a serial run (--threads 1).
        let workers = args.get_usize("threads", pool::default_workers());
        for report in experiments::run_all(&cfg, workers) {
            emit(report.id, &report);
        }
        return 0;
    }
    match experiments::run(which, &cfg) {
        Some(report) => {
            emit(which, &report);
            0
        }
        None => {
            eprintln!("unknown experiment id {which:?}");
            2
        }
    }
}

fn cmd_run(args: &Args) -> i32 {
    let entry = match args.positional.first() {
        Some(e) => e.clone(),
        None => {
            eprintln!("run: missing <entry> (see `mi300a-char list`)");
            return 2;
        }
    };
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let mut exec = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("runtime: {e} (run `make artifacts` first)");
            return 1;
        }
    };
    let spec = match exec.manifest.get(&entry) {
        Some(s) => s.clone(),
        None => {
            eprintln!("unknown entry {entry:?}");
            return 2;
        }
    };
    // Deterministic inputs: same pattern the golden tests use.
    let inputs: Vec<Vec<f32>> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            (0..t.elements())
                .map(|j| ((j % (13 + i)) as f32 - 6.0) / 3.0)
                .collect()
        })
        .collect();
    let t0 = std::time::Instant::now();
    match exec.run_f32(&entry, &inputs) {
        Ok(out) => {
            let dt = t0.elapsed();
            let checksum: f32 = out.iter().sum();
            println!(
                "{entry}: {} outputs, checksum {checksum:.4}, {} ms \
                 (incl. compile)",
                out.len(),
                dt.as_millis()
            );
            0
        }
        Err(e) => {
            eprintln!("execute {entry}: {e}");
            1
        }
    }
}

fn cmd_plan(args: &Args) -> i32 {
    let cfg = build_config(args);
    let objective = match args.get_or("objective", "latency") {
        "latency" => Objective::LatencySensitive,
        "throughput" => Objective::ThroughputOriented,
        "isolation" => Objective::StrictIsolation,
        other => {
            eprintln!("unknown objective {other:?}");
            return 2;
        }
    };
    let n = args.get_usize("size", 512);
    let streams = args.get_usize("streams", 4);
    let p = Precision::parse(args.get_or("precision", "fp8"))
        .unwrap_or(Precision::Fp8);
    let pool = vec![KernelDesc::gemm(n, p).with_iters(100); streams];
    let coord = Coordinator::new(cfg, objective);
    let plan = coord.plan(&pool, true);
    println!("objective: {:?}", plan.objective);
    for (i, g) in plan.groups.iter().enumerate() {
        println!(
            "group {i}: {} kernels, {} streams, expected fairness {:.3}, \
             process isolation {}",
            g.kernels.len(),
            g.streams,
            g.expected_fairness,
            g.process_isolation
        );
        for k in &g.kernels {
            println!("  - {}", k.label());
        }
    }
    0
}

fn cmd_list(_args: &Args) -> i32 {
    println!("experiments:");
    for id in experiments::ALL_IDS {
        println!("  {id}");
    }
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for e in &m.entries {
                println!(
                    "  {} ({} inputs -> {} outputs)",
                    e.name,
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
        }
        Err(_) => println!(
            "artifacts: not built (run `make artifacts`); dir {}",
            dir.display()
        ),
    }
    0
}

fn main() {
    let args = Args::from_env(&["json", "verbose"]);
    let code = match args.subcommand.as_deref() {
        Some("repro") => cmd_repro(&args),
        Some("run") => cmd_run(&args),
        Some("plan") => cmd_plan(&args),
        Some("config") => {
            println!("{}", build_config(&args).to_json().to_string_pretty());
            0
        }
        Some("list") => cmd_list(&args),
        Some("serve") => {
            let cfg = build_config(&args);
            let addr = args.get_or("addr", "127.0.0.1:7300").to_string();
            let max = args.get("max-conns").map(|v| v.parse().unwrap_or(1));
            match mi300a_char::serve::serve(cfg, &addr, max) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("serve: {e}");
                    1
                }
            }
        }
        _ => {
            print!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}
