//! `mi300a-char` — leader entrypoint and CLI.
//!
//! Every subcommand is a thin presentation layer over
//! [`mi300a_char::api::Service`] — the same typed request/response core
//! the TCP serve loop speaks (DESIGN.md §6). No business logic lives
//! here.
//!
//! Subcommands:
//!
//! ```text
//!   repro <id|all>      regenerate a paper table/figure (DESIGN.md §5)
//!   run <entry>         execute one AOT'd artifact via PJRT
//!   plan                show a coordinator execution plan for a pool
//!   scenario            run a declarative ScenarioSpec sweep, locally
//!                       or as an async job with progress (--addr)
//!   replay              replay a recorded kernel-launch trace (JSON
//!                       lines) through the DES, optionally rewritten
//!                       by what-if transforms
//!   serve               serve the JSON-line protocol over TCP
//!                       (batching + result cache; --no-cache disables;
//!                       --io-model picks epoll or threads)
//!   loadgen             measure a serving instance (or a self-hosted
//!                       one) with the built-in load generator
//!   client <json>       send one JSON request to a serving instance
//!   config              dump the active configuration
//!   list                list experiments and artifacts
//! ```

use mi300a_char::api::{
    parse_objective, Ask, CachePolicy, Client, ErrorCode, Request,
    RequestEnvelope, Response, ScenarioSpec, Service, Shape,
};
use mi300a_char::backend::BackendId;
use mi300a_char::config::Config;
use mi300a_char::isa::Precision;
use mi300a_char::loadgen::{LoadgenOptions, Mix};
use mi300a_char::replay::{parse_jsonl, TraceSpec, Transform};
use mi300a_char::runtime::Manifest;
use mi300a_char::serve::IoModel;
use mi300a_char::util::cli::Args;
use mi300a_char::util::json::Json;
use mi300a_char::util::pool;

const USAGE: &str = "\
mi300a-char — execution-centric MI300A characterization (simulated substrate)

USAGE:
  mi300a-char repro <id|all> [--seed N] [--set section.field=value]
                             [--json] [--out-dir DIR] [--threads N]
  mi300a-char run <entry> [--artifacts DIR]
  mi300a-char plan [--objective latency|throughput|isolation]
                   [--streams N] [--size N] [--precision P]
                   [--backend des|analytic|auto]
  mi300a-char scenario [--spec FILE] [--ask sim|plan|sparsity]
                   [--size N] [--precision P] [--streams N] [--iters N]
                   [--shape homogeneous|imbalanced_pair|mixed_sparse|
                            spmm_mix|data_parallel|pipeline|halo]
                   [--devices N] [--topology fully_connected|ring]
                   [--small-size N] [--objective O] [--sparsity MODE]
                   [--sweep-size A,B,..] [--sweep-streams A,B,..]
                   [--sweep-precision A,B,..] [--sweep-iters A,B,..]
                   [--sweep-devices A,B,..]
                   [--backend des|analytic|auto] [--max-error X]
                   [--max-time-ms N] [--json] [--addr HOST:PORT]
  mi300a-char replay --trace FILE.jsonl [--transform T]
                   [--sweep-transform T,T,..]
                   [--backend des|analytic|auto]
                   [--chrome-trace OUT.json] [--json]
  mi300a-char serve [--addr HOST:PORT] [--max-conns N] [--no-cache]
                   [--backend des|analytic|auto] [--io-model epoll|threads]
                   [--coordinator --workers HOST:PORT,HOST:PORT,...]
  mi300a-char loadgen [--addr HOST:PORT] [--connections N]
                   [--warmup-ms N] [--duration-ms N]
                   [--mix hot|cold|mixed] [--io-model epoll|threads]
                   [--no-cache] [--backend des|analytic|auto]
  mi300a-char client <json-request> [--addr HOST:PORT]
  mi300a-char config [--set section.field=value]
  mi300a-char list

Experiment ids: table1 table2 table3 fig2..fig16 (see DESIGN.md §5 and
docs/experiments.md). The wire protocol (client/serve) is specified in
DESIGN.md §6 and docs/serving.md, e.g.:
  mi300a-char client '{\"v\":1,\"type\":\"sim\",\"n\":512,\"precision\":\"fp8\",\"streams\":4}'
Batches answer many requests in one envelope; `stats` reports the
serve-side result cache (add \"cache\":false to bypass it per request):
  mi300a-char client '{\"v\":1,\"type\":\"batch\",\"items\":[{\"type\":\"sparsity\",\"n\":512,\"streams\":4},{\"type\":\"stats\"}]}'
Scenario sweeps (DESIGN.md §6.6, docs/scenarios.md) run locally by
default; with --addr they submit as an async job and stream progress:
  mi300a-char scenario --size 512 --sweep-streams 1,2,4,8,16
  mi300a-char scenario --addr 127.0.0.1:7300 --ask sparsity --sweep-size 256,512,2048,8192
The load generator (docs/performance.md) self-hosts an ephemeral server
when no --addr is given and writes BENCH_serve.json (PERF.md):
  mi300a-char loadgen --connections 64 --duration-ms 2000 --mix mixed
Execution backends (DESIGN.md §6.8, docs/backends.md): --backend picks
the engine answering sim/plan/sparsity points (des = DES replay,
analytic = calibrated closed forms, ~100x faster per sim point;
auto = trust-region router, docs/auto_backend.md — analytic inside the
measured error envelope, DES elsewhere; with --max-error/--max-time-ms
a remote job refines its least-trusted answers on the DES, streaming
`refined` progress frames); `mi300a-char list` and the `backends`
request show the registry:
  mi300a-char scenario --backend analytic --size 512 --sweep-streams 1,2,4,8,16
  mi300a-char scenario --addr 127.0.0.1:7300 --backend auto --max-error 0.45 --sweep-streams 1,2,4,8,16
Cluster mode (DESIGN.md §6.9, docs/cluster.md): a coordinator speaks the
same protocol and consistent-hashes sweep points across plain serve
workers, so `scenario --addr` and `loadgen --addr` work unchanged:
  mi300a-char serve --addr 127.0.0.1:7400 --coordinator --workers 127.0.0.1:7301,127.0.0.1:7302
Multi-APU device sets (DESIGN.md §6.11, docs/multi_apu.md): the
data_parallel/pipeline/halo shapes place work across 1-4 APUs with the
Infinity Fabric transfer model; sim answers grow a transfer_ms field:
  mi300a-char scenario --shape data_parallel --size 512 --sweep-devices 1,2,3,4
  mi300a-char scenario --shape pipeline --devices 4 --topology ring --sweep-size 512,1024,2048
Trace replay (DESIGN.md §6.12, docs/replay.md): a recorded kernel-launch
timeline (JSON lines, examples under docs/traces/) replays through the
DES honoring issue times; what-if transforms (identity,
precision_rewrite:P, sparsity_enable, stream_remap:K, dilate:K,
compress:K) rewrite the timeline before replay and sweep as a scenario
axis; --chrome-trace exports per-launch spans for chrome://tracing:
  mi300a-char replay --trace docs/traces/transformer.jsonl --chrome-trace spans.json
  mi300a-char replay --trace docs/traces/mixed_precision.jsonl --sweep-transform identity,precision_rewrite:fp8
";

/// Parse an optional `--backend` flag into a [`BackendId`], with the
/// one error message every CLI path shares.
fn parse_backend_flag(args: &Args) -> Result<Option<BackendId>, String> {
    match args.get("backend") {
        None => Ok(None),
        Some(b) => BackendId::parse(b).map(Some).ok_or_else(|| {
            format!(
                "unknown backend {b:?} (registered: {})",
                BackendId::names()
            )
        }),
    }
}

/// [`parse_backend_flag`] for subcommands that print-and-exit: prints
/// a usage error and returns `Err(2)` on an unknown id.
fn backend_arg(args: &Args, what: &str) -> Result<Option<BackendId>, i32> {
    parse_backend_flag(args).map_err(|e| {
        eprintln!("{what}: {e}");
        2
    })
}

/// Parse an optional `--io-model` flag: unknown spellings and models
/// the platform cannot run are usage errors (`Err(2)`).
fn io_model_arg(args: &Args, what: &str) -> Result<IoModel, i32> {
    match args.get("io-model") {
        None => Ok(IoModel::default_for_platform()),
        Some(v) => match IoModel::parse(v) {
            Some(m) if m.available() => Ok(m),
            Some(m) => {
                eprintln!(
                    "{what}: io model {:?} is not available on this \
                     platform (try threads)",
                    m.as_str()
                );
                Err(2)
            }
            None => {
                eprintln!(
                    "{what}: unknown io model {v:?} (want epoll|threads)"
                );
                Err(2)
            }
        },
    }
}

fn build_config(args: &Args) -> Config {
    let mut cfg = if let Some(path) = args.get("config") {
        Config::load(std::path::Path::new(path)).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        })
    } else {
        Config::mi300a()
    };
    cfg.seed = args.get_u64("seed", cfg.seed);
    if let Some(spec) = args.get("set") {
        if let Err(e) = cfg.set(spec) {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }
    }
    cfg
}

fn print_error(context: &str, code: ErrorCode, message: &str) {
    eprintln!("{context}: {message} [{}]", code.as_str());
}

/// Service for one-shot subcommands: a single process answering a
/// single request can never hit the result cache, so skip the
/// memoization bookkeeping entirely. Only `serve` caches.
fn one_shot_service(args: &Args) -> Service {
    Service::with_cache_policy(build_config(args), CachePolicy::disabled())
}

fn cmd_repro(args: &Args) -> i32 {
    let svc = one_shot_service(args);
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let out_dir = args.get("out-dir").map(std::path::PathBuf::from);
    if let Some(d) = &out_dir {
        let _ = std::fs::create_dir_all(d);
    }
    let emit = |id: &str, rendered: &str, json: &Json| {
        if args.flag("json") {
            println!("{}", json.to_string_pretty());
        } else {
            println!("{rendered}");
        }
        if let Some(d) = &out_dir {
            let _ = std::fs::write(
                d.join(format!("{id}.json")),
                json.to_string_pretty(),
            );
            let _ = std::fs::write(d.join(format!("{id}.txt")), rendered);
        }
    };
    if which == "all" {
        // Drivers fan out across the pool; reports print in paper order
        // and are byte-identical to a serial run (--threads 1).
        let workers = args.get_usize("threads", pool::default_workers());
        for report in svc.repro_all(workers) {
            emit(report.id, &report.render(), &report.json);
        }
        return 0;
    }
    match svc.handle(&Request::Repro { experiment: which.to_string() }) {
        Response::Repro { experiment, report, rendered, .. } => {
            emit(&experiment, &rendered, &report);
            0
        }
        Response::Error { code, message } => {
            print_error("repro", code, &message);
            2
        }
        other => {
            eprintln!("repro: unexpected response {other:?}");
            1
        }
    }
}

fn cmd_run(args: &Args) -> i32 {
    let entry = match args.positional.first() {
        Some(e) => e.clone(),
        None => {
            eprintln!("run: missing <entry> (see `mi300a-char list`)");
            return 2;
        }
    };
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let svc =
        Service::with_options(build_config(args), dir, CachePolicy::disabled());
    match svc.handle(&Request::Run { entry }) {
        Response::Run { entry, outputs, checksum, exec_ms } => {
            println!(
                "{entry}: {outputs} outputs, checksum {checksum:.4}, \
                 {exec_ms:.1} ms (incl. compile)"
            );
            0
        }
        Response::Error { code, message } => {
            print_error("run", code, &message);
            if code == ErrorCode::UnknownEntry { 2 } else { 1 }
        }
        other => {
            eprintln!("run: unexpected response {other:?}");
            1
        }
    }
}

fn cmd_plan(args: &Args) -> i32 {
    let objective = match parse_objective(args.get_or("objective", "latency"))
    {
        Some(o) => o,
        None => {
            eprintln!(
                "plan: unknown objective {:?} (want \
                 latency|throughput|isolation)",
                args.get_or("objective", "latency")
            );
            return 2;
        }
    };
    let n = args.get_usize("size", 512);
    let streams = args.get_usize("streams", 4);
    let precision = match Precision::parse(args.get_or("precision", "fp8")) {
        Some(p) => p,
        None => {
            eprintln!(
                "plan: bad precision {:?}",
                args.get_or("precision", "fp8")
            );
            return 2;
        }
    };
    let backend = match backend_arg(args, "plan") {
        Ok(b) => b,
        Err(code) => return code,
    };
    let svc = one_shot_service(args);
    let env = RequestEnvelope { backend, ..RequestEnvelope::default() };
    match svc.handle_env(
        &Request::Plan { objective, streams, n, precision },
        &env,
    ) {
        Response::Plan { objective, sparse, groups } => {
            println!("objective: {objective}");
            for (i, g) in groups.iter().enumerate() {
                println!(
                    "group {i}: {} kernels, {} streams, expected fairness \
                     {:.3}, process isolation {}",
                    g.kernels.len(),
                    g.streams,
                    g.expected_fairness,
                    g.process_isolation
                );
                for k in &g.kernels {
                    println!("  - {k}");
                }
            }
            println!("sparse kernels planned: {sparse}");
            0
        }
        Response::Error { code, message } => {
            print_error("plan", code, &message);
            2
        }
        other => {
            eprintln!("plan: unexpected response {other:?}");
            1
        }
    }
}

/// Build a [`ScenarioSpec`] from `--spec FILE` or inline flags; usage
/// errors print and exit 2 via the returned `Err`.
fn scenario_spec_from_args(args: &Args) -> Result<ScenarioSpec, String> {
    let budget = |key: &str| -> Result<Option<f64>, String> {
        match args.get(key) {
            None => Ok(None),
            Some(v) => v.trim().parse::<f64>().map(Some).map_err(|_| {
                format!("--{key} wants a number, got {v:?}")
            }),
        }
    };
    if let Some(path) = args.get("spec") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path}: {e}"))?;
        let v = Json::parse(&text)
            .map_err(|e| format!("{path} is not valid JSON: {e}"))?;
        let mut spec =
            ScenarioSpec::from_json(&v).map_err(|e| e.to_string())?;
        // --backend fills a spec file that names none; a disagreeing
        // pair is a usage error (mirrors the service's envelope rule).
        if let Some(id) = parse_backend_flag(args)? {
            match spec.backend {
                Some(prev) if prev != id => {
                    return Err(format!(
                        "backend requested twice and disagreeing: {path} \
                         says {:?}, --backend says {:?}",
                        prev.as_str(),
                        id.as_str()
                    ))
                }
                _ => spec.backend = Some(id),
            }
        }
        // Budget flags fill (or override) the spec file's budgets.
        if let Some(e) = budget("max-error")? {
            spec.max_error = Some(e);
        }
        if let Some(t) = budget("max-time-ms")? {
            spec.max_time_ms = Some(t);
        }
        return Ok(spec);
    }
    let ask = Ask::parse(args.get_or("ask", "sim")).ok_or_else(|| {
        format!(
            "unknown ask {:?} (want sim|plan|sparsity)",
            args.get_or("ask", "sim")
        )
    })?;
    let shape =
        Shape::parse(args.get_or("shape", "homogeneous")).ok_or_else(|| {
            format!(
                "unknown shape {:?} (want \
                 homogeneous|imbalanced_pair|mixed_sparse|spmm_mix|\
                 data_parallel|pipeline|halo; shape \"trace\" needs \
                 trace records — use `replay` or --spec)",
                args.get_or("shape", "homogeneous")
            )
        })?;
    let mut spec = ScenarioSpec::new(ask);
    spec.shape = shape;
    spec.streams = args.get_usize("streams", shape.default_streams());
    spec.n = args.get_usize("size", spec.n);
    spec.iters = args.get_usize("iters", spec.iters);
    spec.device_set.devices =
        args.get_usize("devices", spec.device_set.devices);
    if let Some(t) = args.get("topology") {
        spec.device_set.topology =
            mi300a_char::fabric::Topology::parse(t).ok_or_else(|| {
                format!(
                    "unknown topology {t:?} (want fully_connected|ring)"
                )
            })?;
    }
    if let Some(p) = args.get("precision") {
        spec.precision = Precision::parse(p)
            .ok_or_else(|| format!("bad precision {p:?}"))?;
    }
    if args.get("small-size").is_some() {
        spec.small_n = Some(args.get_usize("small-size", 0));
    }
    if let Some(o) = args.get("objective") {
        spec.objective = Some(
            parse_objective(o).ok_or_else(|| {
                format!(
                    "unknown objective {o:?} (want \
                     latency|throughput|isolation)"
                )
            })?,
        );
    }
    if let Some(s) = args.get("sparsity") {
        spec.sparsity =
            mi300a_char::sim::SparsityMode::parse(s).ok_or_else(|| {
                format!("bad sparsity {s:?} (want dense|lhs|rhs|both)")
            })?;
    }
    if let Some(id) = parse_backend_flag(args)? {
        spec.backend = Some(id);
    }
    spec.max_error = budget("max-error")?;
    spec.max_time_ms = budget("max-time-ms")?;
    let usize_list = |key: &str| -> Result<Vec<usize>, String> {
        match args.get(key) {
            None => Ok(Vec::new()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim().parse::<usize>().map_err(|_| {
                        format!("--{key} wants a comma list of integers, \
                                 got {v:?}")
                    })
                })
                .collect(),
        }
    };
    spec.sweep.n = usize_list("sweep-size")?;
    spec.sweep.streams = usize_list("sweep-streams")?;
    spec.sweep.iters = usize_list("sweep-iters")?;
    spec.sweep.devices = usize_list("sweep-devices")?;
    if let Some(v) = args.get("sweep-precision") {
        spec.sweep.precision = v
            .split(',')
            .map(|x| {
                Precision::parse(x.trim())
                    .ok_or_else(|| format!("bad precision {x:?}"))
            })
            .collect::<Result<Vec<_>, _>>()?;
    }
    Ok(spec)
}

fn print_scenario_points(resp: &Response) {
    if let Response::Scenario { points } = resp {
        for pr in points {
            let devices = if pr.point.devices > 1 {
                format!(" devices={}", pr.point.devices)
            } else {
                String::new()
            };
            let transform = if pr.point.transform != Transform::Identity {
                format!(" transform={}", pr.point.transform.name())
            } else {
                String::new()
            };
            println!(
                "n={} precision={} streams={} iters={}{}{}: {}",
                pr.point.n,
                mi300a_char::api::precision_wire_name(pr.point.precision),
                pr.point.streams,
                pr.point.iters,
                devices,
                transform,
                pr.result.to_item_json()
            );
        }
        println!("points: {}", points.len());
    }
}

fn cmd_scenario(args: &Args) -> i32 {
    let spec = match scenario_spec_from_args(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("scenario: {e}");
            return 2;
        }
    };
    // Remote mode: submit as an async job and stream progress frames.
    if let Some(addr) = args.get("addr") {
        let mut client = match Client::connect(addr) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("scenario: cannot connect to {addr}: {e}");
                return 1;
            }
        };
        let result = client.submit_and_wait(&spec, |p| {
            // Refinement frames (budgeted auto jobs) carry the extra
            // counter; the `progress ` prefix stays stable for
            // line-oriented consumers (scripts/ci.sh greps it).
            if p.refined > 0 {
                println!(
                    "progress {}/{} (job {}, {}, refined {})",
                    p.completed,
                    p.total,
                    p.job,
                    p.state.as_str(),
                    p.refined
                );
            } else {
                println!(
                    "progress {}/{} (job {}, {})",
                    p.completed,
                    p.total,
                    p.job,
                    p.state.as_str()
                );
            }
        });
        return match result {
            Ok(resp @ Response::Scenario { .. }) => {
                if args.flag("json") {
                    println!("{}", resp.to_json(None).to_string_pretty());
                } else {
                    print_scenario_points(&resp);
                }
                0
            }
            // Typed server errors exit 2 like the local mode (same
            // spec, same classification); transport failures exit 1.
            Ok(Response::Error { code, message }) => {
                print_error("scenario", code, &message);
                2
            }
            Ok(other) => {
                eprintln!("scenario: unexpected response {other:?}");
                1
            }
            Err(e) => {
                eprintln!("scenario: {e}");
                1
            }
        };
    }
    // Local mode: run the sweep in-process through the same service.
    let svc = one_shot_service(args);
    match svc.handle(&Request::Scenario { spec }) {
        resp @ Response::Scenario { .. } => {
            if args.flag("json") {
                println!("{}", resp.to_json(None).to_string_pretty());
            } else {
                print_scenario_points(&resp);
            }
            0
        }
        Response::Error { code, message } => {
            print_error("scenario", code, &message);
            2
        }
        other => {
            eprintln!("scenario: unexpected response {other:?}");
            1
        }
    }
}

fn cmd_replay(args: &Args) -> i32 {
    let path = match args.get("trace") {
        Some(p) => p.to_string(),
        None => {
            eprintln!(
                "replay: missing --trace FILE.jsonl (a recorded \
                 kernel-launch timeline, see docs/replay.md)"
            );
            return 2;
        }
    };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("replay: cannot read {path}: {e}");
            return 2;
        }
    };
    let records = match parse_jsonl(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("replay: {path}: {e}");
            return 2;
        }
    };
    let mut spec = match ScenarioSpec::trace_replay(records) {
        Ok(s) => s,
        Err(e) => {
            print_error("replay", e.code, &e.message);
            return 2;
        }
    };
    let parse_transform = |t: &str| -> Result<Transform, String> {
        Transform::parse(t).ok_or_else(|| {
            format!(
                "unknown transform {t:?} (want identity|\
                 precision_rewrite:P|sparsity_enable|stream_remap:K|\
                 dilate:K|compress:K)"
            )
        })
    };
    if let Some(t) = args.get("transform") {
        spec.transform = match parse_transform(t) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("replay: {e}");
                return 2;
            }
        };
    }
    if let Some(v) = args.get("sweep-transform") {
        spec.sweep.transform = match v
            .split(',')
            .map(|x| parse_transform(x.trim()))
            .collect::<Result<Vec<_>, _>>()
        {
            Ok(ts) => ts,
            Err(e) => {
                eprintln!("replay: {e}");
                return 2;
            }
        };
    }
    match backend_arg(args, "replay") {
        Ok(Some(id)) => spec.backend = Some(id),
        Ok(None) => {}
        Err(code) => return code,
    }
    // The wire answer carries only the span *count*; the spans
    // themselves come straight from the replay engine, so the export
    // replays the (--transform'd) timeline once more here.
    if let Some(out) = args.get("chrome-trace") {
        let cfg = build_config(args);
        let ts = TraceSpec::from_records(spec.trace.clone())
            .expect("trace_replay validated the records");
        let run =
            mi300a_char::replay::replay(&cfg, &ts, spec.transform, cfg.seed);
        let j = mi300a_char::sim::trace::chrome_trace_spans(
            &run.spans,
            &run.labels,
        );
        if let Err(e) = std::fs::write(out, j.to_string_pretty()) {
            eprintln!("replay: cannot write {out}: {e}");
            return 1;
        }
        println!("wrote {out} ({} spans)", run.spans.len());
    }
    let svc = one_shot_service(args);
    match svc.handle(&Request::Scenario { spec }) {
        resp @ Response::Scenario { .. } => {
            if args.flag("json") {
                println!("{}", resp.to_json(None).to_string_pretty());
            } else {
                print_scenario_points(&resp);
            }
            0
        }
        Response::Error { code, message } => {
            print_error("replay", code, &message);
            2
        }
        other => {
            eprintln!("replay: unexpected response {other:?}");
            1
        }
    }
}

fn cmd_config(args: &Args) -> i32 {
    let svc = one_shot_service(args);
    match svc.handle(&Request::Config) {
        Response::Config { config } => {
            println!("{}", config.to_string_pretty());
            0
        }
        other => {
            eprintln!("config: unexpected response {other:?}");
            1
        }
    }
}

fn cmd_list(args: &Args) -> i32 {
    let svc = one_shot_service(args);
    match svc.handle(&Request::ListExperiments) {
        Response::Experiments { experiments } => {
            println!("experiments:");
            for e in &experiments {
                println!("  {:<8} {:<4} {}", e.id, e.section, e.title);
            }
        }
        other => {
            eprintln!("list: unexpected response {other:?}");
            return 1;
        }
    }
    match svc.handle(&Request::Backends) {
        Response::Backends { backends } => {
            println!("backends:");
            for b in &backends {
                println!(
                    "  {:<9} {}{}",
                    b.id,
                    b.description,
                    if b.default { " [default]" } else { "" }
                );
            }
        }
        other => {
            eprintln!("list: unexpected response {other:?}");
            return 1;
        }
    }
    match svc.load_manifest() {
        Ok(m) => {
            println!("artifacts ({}):", svc.artifacts_dir().display());
            for e in &m.entries {
                println!(
                    "  {} ({} inputs -> {} outputs)",
                    e.name,
                    e.inputs.len(),
                    e.outputs.len()
                );
            }
        }
        Err(_) => println!(
            "artifacts: not built (run `make artifacts`); dir {}",
            svc.artifacts_dir().display()
        ),
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = build_config(args);
    let addr = args.get_or("addr", "127.0.0.1:7300").to_string();
    let max = match args.get("max-conns") {
        None => None,
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            // Report a usage error instead of silently serving one
            // connection (the pre-API behavior of `unwrap_or(1)`).
            _ => {
                eprintln!(
                    "serve: --max-conns wants a positive integer, got {v:?}"
                );
                return 2;
            }
        },
    };
    let policy = if args.flag("no-cache") {
        CachePolicy::disabled()
    } else {
        CachePolicy::default()
    };
    let default_backend = match backend_arg(args, "serve") {
        Ok(b) => b.unwrap_or(mi300a_char::backend::DEFAULT),
        Err(code) => return code,
    };
    let io = match io_model_arg(args, "serve") {
        Ok(m) => m,
        Err(code) => return code,
    };
    // Coordinator mode (DESIGN.md §6.9): same protocol, same transport
    // machinery, but every sweep point routes to a worker instead of a
    // local engine. Caching happens on the workers (the coordinator
    // forwards the per-request `cache` flag), so --no-cache here only
    // affects what clients of this process send onward.
    if args.flag("coordinator") {
        let workers: Vec<String> = args
            .get("workers")
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .unwrap_or_default();
        if workers.is_empty() {
            eprintln!(
                "serve: --coordinator wants --workers \
                 HOST:PORT,HOST:PORT,..."
            );
            return 2;
        }
        return match mi300a_char::cluster::serve_cluster(
            &addr,
            workers,
            max,
            default_backend,
            io,
        ) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("serve: {e}");
                1
            }
        };
    }
    match mi300a_char::serve::serve_io(cfg, &addr, max, policy,
                                       default_backend, io)
    {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

fn cmd_loadgen(args: &Args) -> i32 {
    let mut opts = LoadgenOptions::new(build_config(args));
    opts.addr = args.get("addr").map(str::to_string);
    opts.connections = args.get_usize("connections", opts.connections);
    if opts.connections == 0 {
        eprintln!("loadgen: --connections wants a positive integer");
        return 2;
    }
    opts.warmup_ms = args.get_u64("warmup-ms", opts.warmup_ms);
    opts.duration_ms = args.get_u64("duration-ms", opts.duration_ms);
    if opts.duration_ms == 0 {
        eprintln!("loadgen: --duration-ms wants a positive integer");
        return 2;
    }
    opts.mix = match Mix::parse(args.get_or("mix", opts.mix.as_str())) {
        Some(m) => m,
        None => {
            eprintln!(
                "loadgen: unknown mix {:?} (want {})",
                args.get_or("mix", ""),
                Mix::names()
            );
            return 2;
        }
    };
    opts.io = match io_model_arg(args, "loadgen") {
        Ok(m) => m,
        Err(code) => return code,
    };
    opts.cache = !args.flag("no-cache");
    opts.default_backend = match backend_arg(args, "loadgen") {
        Ok(b) => b.unwrap_or(mi300a_char::backend::DEFAULT),
        Err(code) => return code,
    };
    let report = match mi300a_char::loadgen::run(&opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("loadgen: {e}");
            return 1;
        }
    };
    match mi300a_char::loadgen::write_bench(&report, &opts) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("loadgen: cannot write BENCH_serve.json: {e}");
            return 1;
        }
    }
    println!(
        "loadgen: {:.0} req/s sustained ({} requests / {:.0} ms, {} \
         connections, io {}, mix {})",
        report.req_per_sec,
        report.requests,
        report.measured_ms,
        report.connections,
        report.io.map(IoModel::as_str).unwrap_or("remote"),
        opts.mix.as_str()
    );
    println!(
        "latency p50 {:.1} us, p90 {:.1} us, p99 {:.1} us; overloaded \
         {}; cache hit rate {}",
        report.p50_ns as f64 / 1e3,
        report.p90_ns as f64 / 1e3,
        report.p99_ns as f64 / 1e3,
        report.overloaded,
        report
            .cache_hit_rate
            .map(|r| format!("{:.1}%", r * 100.0))
            .unwrap_or_else(|| "unknown".to_string())
    );
    if report.errors > 0 {
        eprintln!(
            "loadgen: {} unexpected typed/transport errors (first: {})",
            report.errors,
            report.first_error.as_deref().unwrap_or("unknown")
        );
        return 1;
    }
    if report.requests == 0 {
        eprintln!("loadgen: zero requests completed in the measured window");
        return 1;
    }
    0
}

fn cmd_client(args: &Args) -> i32 {
    let addr = args.get_or("addr", "127.0.0.1:7300").to_string();
    let line = match args.positional.first() {
        Some(l) => l.clone(),
        None => {
            eprintln!(
                "client: missing <json-request>, e.g. \
                 '{{\"v\":1,\"type\":\"sim\",\"n\":512,\"precision\":\
                 \"fp8\",\"streams\":4}}'"
            );
            return 2;
        }
    };
    let v = match Json::parse(&line) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("client: request is not valid JSON: {e}");
            return 2;
        }
    };
    // Decode locally first: usage errors are caught (typed) before any
    // connection is made. The envelope's `cache` and `backend` options
    // are forwarded so `"cache":false` measurement requests stay
    // cache-bypassing and `"backend":…` selections reach the server.
    let (req, env) = match Request::decode(&v) {
        Ok(decoded) => decoded,
        Err((e, _)) => {
            eprintln!("client: {e}");
            return 2;
        }
    };
    let mut client = match Client::connect(addr.as_str()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("client: cannot connect to {addr}: {e}");
            return 1;
        }
    };
    match client.request_json_env(&req, &env) {
        Ok((resp, _id)) => {
            println!("{resp}");
            // Typed error responses must be visible to shell pipelines.
            if resp.get("type").and_then(|t| t.as_str()) == Some("error") {
                1
            } else {
                0
            }
        }
        Err(e) => {
            eprintln!("client: {e}");
            1
        }
    }
}

fn main() {
    let args = Args::from_env(&["json", "verbose", "no-cache", "coordinator"]);
    let code = match args.subcommand.as_deref() {
        Some("repro") => cmd_repro(&args),
        Some("run") => cmd_run(&args),
        Some("plan") => cmd_plan(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("replay") => cmd_replay(&args),
        Some("config") => cmd_config(&args),
        Some("list") => cmd_list(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("client") => cmd_client(&args),
        _ => {
            print!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}
