//! Mixed-precision workload (paper §8.3): a sequence of matrix
//! operations at FP32, FP16, and FP8, "representing a common pattern in
//! training pipelines that use different precisions for different
//! computational stages".

use crate::isa::Precision;
use crate::sim::kernel::KernelDesc;

/// One operation of the chain.
#[derive(Debug, Clone)]
pub struct MixedOp {
    pub name: &'static str,
    pub kernel: KernelDesc,
}

/// The FP32 -> FP16 -> FP8 chain (mirrors the AOT'd `mixed_chain` L2
/// entry point).
#[derive(Debug, Clone)]
pub struct MixedChain {
    pub n: usize,
    pub ops: Vec<MixedOp>,
}

impl MixedChain {
    pub fn new(n: usize) -> MixedChain {
        MixedChain {
            n,
            ops: vec![
                MixedOp {
                    name: "fp32_gemm",
                    kernel: KernelDesc::gemm(n, Precision::F32).with_iters(1),
                },
                MixedOp {
                    name: "fp16_gemm",
                    kernel: KernelDesc::gemm(n, Precision::F16).with_iters(1),
                },
                MixedOp {
                    name: "fp8_gemm",
                    kernel: KernelDesc::gemm(n, Precision::Fp8).with_iters(1),
                },
            ],
        }
    }

    pub fn precisions(&self) -> Vec<Precision> {
        self.ops.iter().map(|o| o.kernel.precision).collect()
    }

    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.kernel.flops()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_order_is_fp32_fp16_fp8() {
        let c = MixedChain::new(256);
        assert_eq!(
            c.precisions(),
            vec![Precision::F32, Precision::F16, Precision::Fp8]
        );
    }

    #[test]
    fn flops_are_three_equal_gemms() {
        let c = MixedChain::new(256);
        assert_eq!(c.total_flops(), 3.0 * 2.0 * 256.0f64.powi(3));
    }
}
