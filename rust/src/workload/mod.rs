//! Workload generators: the kernels and kernel chains the paper's
//! experiments drive (§4 microbenchmarks, §8 case studies).

pub mod generator;
pub mod mixed;
pub mod transformer;

pub use generator::{gemm_sweep, stream_set, StreamSetSpec};
pub use mixed::{MixedChain, MixedOp};
pub use transformer::TransformerWorkload;
