//! Transformer-style FP8 inference workload (paper §8.1).
//!
//! The case-study kernel is "composed primarily of FP8 GEMM operations"
//! executed sequentially: QKV projection, attention output projection,
//! and the two MLP GEMMs. For a given model geometry and batch size this
//! expands to the GEMM chain the simulator prices, and the coordinator
//! maps onto the AOT'd `transformer_block` artifact for real numerics.

use crate::isa::Precision;
use crate::sim::kernel::{KernelDesc, SparsityMode};

/// Model geometry of the transformer-style kernel.
#[derive(Debug, Clone, Copy)]
pub struct TransformerWorkload {
    pub seq: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub batch: usize,
    pub sparse_mlp: bool,
}

impl TransformerWorkload {
    pub fn new(seq: usize, d_model: usize) -> TransformerWorkload {
        TransformerWorkload {
            seq,
            d_model,
            d_ff: 4 * d_model,
            n_heads: (d_model / 64).max(1),
            batch: 1,
            sparse_mlp: false,
        }
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_sparse_mlp(mut self, on: bool) -> Self {
        self.sparse_mlp = on;
        self
    }

    /// Effective GEMM M dimension: tokens in flight.
    pub fn tokens(&self) -> usize {
        self.seq * self.batch
    }

    /// The FP8 GEMM chain of one block (paper §8.1's kernel).
    pub fn gemms(&self) -> Vec<KernelDesc> {
        let t = self.tokens();
        let mlp_sparse = if self.sparse_mlp {
            SparsityMode::SparseLhs
        } else {
            SparsityMode::Dense
        };
        vec![
            // QKV projection: (t, d) x (d, 3d)
            KernelDesc::gemm(t, Precision::Fp8)
                .with_shape(t, 3 * self.d_model, self.d_model)
                .with_iters(1),
            // Attention output projection: (t, d) x (d, d)
            KernelDesc::gemm(t, Precision::Fp8)
                .with_shape(t, self.d_model, self.d_model)
                .with_iters(1),
            // MLP up: (t, d) x (d, 4d)
            KernelDesc::gemm(t, Precision::Fp8)
                .with_shape(t, self.d_ff, self.d_model)
                .with_iters(1)
                .with_sparsity(mlp_sparse),
            // MLP down: (t, 4d) x (4d, d)
            KernelDesc::gemm(t, Precision::Fp8)
                .with_shape(t, self.d_model, self.d_ff)
                .with_iters(1)
                .with_sparsity(mlp_sparse),
        ]
    }

    /// Total dense-equivalent FLOPs per block.
    pub fn flops(&self) -> f64 {
        self.gemms().iter().map(|g| g.flops()).sum()
    }

    /// Total wavefronts the chain's largest GEMM puts in flight — the
    /// §9.1 occupancy number ("a transformer decoder with batch size 32
    /// achieves only 128 wavefronts").
    pub fn peak_wavefronts(&self) -> usize {
        self.gemms().iter().map(|g| g.blocks()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_has_four_gemms() {
        let w = TransformerWorkload::new(128, 256);
        assert_eq!(w.gemms().len(), 4);
    }

    #[test]
    fn flops_match_hand_count() {
        let w = TransformerWorkload::new(128, 256);
        // 2*t*3d*d + 2*t*d*d + 2*t*4d*d + 2*t*4d*d = 2*t*d^2*(3+1+4+4).
        let want = 2.0 * 128.0 * 256.0 * 256.0 * 12.0;
        assert_eq!(w.flops(), want);
    }

    #[test]
    fn batch_scales_tokens_and_wavefronts() {
        let w1 = TransformerWorkload::new(128, 512);
        let w8 = w1.with_batch(8);
        assert_eq!(w8.tokens(), 8 * 128);
        assert!(w8.peak_wavefronts() > w1.peak_wavefronts());
    }

    #[test]
    fn sparse_mlp_marks_only_mlp_gemms() {
        let w = TransformerWorkload::new(64, 256).with_sparse_mlp(true);
        let gs = w.gemms();
        assert!(!gs[0].sparsity.is_sparse());
        assert!(!gs[1].sparsity.is_sparse());
        assert!(gs[2].sparsity.is_sparse());
        assert!(gs[3].sparsity.is_sparse());
    }
}
