//! Parameter-sweep and stream-set construction for the experiment
//! drivers (paper §4.2 "controlled scaling").

use crate::isa::Precision;
use crate::sim::kernel::{
    KernelDesc, SparsityMode, DEFAULT_SPMM_DENSITY_PCT,
};

/// A multi-stream workload specification.
#[derive(Debug, Clone)]
pub struct StreamSetSpec {
    pub kernels: Vec<KernelDesc>,
}

impl StreamSetSpec {
    pub fn homogeneous(kernel: KernelDesc, streams: usize) -> StreamSetSpec {
        StreamSetSpec { kernels: vec![kernel; streams] }
    }

    /// Occupancy-imbalance pair (paper §6.3): a large and a small kernel
    /// on the same ACE, e.g. 2048^3 paired with 512^3 at 4:1.
    pub fn imbalanced_pair(large_n: usize, small_n: usize, p: Precision,
                           iters: usize) -> StreamSetSpec {
        StreamSetSpec {
            kernels: vec![
                KernelDesc::gemm(large_n, p).with_iters(iters),
                KernelDesc::gemm(small_n, p).with_iters(iters),
            ],
        }
    }

    /// Mixed dense/sparse set (paper §7.2's "mixed" workload: alternate
    /// sparse and dense streams).
    pub fn mixed_sparse(n: usize, p: Precision, streams: usize,
                        iters: usize) -> StreamSetSpec {
        StreamSetSpec {
            kernels: (0..streams)
                .map(|i| {
                    let k = KernelDesc::gemm(n, p).with_iters(iters);
                    if i % 2 == 0 {
                        k.with_sparsity(SparsityMode::SparseLhs)
                    } else {
                        k
                    }
                })
                .collect(),
        }
    }

    /// Data-sparse mix (AsyncSparse-style `spmm_mix` shape): even
    /// streams run CSR SpMM at the default density — irregular per-lane
    /// work — while odd streams run the dense GEMM, so the set stresses
    /// fairness under structurally unequal streams rather than the 2:4
    /// structured overlay `mixed_sparse` models.
    pub fn spmm_mix(n: usize, p: Precision, streams: usize,
                    iters: usize) -> StreamSetSpec {
        StreamSetSpec {
            kernels: (0..streams)
                .map(|i| {
                    if i % 2 == 0 {
                        KernelDesc::spmm(n, p, DEFAULT_SPMM_DENSITY_PCT)
                            .with_iters(iters)
                    } else {
                        KernelDesc::gemm(n, p).with_iters(iters)
                    }
                })
                .collect(),
        }
    }

    /// Data-parallel replica (multi-APU `data_parallel` shape): every
    /// device runs the full homogeneous stream set; the fabric layer
    /// adds the allreduce-style gradient exchange between iterations.
    pub fn data_parallel_replica(n: usize, p: Precision, streams: usize,
                                 iters: usize) -> StreamSetSpec {
        StreamSetSpec::homogeneous(
            KernelDesc::gemm(n, p).with_iters(iters),
            streams,
        )
    }

    /// One pipeline stage of a depth-split GEMM (multi-APU `pipeline`
    /// shape): each of `devices` stages computes a `K/devices` slice of
    /// every iteration and relays activations to the next stage. The
    /// split floors at 64 so tiny kernels stay well-formed.
    pub fn pipeline_stage(n: usize, p: Precision, devices: usize,
                          streams: usize, iters: usize) -> StreamSetSpec {
        let k_slice = (n / devices.max(1)).max(64).min(n);
        StreamSetSpec::homogeneous(
            KernelDesc::gemm(n, p)
                .with_shape(n, n, k_slice)
                .with_iters(iters),
            streams,
        )
    }

    /// One row-shard of a halo decomposition (multi-APU `halo` shape):
    /// each of `devices` devices owns `M/devices` output rows and
    /// swaps boundary tiles with its ring neighbors every iteration.
    pub fn halo_shard(n: usize, p: Precision, devices: usize,
                      streams: usize, iters: usize) -> StreamSetSpec {
        let m_shard = (n / devices.max(1)).max(64).min(n);
        StreamSetSpec::homogeneous(
            KernelDesc::gemm(n, p)
                .with_shape(m_shard, n, n)
                .with_iters(iters),
            streams,
        )
    }

    /// Overlay `mode` onto every kernel (the scenario layer's base
    /// sparsity; see `api::scenario`).
    pub fn with_sparsity(mut self, mode: SparsityMode) -> StreamSetSpec {
        for k in &mut self.kernels {
            k.sparsity = mode;
        }
        self
    }

    pub fn occupancy_ratio(&self) -> f64 {
        let blocks: Vec<f64> =
            self.kernels.iter().map(|k| k.blocks() as f64).collect();
        let max = blocks.iter().cloned().fold(0.0, f64::max);
        let min = blocks.iter().cloned().fold(f64::INFINITY, f64::min);
        max / min
    }
}

/// Sweep of homogeneous GEMMs over matrix dimension (Fig 14's axis).
pub fn gemm_sweep(dims: &[usize], p: Precision, iters: usize) -> Vec<KernelDesc> {
    dims.iter()
        .map(|&n| KernelDesc::gemm(n, p).with_iters(iters))
        .collect()
}

/// Homogeneous stream set (paper baseline: fixed 512^3, 100 iters).
pub fn stream_set(n: usize, p: Precision, streams: usize, iters: usize)
    -> Vec<KernelDesc> {
    vec![KernelDesc::gemm(n, p).with_iters(iters); streams]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_set_size() {
        let s = StreamSetSpec::homogeneous(
            KernelDesc::gemm(512, Precision::F32), 4);
        assert_eq!(s.kernels.len(), 4);
        assert!((s.occupancy_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalanced_pair_ratio() {
        // 2048^3 (tile 256 -> 64 blocks) vs 512^3 (tile 128 -> 16 blocks).
        let s = StreamSetSpec::imbalanced_pair(2048, 512, Precision::F32, 8);
        assert!(s.occupancy_ratio() >= 2.0, "ratio {}", s.occupancy_ratio());
    }

    #[test]
    fn mixed_set_alternates() {
        let s = StreamSetSpec::mixed_sparse(512, Precision::Fp8, 4, 50);
        let sparse_count =
            s.kernels.iter().filter(|k| k.sparsity.is_sparse()).count();
        assert_eq!(sparse_count, 2);
    }

    #[test]
    fn spmm_mix_alternates_kernel_classes() {
        use crate::sim::kernel::KernelClass;
        let s = StreamSetSpec::spmm_mix(512, Precision::Fp8, 4, 50);
        let spmm_count = s
            .kernels
            .iter()
            .filter(|k| k.class == KernelClass::Spmm)
            .count();
        assert_eq!(spmm_count, 2);
        assert!(s.kernels[0].irregularity() > 0.0);
        assert_eq!(s.kernels[1].irregularity(), 0.0);
    }

    #[test]
    fn device_placements_split_or_replicate() {
        let rep = StreamSetSpec::data_parallel_replica(
            512, Precision::Fp8, 4, 50);
        assert_eq!(rep.kernels.len(), 4);
        assert!(rep.kernels.iter().all(|k| k.m == 512 && k.k == 512));

        let stage = StreamSetSpec::pipeline_stage(
            512, Precision::Fp8, 4, 4, 50);
        assert!(stage.kernels.iter().all(|k| k.k == 128 && k.m == 512));

        let shard = StreamSetSpec::halo_shard(
            512, Precision::Fp8, 4, 4, 50);
        assert!(shard.kernels.iter().all(|k| k.m == 128 && k.k == 512));
        // Tiny kernels floor the split at 64.
        let tiny = StreamSetSpec::halo_shard(65, Precision::Fp8, 4, 2, 50);
        assert!(tiny.kernels.iter().all(|k| k.m == 64));
        // One device is the unsplit kernel.
        let solo = StreamSetSpec::pipeline_stage(
            512, Precision::Fp8, 1, 4, 50);
        assert!(solo.kernels.iter().all(|k| k.k == 512));
    }

    #[test]
    fn sweep_covers_dims() {
        let ks = gemm_sweep(&[64, 256, 1024], Precision::Fp8, 10);
        assert_eq!(ks.len(), 3);
        assert_eq!(ks[2].m, 1024);
        assert!(ks.iter().all(|k| k.iters == 10));
    }
}
