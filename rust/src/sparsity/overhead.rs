//! rocSPARSE-like API overhead model (paper §7.1.1, Fig 10).
//!
//! The paper profiles three size-independent overhead components on the
//! sparse path: dense->compressed format conversion (~2 µs), metadata
//! buffer allocation (~1 µs), and kernel dispatch through the sparse API
//! (~1 µs); both-side sparsity adds a second conversion (~1.8 µs extra).
//! Constancy across problem sizes is the paper's central sparsity
//! finding — the overhead never amortizes in isolation.

use crate::config::Config;
use crate::sim::kernel::SparsityMode;
use crate::util::rng::Rng;

/// Breakdown of one sparse launch's API overhead, ns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverheadBreakdown {
    pub format_conversion_ns: f64,
    pub metadata_alloc_ns: f64,
    pub dispatch_ns: f64,
}

impl OverheadBreakdown {
    pub fn total_ns(&self) -> f64 {
        self.format_conversion_ns + self.metadata_alloc_ns + self.dispatch_ns
    }

    pub fn total_us(&self) -> f64 {
        self.total_ns() / 1e3
    }
}

/// The overhead model.
#[derive(Debug, Clone)]
pub struct OverheadModel<'a> {
    cfg: &'a Config,
}

impl<'a> OverheadModel<'a> {
    pub fn new(cfg: &'a Config) -> OverheadModel<'a> {
        OverheadModel { cfg }
    }

    /// Mean overhead for a sparsity pattern (no measurement noise).
    pub fn mean(&self, mode: SparsityMode) -> OverheadBreakdown {
        let s = &self.cfg.sparsity;
        let conv_extra = if mode == SparsityMode::SparseBoth {
            s.both_side_extra_us
        } else {
            0.0
        };
        OverheadBreakdown {
            format_conversion_ns: (s.format_conversion_us + conv_extra) * 1e3,
            metadata_alloc_ns: s.metadata_alloc_us * 1e3,
            dispatch_ns: s.dispatch_us * 1e3,
        }
    }

    /// One sampled measurement (Fig 10's 3.5-3.9 µs run-to-run band).
    /// Size-independent by construction: `_matrix_dim` is accepted only
    /// to document the contract.
    pub fn sample_ns(
        &self,
        mode: SparsityMode,
        _matrix_dim: usize,
        rng: &mut Rng,
    ) -> f64 {
        let spread = self.cfg.sparsity.overhead_spread_us * 1e3;
        self.mean(mode).total_ns() + rng.range(-spread, spread)
    }

    /// Time (ns) the 50% FLOP saving buys at a given dense-equivalent
    /// work time — the quantity Fig 10/§7.1.1 compares overhead against.
    pub fn computational_saving_ns(&self, dense_work_ns: f64) -> f64 {
        dense_work_ns * (1.0 - self.cfg.sparsity.flop_fraction)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::kernel::SparsityMode::*;

    #[test]
    fn single_side_mean_matches_paper_band() {
        let cfg = Config::mi300a();
        let m = OverheadModel::new(&cfg);
        let lhs = m.mean(SparseLhs).total_us();
        assert!(
            (3.5..=3.9).contains(&lhs),
            "single-side overhead {lhs} µs outside Fig 10's 3.5-3.9 band"
        );
    }

    #[test]
    fn both_side_mean_matches_paper_band() {
        let cfg = Config::mi300a();
        let m = OverheadModel::new(&cfg);
        let both = m.mean(SparseBoth).total_us();
        assert!(
            (5.3..=5.8).contains(&both),
            "both-side overhead {both} µs outside Fig 10's 5.3-5.8 band"
        );
    }

    #[test]
    fn component_decomposition_matches_profile() {
        // Paper §7.1.1: conversion ~2 µs, metadata ~1 µs, dispatch ~1 µs.
        let cfg = Config::mi300a();
        let b = OverheadModel::new(&cfg).mean(SparseLhs);
        assert!((b.format_conversion_ns / 1e3 - 2.0).abs() < 0.5);
        assert!((b.metadata_alloc_ns / 1e3 - 1.0).abs() < 0.5);
        assert!((b.dispatch_ns / 1e3 - 1.0).abs() < 0.5);
    }

    #[test]
    fn overhead_is_size_independent() {
        let cfg = Config::mi300a();
        let m = OverheadModel::new(&cfg);
        let mut r1 = crate::util::rng::Rng::new(3);
        let mut r2 = crate::util::rng::Rng::new(3);
        let small = m.sample_ns(SparseLhs, 256, &mut r1);
        let huge = m.sample_ns(SparseLhs, 8192, &mut r2);
        assert_eq!(small, huge, "identical seeds, any size: same overhead");
    }

    #[test]
    fn samples_stay_in_band() {
        let cfg = Config::mi300a();
        let m = OverheadModel::new(&cfg);
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..200 {
            let us = m.sample_ns(SparseRhs, 512, &mut rng) / 1e3;
            assert!((3.3..=4.1).contains(&us), "sample {us} µs");
        }
    }

    #[test]
    fn saving_is_half_the_dense_work() {
        let cfg = Config::mi300a();
        let m = OverheadModel::new(&cfg);
        assert_eq!(m.computational_saving_ns(1000.0), 500.0);
    }
}
