//! 2:4 structured sparsity encoding (paper §7): prune, compress to
//! (values, 2-bit indices), decompress. Mirrors the Python oracle
//! (`python/compile/kernels/ref.py`) so the Rust coordinator can prepare
//! sparse operands for the AOT'd sparse GEMM artifact.

/// A 2:4-compressed matrix: for every group of 4 consecutive elements
/// along a row, the 2 surviving values and their in-group positions.
#[derive(Debug, Clone, PartialEq)]
pub struct Compressed24 {
    pub rows: usize,
    /// Dense column count (multiple of 4).
    pub cols: usize,
    /// rows x cols/2 surviving values, row-major.
    pub values: Vec<f32>,
    /// rows x cols/2 in-group positions (0..4), row-major.
    pub indices: Vec<u8>,
}

/// Prune a row-major matrix to 2:4: keep the 2 largest-magnitude
/// elements of each consecutive group of 4 (ties keep the earlier
/// element, matching the Python oracle's stable ordering).
pub fn prune_2_4(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols);
    assert!(cols % 4 == 0, "cols {cols} not divisible by 4");
    let mut out = data.to_vec();
    for r in 0..rows {
        for g in 0..cols / 4 {
            let base = r * cols + g * 4;
            // Rank the 4 by |x| descending, stable.
            let mut order = [0usize, 1, 2, 3];
            order.sort_by(|&a, &b| {
                data[base + b]
                    .abs()
                    .partial_cmp(&data[base + a].abs())
                    .unwrap()
                    .then(a.cmp(&b))
            });
            out[base + order[2]] = 0.0;
            out[base + order[3]] = 0.0;
        }
    }
    out
}

/// Compress a 2:4-pruned matrix. The two survivors per group are stored
/// in ascending position order (sparse-MFMA metadata layout). Groups
/// with fewer than 2 nonzeros pad with position slots in ascending
/// order of remaining indices.
pub fn compress_2_4(pruned: &[f32], rows: usize, cols: usize) -> Compressed24 {
    assert_eq!(pruned.len(), rows * cols);
    assert!(cols % 4 == 0);
    let half = cols / 2;
    let mut values = vec![0.0f32; rows * half];
    let mut indices = vec![0u8; rows * half];
    for r in 0..rows {
        for g in 0..cols / 4 {
            let base = r * cols + g * 4;
            let mut picked = Vec::with_capacity(2);
            for p in 0..4 {
                if pruned[base + p] != 0.0 {
                    picked.push(p);
                }
            }
            assert!(
                picked.len() <= 2,
                "row {r} group {g}: {} nonzeros violates 2:4",
                picked.len()
            );
            // Pad with unused ascending positions.
            let mut p_iter = 0;
            while picked.len() < 2 {
                if !picked.contains(&p_iter) {
                    picked.push(p_iter);
                }
                p_iter += 1;
            }
            picked.sort_unstable();
            for (slot, &p) in picked.iter().enumerate() {
                values[r * half + g * 2 + slot] = pruned[base + p];
                indices[r * half + g * 2 + slot] = p as u8;
            }
        }
    }
    Compressed24 { rows, cols, values, indices }
}

/// Decompress back to dense (exact inverse of compress over pruned
/// input).
pub fn decompress_2_4(c: &Compressed24) -> Vec<f32> {
    let mut out = vec![0.0f32; c.rows * c.cols];
    let half = c.cols / 2;
    for r in 0..c.rows {
        for g in 0..c.cols / 4 {
            for slot in 0..2 {
                let v = c.values[r * half + g * 2 + slot];
                let p = c.indices[r * half + g * 2 + slot] as usize;
                out[r * c.cols + g * 4 + p] += v;
            }
        }
    }
    out
}

/// Validate the 2:4 invariant on a dense matrix.
pub fn is_2_4(data: &[f32], rows: usize, cols: usize) -> bool {
    if cols % 4 != 0 || data.len() != rows * cols {
        return false;
    }
    for r in 0..rows {
        for g in 0..cols / 4 {
            let base = r * cols + g * 4;
            let nnz = (0..4).filter(|&p| data[base + p] != 0.0).count();
            if nnz > 2 {
                return false;
            }
        }
    }
    true
}

/// Metadata bytes of a compressed matrix (2 bits per surviving element,
/// packed; the paper's overhead model charges their allocation).
pub fn metadata_bytes(rows: usize, cols: usize) -> usize {
    // cols/2 survivors per row x 2 bits = cols/8 bytes per row.
    rows * cols / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn prune_keeps_two_largest() {
        let data = [1.0f32, -4.0, 2.0, 0.5];
        let pruned = prune_2_4(&data, 1, 4);
        assert_eq!(pruned, vec![0.0, -4.0, 2.0, 0.0]);
    }

    #[test]
    fn prune_is_idempotent() {
        let mut rng = Rng::new(5);
        let data = rand_matrix(&mut rng, 8, 16);
        let once = prune_2_4(&data, 8, 16);
        let twice = prune_2_4(&once, 8, 16);
        assert_eq!(once, twice);
    }

    #[test]
    fn compress_decompress_roundtrip_property() {
        check(100, 7, |g| {
            let rows = g.sized(1, 16);
            let cols = 4 * g.sized(1, 16);
            let mut data = Vec::with_capacity(rows * cols);
            for _ in 0..rows * cols {
                data.push(g.f64_in(-10.0, 10.0) as f32);
            }
            let pruned = prune_2_4(&data, rows, cols);
            if !is_2_4(&pruned, rows, cols) {
                return Err("prune violated 2:4".into());
            }
            let c = compress_2_4(&pruned, rows, cols);
            if c.values.len() != rows * cols / 2 {
                return Err("compressed size wrong".into());
            }
            if c.indices.iter().any(|&i| i > 3) {
                return Err("index out of group range".into());
            }
            let back = decompress_2_4(&c);
            if back != pruned {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn indices_strictly_ascending_within_group() {
        let mut rng = Rng::new(9);
        let data = rand_matrix(&mut rng, 4, 32);
        let c = compress_2_4(&prune_2_4(&data, 4, 32), 4, 32);
        for pair in c.indices.chunks(2) {
            assert!(pair[0] < pair[1], "metadata must be position-sorted");
        }
    }

    #[test]
    fn all_zero_rows_compress_cleanly() {
        let data = vec![0.0f32; 2 * 8];
        let pruned = prune_2_4(&data, 2, 8);
        let c = compress_2_4(&pruned, 2, 8);
        assert_eq!(decompress_2_4(&c), data);
    }

    #[test]
    fn metadata_size() {
        // 128x128: 128 * 128/8 = 2048 bytes of 2-bit metadata.
        assert_eq!(metadata_bytes(128, 128), 2048);
    }

    #[test]
    fn rejects_invalid_density() {
        let dense = vec![1.0f32; 8];
        assert!(!is_2_4(&dense, 1, 8), "fully dense is not 2:4");
    }
}
