//! Sparse-vs-dense speedup model (paper §7.1.2-§7.2, Figs 11-13).
//!
//! The paper's isolated measurements are reconciled by three facts its
//! §7/§9 analysis establishes:
//!
//! 1. The rocSPARSE path is **software-limited**: it executes
//!    dense-equivalent FLOPs (no realized 50% saving) plus a constant
//!    3.7-5.5 µs API overhead (`realized_flop_fraction = 1.0`).
//! 2. Both dense (rocBLAS) and sparse (rocSPARSE) calls share a large
//!    constant API/launch cost (`dense_api_launch_us`) — visible in the
//!    paper's own §7 baseline throughput (59.98 GFLOPS at 512^3). The
//!    extra sparse overhead is therefore invisible at any size:
//!    break-even 0.97-1.02x across the whole 60-config sweep.
//! 3. Strongly rectangular shapes are the exception: the dense path
//!    handles them poorly while the decompress path streams them,
//!    giving the 1.6-1.76x win (`rect_dense_penalty`).
//!
//! Under concurrency the value flips (Fig 13): the sparse path's halved
//! memory traffic avoids the contention collapse, yielding the stable
//! ~1.3x per-stream speedup — modelled in the DES via `mem_fraction`.

use super::overhead::OverheadModel;
use crate::config::Config;
use crate::sim::cost::CostModel;
use crate::sim::kernel::{KernelDesc, SparsityMode};

/// Isolated (single-stream) sparse vs dense timing for one kernel shape.
#[derive(Debug, Clone)]
pub struct IsolatedComparison {
    pub dense_ns: f64,
    pub sparse_ns: f64,
    pub overhead_ns: f64,
}

impl IsolatedComparison {
    pub fn speedup(&self) -> f64 {
        self.dense_ns / self.sparse_ns
    }
}

pub struct SpeedupModel<'a> {
    cfg: &'a Config,
    cost: CostModel<'a>,
    overhead: OverheadModel<'a>,
}

impl<'a> SpeedupModel<'a> {
    pub fn new(cfg: &'a Config) -> SpeedupModel<'a> {
        SpeedupModel {
            cfg,
            cost: CostModel::new(cfg),
            overhead: OverheadModel::new(cfg),
        }
    }

    /// Isolated comparison for a dense kernel vs its `mode`-sparse twin.
    pub fn isolated(&self, dense: &KernelDesc, mode: SparsityMode) -> IsolatedComparison {
        assert!(mode.is_sparse());
        let sparse_k = dense.clone().with_sparsity(mode);
        let launch = self.cfg.sparsity.dense_api_launch_us * 1e3;
        let oh = self.overhead.mean(mode).total_ns();

        let mut dense_ns = self.cost.solo_work_ns(dense) + launch;
        let sparse_ns = self.cost.solo_work_ns(&sparse_k) + launch + oh;
        if dense.is_rectangular() {
            // §7.1.2 exception: the dense path pays a penalty on
            // strongly skewed shapes that the decompress path does not.
            dense_ns *= self.cfg.sparsity.rect_dense_penalty;
        }
        IsolatedComparison { dense_ns, sparse_ns, overhead_ns: oh }
    }

    /// Per-stream sparse/dense speedup under `streams`-way concurrency
    /// (paper Fig 13c: constant ~1.3x — contention avoidance, not
    /// amortization). Derived from the relative contention relief of the
    /// sparse memory path.
    pub fn concurrent_per_stream(&self, dense: &KernelDesc, streams: usize) -> f64 {
        if streams <= 1 {
            let iso = self.isolated(dense, SparsityMode::SparseLhs);
            return iso.speedup();
        }
        // Contention relief: sparse kernels issue mem_fraction of the
        // memory requests, so they feel proportionally less of the
        // concurrency slowdown. Calibrated to the paper's stable 1.3x.
        let relief = 1.0 - self.cfg.sparsity.mem_fraction; // 0.4375
        1.0 + relief * 0.686
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    fn model(cfg: &Config) -> SpeedupModel<'_> {
        SpeedupModel::new(cfg)
    }

    #[test]
    fn square_isolated_is_break_even_at_all_sizes() {
        // Paper Fig 11/12: 0.97-1.03x across the whole square sweep.
        let cfg = Config::mi300a();
        let m = model(&cfg);
        for n in [256usize, 512, 2048, 8192] {
            for mode in [
                SparsityMode::SparseLhs,
                SparsityMode::SparseRhs,
                SparsityMode::SparseBoth,
            ] {
                let s = m
                    .isolated(&KernelDesc::gemm(n, Precision::Fp8), mode)
                    .speedup();
                assert!(
                    (0.95..=1.05).contains(&s),
                    "n={n} {mode:?}: isolated speedup {s:.3} not break-even"
                );
            }
        }
    }

    #[test]
    fn overhead_never_amortizes_in_isolation() {
        // Even at 8192^3 the speedup stays pinned at break-even: the
        // software path realizes no FLOP saving for the overhead to
        // amortize against (paper §7.1.1).
        let cfg = Config::mi300a();
        let m = model(&cfg);
        let small = m
            .isolated(&KernelDesc::gemm(256, Precision::Fp8),
                      SparsityMode::SparseLhs)
            .speedup();
        let large = m
            .isolated(&KernelDesc::gemm(8192, Precision::Fp8),
                      SparsityMode::SparseLhs)
            .speedup();
        assert!(
            (large - small).abs() < 0.06,
            "no size-dependent improvement: {small:.3} vs {large:.3}"
        );
        assert!(large < 1.05, "never a real win in isolation: {large:.3}");
    }

    #[test]
    fn custom_kernel_config_would_beat_break_even() {
        // §9.1 implication: bypassing rocSPARSE (realizing the 50% FLOP
        // saving, no API overhead) yields real speedup at compute-bound
        // sizes.
        let mut cfg = Config::mi300a();
        cfg.sparsity.realized_flop_fraction = 0.5;
        cfg.sparsity.dense_api_launch_us = 0.0;
        cfg.sparsity.sparse_pipe_eff = 1.0;
        let m = model(&cfg);
        let s = m
            .isolated(&KernelDesc::gemm(8192, Precision::Fp8),
                      SparsityMode::SparseLhs)
            .speedup();
        assert!(s > 1.5, "custom kernel should approach 2x: {s:.2}");
    }

    #[test]
    fn rectangular_shape_beats_break_even() {
        // Paper §7.1.2: 512x2048x1024 reaches 1.6-1.76x.
        let cfg = Config::mi300a();
        let m = model(&cfg);
        let rect = KernelDesc::gemm(512, Precision::Fp8).with_shape(512, 2048, 1024);
        let s = m.isolated(&rect, SparsityMode::SparseLhs).speedup();
        assert!(
            (1.5..=1.85).contains(&s),
            "rectangular speedup {s:.2} outside the paper's 1.6-1.76 region"
        );
    }

    #[test]
    fn concurrent_speedup_is_stable_1_3() {
        let cfg = Config::mi300a();
        let m = model(&cfg);
        let k = KernelDesc::gemm(512, Precision::Fp8);
        for streams in [2usize, 3, 4] {
            let s = m.concurrent_per_stream(&k, streams);
            assert!(
                (1.25..=1.35).contains(&s),
                "streams={streams}: {s:.3} should be ~1.3 and stream-count \
                 independent"
            );
        }
    }
}
