//! 2:4 structured sparsity: encoding substrate, rocSPARSE-like API
//! overhead model, and the sparse-vs-dense speedup composition
//! (paper §7).

pub mod encode;
pub mod overhead;
pub mod speedup;

pub use encode::{compress_2_4, decompress_2_4, is_2_4, prune_2_4, Compressed24};
pub use overhead::{OverheadBreakdown, OverheadModel};
pub use speedup::{IsolatedComparison, SpeedupModel};
