//! §5 experiments: Tables 1-3 and Figs 2-3 (matrix-core microbenchmarks).

use super::ExperimentReport;
use crate::config::Config;
use crate::isa::{Precision, OPCODES};
use crate::report::{ascii_plot, Table};
use crate::sim::MicrobenchModel;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;

/// Table 1: system configuration (documented; ours is the simulated
/// substitute, reported side by side).
pub fn table1(cfg: &Config) -> ExperimentReport {
    let mut t = Table::new(
        "Table 1 — system configuration (paper vs this reproduction)",
        &["component", "paper", "this repo"],
    );
    t.row(vec!["OS".into(), "RHEL 8.10".into(), "any (simulated)".into()]);
    t.row(vec![
        "GPU".into(),
        "AMD MI300A APU (CDNA3, gfx942)".into(),
        format!(
            "apusim: {} XCD x {} CU, {} MFMA/CU",
            cfg.hw.xcds, cfg.hw.cus_per_xcd, cfg.hw.mfma_per_cu
        ),
    ]);
    t.row(vec![
        "Memory".into(),
        "128 GB shared HBM3".into(),
        format!("{} GiB @ {} TB/s (model)", cfg.hw.hbm_gib, cfg.hw.hbm_tbps),
    ]);
    t.row(vec![
        "Toolchain".into(),
        "ROCm 7.2.0, hipcc gfx942".into(),
        "rust + JAX/Pallas AOT via PJRT".into(),
    ]);
    ExperimentReport {
        id: "table1",
        title: "System configuration".into(),
        json: cfg.to_json(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            "hardware gate: no MI300A available; apusim substitutes \
             (DESIGN.md §1)".into(),
        ],
    }
}

/// Table 2: microbenchmark coverage.
pub fn table2(_cfg: &Config) -> ExperimentReport {
    let mut t = Table::new(
        "Table 2 — microbenchmark coverage",
        &["class", "targeted execution behavior", "drivers"],
    );
    t.row(vec![
        "FP8 matrix execution".into(),
        "throughput scaling, occupancy sensitivity, shape effects".into(),
        "fig2 fig3 table3".into(),
    ]);
    t.row(vec![
        "ACE".into(),
        "overlap efficiency, fairness, saturation under concurrency".into(),
        "fig4 fig5 fig6 fig7 fig8 fig9".into(),
    ]);
    t.row(vec![
        "Structured sparsity (2:4)".into(),
        "realized speedups, overheads, break-even regimes".into(),
        "fig10 fig11 fig12 fig13".into(),
    ]);
    ExperimentReport {
        id: "table2",
        title: "Microbenchmark classes".into(),
        json: Json::Null,
        tables: vec![t],
        plots: vec![],
        notes: vec![],
    }
}

/// Fig 2: throughput vs total active wavefronts, normalized to peak.
pub fn fig2(cfg: &Config) -> ExperimentReport {
    let m = MicrobenchModel::new(cfg);
    let counts: Vec<usize> = vec![1, 2, 4, 8, 16, 32, 64, 96, 128, 192, 256];
    let mut t = Table::new(
        "Fig 2 — normalized throughput vs active wavefronts",
        &["waves", "FP64", "FP32", "FP16", "BF16", "FP8"],
    );
    let mut series: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut json_rows = Vec::new();
    // One occupancy sweep per precision, fanned out across the pool.
    let sweeps: Vec<(Precision, Vec<f64>)> =
        pool::scoped_map(&Precision::SWEEP, pool::default_workers(), |_, &p| {
            (
                p,
                m.occupancy_sweep(p, &counts)
                    .iter()
                    .map(|pt| pt.normalized)
                    .collect(),
            )
        });
    for (i, &w) in counts.iter().enumerate() {
        let mut row = vec![w.to_string()];
        let mut jrow = vec![("waves", Json::Num(w as f64))];
        for (p, ys) in &sweeps {
            row.push(format!("{:.2}%", ys[i] * 100.0));
            jrow.push((p.name(), Json::Num(ys[i])));
        }
        t.row(row);
        json_rows.push(Json::obj(jrow));
    }
    for (p, ys) in &sweeps {
        series.push((p.name(), ys.clone()));
    }
    let x: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
    let plot = ascii_plot("Fig 2: normalized throughput vs wavefronts",
                          &x, &series, 14);
    let at256: Vec<String> = sweeps
        .iter()
        .map(|(p, ys)| format!("{}={:.1}%", p.name(), ys.last().unwrap() * 100.0))
        .collect();
    ExperimentReport {
        id: "fig2",
        title: "FP8 matrix-core occupancy scaling".into(),
        tables: vec![t],
        plots: vec![plot],
        notes: vec![
            format!("at 256 wavefronts: {}", at256.join(", ")),
            "paper: FP8 13.7%, FP64 12.1%, FP32 10.4% at 256 waves; ~7% \
             (FP8) at 128".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 3: absolute GFLOPS vs aspect ratio at fixed total blocks.
pub fn fig3(cfg: &Config) -> ExperimentReport {
    let m = MicrobenchModel::new(cfg);
    // Fixed total blocks chosen to reproduce the paper's absolute scale
    // (FP8 ~4200 GFLOPS at favorable ratios) — see EXPERIMENTS.md.
    let blocks = 4;
    let aspects = [0.25, 0.5, 1.0, 2.0, 4.0];
    let mut t = Table::new(
        "Fig 3 — absolute GFLOPS vs aspect ratio (fixed blocks)",
        &["aspect M/N", "FP64", "FP32", "FP16", "BF16", "FP8"],
    );
    let mut series = Vec::new();
    let mut json_rows = Vec::new();
    // One aspect-ratio sweep per precision, fanned out across the pool.
    let sweeps: Vec<(Precision, Vec<f64>)> =
        pool::scoped_map(&Precision::SWEEP, pool::default_workers(), |_, &p| {
            (
                p,
                aspects
                    .iter()
                    .map(|&a| m.shape_throughput(p, a, blocks))
                    .collect(),
            )
        });
    for (i, &a) in aspects.iter().enumerate() {
        let mut row = vec![format!("{a}")];
        let mut jrow = vec![("aspect", Json::Num(a))];
        for (p, ys) in &sweeps {
            row.push(format!("{:.0}", ys[i]));
            jrow.push((p.name(), Json::Num(ys[i])));
        }
        t.row(row);
        json_rows.push(Json::obj(jrow));
    }
    for (p, ys) in &sweeps {
        series.push((p.name(), ys.clone()));
    }
    let plot = ascii_plot(
        "Fig 3: GFLOPS vs aspect ratio",
        &aspects.to_vec(),
        &series,
        12,
    );
    let fp8 = &sweeps.iter().find(|(p, _)| *p == Precision::Fp8).unwrap().1;
    let loss = (fp8[2] - fp8[4]) / fp8[2];
    ExperimentReport {
        id: "fig3",
        title: "Matrix shape effects".into(),
        tables: vec![t],
        plots: vec![plot],
        notes: vec![
            format!("FP8 loses {:.0}% at 4:1 vs 1:1 (paper: up to 16%)", loss * 100.0),
            "paper: FP8 ~4200 GFLOPS vs FP32 ~400 at favorable ratios".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Table 3: MFMA dependency-chain latency per opcode, re-measured
/// through the simulated instruction-targeted microbenchmark.
pub fn table3(cfg: &Config) -> ExperimentReport {
    let m = MicrobenchModel::new(cfg);
    let mut rng = Rng::new(cfg.seed ^ 0x7ab1e3);
    let mut t = Table::new(
        "Table 3 — MFMA single-issue latency (1e-5 ms)",
        &["instruction", "MxNxK", "paper", "measured", "dev%"],
    );
    let mut json_rows = Vec::new();
    let mut worst_dev = 0.0f64;
    for op in OPCODES {
        let measured_ns = m.measure_chain_latency_ns(op, &mut rng);
        let measured = measured_ns / 10.0; // to 1e-5 ms units
        let dev = (measured - op.latency_e5_ms()).abs() / op.latency_e5_ms();
        worst_dev = worst_dev.max(dev);
        t.row(vec![
            op.name.to_string(),
            op.tile.to_string(),
            format!("{:.3}", op.latency_e5_ms()),
            format!("{measured:.3}"),
            format!("{:.2}", dev * 100.0),
        ]);
        json_rows.push(Json::obj(vec![
            ("name", Json::Str(op.name.to_string())),
            ("tile", Json::Str(op.tile.to_string())),
            ("paper_e5ms", Json::Num(op.latency_e5_ms())),
            ("measured_e5ms", Json::Num(measured)),
        ]));
    }
    ExperimentReport {
        id: "table3",
        title: "MFMA opcode coverage and baseline latency".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            format!("worst deviation from Table 3: {:.2}%", worst_dev * 100.0),
            "Table 3 values are the simulator's calibration inputs \
             (DESIGN.md §7); this driver validates the measurement path \
             recovers them through the dependency-chain harness".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_normalized_values_bounded() {
        let r = fig2(&Config::mi300a());
        for row in r.json.as_arr().unwrap() {
            for p in Precision::SWEEP {
                let v = row.get(p.name()).unwrap().as_f64().unwrap();
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn fig3_fp8_beats_fp32_absolute() {
        let r = fig3(&Config::mi300a());
        for row in r.json.as_arr().unwrap() {
            let fp8 = row.get("FP8").unwrap().as_f64().unwrap();
            let f32_ = row.get("FP32").unwrap().as_f64().unwrap();
            assert!(fp8 > f32_, "FP8 must dominate in absolute GFLOPS");
        }
    }

    #[test]
    fn table3_covers_all_25_opcodes() {
        let r = table3(&Config::mi300a());
        assert_eq!(r.json.as_arr().unwrap().len(), 25);
        assert_eq!(r.tables[0].rows.len(), 25);
    }

    #[test]
    fn table3_measurements_within_1pct() {
        let r = table3(&Config::mi300a());
        for row in r.json.as_arr().unwrap() {
            let paper = row.get("paper_e5ms").unwrap().as_f64().unwrap();
            let meas = row.get("measured_e5ms").unwrap().as_f64().unwrap();
            assert!(
                ((meas - paper) / paper).abs() < 0.01,
                "{:?}: {meas} vs {paper}",
                row.get("name")
            );
        }
    }
}
