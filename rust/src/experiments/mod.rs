//! Experiment drivers: one per paper table/figure (DESIGN.md §5 index).
//!
//! Every driver regenerates its artifact's rows/series from the
//! simulator and returns an [`ExperimentReport`] (tables + ASCII plots +
//! machine-readable JSON). `mi300a-char repro <id>` prints them;
//! `rust/benches/` wraps them for `cargo bench`; EXPERIMENTS.md records
//! paper-vs-measured.

pub mod ace;
pub mod apps;
pub mod micro;
pub mod sparsity;

use crate::config::Config;
use crate::report::Table;
use crate::util::json::Json;

/// The output of one experiment driver.
#[derive(Debug, Clone)]
pub struct ExperimentReport {
    /// The registry id that produced this report.
    pub id: &'static str,
    /// Human title (matches the registry entry's).
    pub title: String,
    /// Rendered result tables.
    pub tables: Vec<Table>,
    /// ASCII plots accompanying the tables.
    pub plots: Vec<String>,
    /// Paper-context notes printed under the tables.
    pub notes: Vec<String>,
    /// Machine-readable result (written to `reports/<id>.json`).
    pub json: Json,
}

impl ExperimentReport {
    /// The human-readable form `repro` prints: title, tables, plots,
    /// notes.
    pub fn render(&self) -> String {
        let mut out = format!("### {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        for p in &self.plots {
            out.push_str(p);
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }
}

/// One registry entry: everything the system needs to know about an
/// experiment besides its driver output. `ListExperiments`, `repro`,
/// `run_all`, and the benches all consume this table — adding an
/// experiment is one new row (plus its driver).
pub struct ExperimentSpec {
    /// Stable id (`repro <id>`, report filenames, bench labels).
    pub id: &'static str,
    /// Human title; must match the driver's `ExperimentReport::title`.
    pub title: &'static str,
    /// Paper section the artifact reproduces.
    pub section: &'static str,
    /// The driver regenerating the artifact from the simulator.
    pub runner: fn(&Config) -> ExperimentReport,
    /// Purity annotation: `true` when the runner is a pure function of
    /// its `Config` — every stochastic draw is seeded from `cfg.seed`
    /// (DESIGN.md §7), with no wall-clock, filesystem, or ambient
    /// state. This is what makes the driver's `repro` response safe to
    /// memoize: the service's result cache (`api::cache`) only caches
    /// experiments flagged deterministic. A future driver measuring
    /// real hardware or wall-clock time must set `false`.
    pub deterministic: bool,
}

/// Every experiment, in paper order (the DESIGN.md §5 index is the
/// prose version of this table).
pub const REGISTRY: &[ExperimentSpec] = &[
    ExperimentSpec {
        id: "table1",
        title: "System configuration",
        section: "§4",
        runner: micro::table1,
        deterministic: true,
    },
    ExperimentSpec {
        id: "table2",
        title: "Microbenchmark classes",
        section: "§4",
        runner: micro::table2,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig2",
        title: "FP8 matrix-core occupancy scaling",
        section: "§5",
        runner: micro::fig2,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig3",
        title: "Matrix shape effects",
        section: "§5",
        runner: micro::fig3,
        deterministic: true,
    },
    ExperimentSpec {
        id: "table3",
        title: "MFMA opcode coverage and baseline latency",
        section: "§5",
        runner: micro::table3,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig4",
        title: "ACE concurrency scaling",
        section: "§6",
        runner: ace::fig4,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig5",
        title: "Fairness and overlap characterization",
        section: "§6",
        runner: ace::fig5,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig6",
        title: "L2 contention",
        section: "§6",
        runner: ace::fig6,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig7",
        title: "LDS saturation",
        section: "§6",
        runner: ace::fig7,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig8",
        title: "Execution-time variance under contention",
        section: "§6",
        runner: ace::fig8,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig9",
        title: "Occupancy fragmentation",
        section: "§6",
        runner: ace::fig9,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig10",
        title: "Sparsity overhead characterization",
        section: "§7",
        runner: sparsity::fig10,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig11",
        title: "Sparsity speedup across problem sizes",
        section: "§7",
        runner: sparsity::fig11,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig12",
        title: "Comprehensive parameter sweep (60 configs)",
        section: "§7",
        runner: sparsity::fig12,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig13",
        title: "Sparsity under resource contention",
        section: "§7",
        runner: sparsity::fig13,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig14",
        title: "Transformer-style inference kernel",
        section: "§8",
        runner: apps::fig14,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig15",
        title: "Concurrent FP8 workloads with asynchronous execution",
        section: "§8",
        runner: apps::fig15,
        deterministic: true,
    },
    ExperimentSpec {
        id: "fig16",
        title: "Mixed-precision workload analysis",
        section: "§8",
        runner: apps::fig16,
        deterministic: true,
    },
];

/// Look up a registry entry by id.
pub fn spec(id: &str) -> Option<&'static ExperimentSpec> {
    REGISTRY.iter().find(|s| s.id == id)
}

/// Run every experiment with up to `workers` driver threads, returning
/// reports in [`REGISTRY`] order. Each driver is seed-deterministic and
/// independent, and `pool::scoped_map` merges results in item order, so
/// the output is byte-identical to the serial path for any worker count
/// (enforced by `tests/parallel_determinism.rs`). Callers exposing the
/// service `stats` counters must count these driver executions
/// themselves (see `api::Service::repro_all`); ad-hoc sweeps beyond
/// the registry are better expressed as `api::scenario` specs, which
/// count and cache per point automatically.
pub fn run_all(cfg: &Config, workers: usize) -> Vec<ExperimentReport> {
    crate::util::pool::scoped_map(REGISTRY, workers, |_, s| (s.runner)(cfg))
}

/// Run one experiment by id.
pub fn run(id: &str, cfg: &Config) -> Option<ExperimentReport> {
    spec(id).map(|s| (s.runner)(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_runs_and_renders() {
        let cfg = Config::mi300a();
        for s in REGISTRY {
            let id = s.id;
            let r = run(id, &cfg).unwrap_or_else(|| panic!("{id} missing"));
            let text = r.render();
            assert!(text.contains(id), "{id} render");
            assert!(
                !r.tables.is_empty() || !r.plots.is_empty(),
                "{id} produced no output"
            );
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run("fig99", &Config::mi300a()).is_none());
        assert!(spec("fig99").is_none());
    }

    #[test]
    fn registry_entries_are_unique_and_well_formed() {
        assert_eq!(REGISTRY.len(), 18, "one entry per paper artifact");
        for (i, s) in REGISTRY.iter().enumerate() {
            assert!(!s.title.is_empty(), "{}: empty title", s.id);
            assert!(s.section.starts_with('§'), "{}: bad section", s.id);
            assert!(
                REGISTRY[..i].iter().all(|t| t.id != s.id),
                "duplicate id {:?}",
                s.id
            );
        }
    }

    #[test]
    fn registry_titles_match_driver_reports() {
        let cfg = Config::mi300a();
        // Spot-check one driver per module (running all 18 here would
        // duplicate the integration suite's full pass).
        for id in ["table1", "fig4", "fig10", "fig14"] {
            let s = spec(id).unwrap();
            assert_eq!((s.runner)(&cfg).title, s.title, "{id}");
        }
    }

    #[test]
    fn run_all_covers_every_id_in_order() {
        let cfg = Config::mi300a();
        let reports = run_all(&cfg, 4);
        assert_eq!(reports.len(), REGISTRY.len());
        for (r, s) in reports.iter().zip(REGISTRY) {
            assert_eq!(r.id, s.id);
        }
    }

    #[test]
    fn reports_are_deterministic() {
        let cfg = Config::mi300a();
        for id in ["fig4", "fig13"] {
            assert!(
                spec(id).unwrap().deterministic,
                "{id} must be flagged deterministic"
            );
            let a = run(id, &cfg).unwrap().render();
            let b = run(id, &cfg).unwrap().render();
            assert_eq!(a, b, "{id} must be seed-deterministic");
        }
    }

    #[test]
    fn every_driver_on_the_simulated_substrate_is_deterministic() {
        // The whole registry runs on the seeded simulator (DESIGN.md
        // §7), so every entry is cacheable today. A driver measuring
        // real hardware must flip its flag — and this test — when it
        // lands.
        for s in REGISTRY {
            assert!(s.deterministic, "{}: unexpected nondeterminism", s.id);
        }
    }
}
