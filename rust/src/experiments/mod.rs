//! Experiment drivers: one per paper table/figure (DESIGN.md §5 index).
//!
//! Every driver regenerates its artifact's rows/series from the
//! simulator and returns an [`ExperimentReport`] (tables + ASCII plots +
//! machine-readable JSON). `mi300a-char repro <id>` prints them;
//! `rust/benches/` wraps them for `cargo bench`; EXPERIMENTS.md records
//! paper-vs-measured.

pub mod ace;
pub mod apps;
pub mod micro;
pub mod sparsity;

use crate::config::Config;
use crate::report::Table;
use crate::util::json::Json;

/// The output of one experiment driver.
#[derive(Debug, Clone)]
pub struct ExperimentReport {
    pub id: &'static str,
    pub title: String,
    pub tables: Vec<Table>,
    pub plots: Vec<String>,
    /// Paper-context notes printed under the tables.
    pub notes: Vec<String>,
    /// Machine-readable result (written to reports/<id>.json).
    pub json: Json,
}

impl ExperimentReport {
    pub fn render(&self) -> String {
        let mut out = format!("### {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        for p in &self.plots {
            out.push_str(p);
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }
}

/// All experiment ids in paper order.
pub const ALL_IDS: &[&str] = &[
    "table1", "table2", "fig2", "fig3", "table3", "fig4", "fig5", "fig6",
    "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14",
    "fig15", "fig16",
];

/// Run every experiment with up to `workers` driver threads, returning
/// reports in `ALL_IDS` order. Each driver is seed-deterministic and
/// independent, and `pool::scoped_map` merges results in item order, so
/// the output is byte-identical to the serial path for any worker count
/// (enforced by `tests/parallel_determinism.rs`).
pub fn run_all(cfg: &Config, workers: usize) -> Vec<ExperimentReport> {
    crate::util::pool::scoped_map(ALL_IDS, workers, |_, id| {
        run(id, cfg).expect("ALL_IDS entries are known ids")
    })
}

/// Run one experiment by id.
pub fn run(id: &str, cfg: &Config) -> Option<ExperimentReport> {
    match id {
        "table1" => Some(micro::table1(cfg)),
        "table2" => Some(micro::table2(cfg)),
        "fig2" => Some(micro::fig2(cfg)),
        "fig3" => Some(micro::fig3(cfg)),
        "table3" => Some(micro::table3(cfg)),
        "fig4" => Some(ace::fig4(cfg)),
        "fig5" => Some(ace::fig5(cfg)),
        "fig6" => Some(ace::fig6(cfg)),
        "fig7" => Some(ace::fig7(cfg)),
        "fig8" => Some(ace::fig8(cfg)),
        "fig9" => Some(ace::fig9(cfg)),
        "fig10" => Some(sparsity::fig10(cfg)),
        "fig11" => Some(sparsity::fig11(cfg)),
        "fig12" => Some(sparsity::fig12(cfg)),
        "fig13" => Some(sparsity::fig13(cfg)),
        "fig14" => Some(apps::fig14(cfg)),
        "fig15" => Some(apps::fig15(cfg)),
        "fig16" => Some(apps::fig16(cfg)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_runs_and_renders() {
        let cfg = Config::mi300a();
        for id in ALL_IDS {
            let r = run(id, &cfg).unwrap_or_else(|| panic!("{id} missing"));
            let text = r.render();
            assert!(text.contains(id), "{id} render");
            assert!(
                !r.tables.is_empty() || !r.plots.is_empty(),
                "{id} produced no output"
            );
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run("fig99", &Config::mi300a()).is_none());
    }

    #[test]
    fn run_all_covers_every_id_in_order() {
        let cfg = Config::mi300a();
        let reports = run_all(&cfg, 4);
        assert_eq!(reports.len(), ALL_IDS.len());
        for (r, id) in reports.iter().zip(ALL_IDS) {
            assert_eq!(&r.id, id);
        }
    }

    #[test]
    fn reports_are_deterministic() {
        let cfg = Config::mi300a();
        for id in ["fig4", "fig13"] {
            let a = run(id, &cfg).unwrap().render();
            let b = run(id, &cfg).unwrap().render();
            assert_eq!(a, b, "{id} must be seed-deterministic");
        }
    }
}
