//! §8 experiments: application kernels (Figs 14-16).

use super::ExperimentReport;
use crate::config::Config;
use crate::isa::Precision;
use crate::metrics::Summary;
use crate::report::{ascii_plot, Table};
use crate::sim::{ConcurrencyProfile, CostModel, Engine, KernelDesc};
use crate::util::json::Json;
use crate::util::pool;
use crate::workload::{MixedChain, TransformerWorkload};

/// Fig 14: transformer-style FP8 GEMM throughput (normalized to best)
/// vs matrix dimension M = N = K.
pub fn fig14(cfg: &Config) -> ExperimentReport {
    let micro = crate::sim::MicrobenchModel::new(cfg);
    let dims = [64usize, 128, 256, 512, 1024, 2048, 4096];
    // Transformer-style FP8 GEMM with a fixed 128-tile: wavefronts grow
    // with the dimension (occupancy climbs toward the Fig-2 knee), and
    // past ~2048 the working set blows L2 and the realized rate
    // collapses — producing the paper's peak at moderate dimensions.
    let gflops: Vec<f64> =
        pool::scoped_map(&dims, pool::default_workers(), |_, &n| {
            let waves = ((n + 127) / 128).pow(2);
            let compute = micro.throughput_gflops(Precision::Fp8, waves);
            let ws = KernelDesc::gemm(n, Precision::Fp8).working_set();
            let over = (ws / cfg.l2_bytes() - 1.0).max(0.0);
            compute / (1.0 + 4.0 * over)
        });
    let best = gflops.iter().cloned().fold(0.0, f64::max);
    let normalized: Vec<f64> = gflops.iter().map(|g| g / best).collect();

    let mut t = Table::new(
        "Fig 14 — transformer-style FP8 GEMM: throughput vs dimension",
        &["M=N=K", "GFLOPS", "normalized", "wavefronts"],
    );
    let mut json_rows = Vec::new();
    for (i, &n) in dims.iter().enumerate() {
        let waves = ((n + 127) / 128).pow(2);
        t.row(vec![
            n.to_string(),
            format!("{:.0}", gflops[i]),
            format!("{:.2}", normalized[i]),
            waves.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("dim", Json::Num(n as f64)),
            ("gflops", Json::Num(gflops[i])),
            ("normalized", Json::Num(normalized[i])),
            ("waves", Json::Num(waves as f64)),
        ]));
    }
    let x: Vec<f64> = dims.iter().map(|&d| (d as f64).log2()).collect();
    let plot = ascii_plot(
        "Fig 14: normalized throughput vs log2 dim",
        &x,
        &[("fp8 gemm", normalized.clone())],
        10,
    );
    // Batch-size guidance from the workload model (paper §8.1/§9.1).
    let w32 = TransformerWorkload::new(128, 512).with_batch(32);
    let w64 = TransformerWorkload::new(128, 512).with_batch(64);
    ExperimentReport {
        id: "fig14",
        title: "Transformer-style inference kernel".into(),
        tables: vec![t],
        plots: vec![plot],
        notes: vec![
            "paper: small sizes underutilize matrix cores; throughput \
             peaks at moderate dimensions".into(),
            format!(
                "workload check: batch 32 -> {} peak waves (< FP8 target \
                 256); batch 64 -> {}",
                w32.peak_wavefronts(),
                w64.peak_wavefronts()
            ),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 15: two concurrent FP8 transformer-style workloads on separate
/// queues — aggregate throughput and per-stream times.
pub fn fig15(cfg: &Config) -> ExperimentReport {
    let engine = Engine::new(cfg, ConcurrencyProfile::case_study());
    // One "workload instance" = the 4-GEMM chain collapsed to its
    // dominant GEMM repeated per chain element, 50 chain iterations.
    let w = TransformerWorkload::new(128, 1024).with_batch(4);
    let dominant = w
        .gemms()
        .into_iter()
        .max_by(|a, b| a.flops().partial_cmp(&b.flops()).unwrap())
        .unwrap()
        .with_iters(50);

    // Solo and duo runs are independent: run them concurrently, then
    // derive the speedup from the same duo run (no re-simulation).
    let duo_set = vec![dominant.clone(); 2];
    let (solo, duo) = pool::join(
        || engine.run_solo(&dominant, cfg.seed + 150),
        || engine.run(&duo_set, cfg.seed + 150),
    );
    let flops = vec![dominant.flops(); 2];
    let agg_solo = solo.aggregate_gflops(&flops[..1]);
    let agg_duo = duo.aggregate_gflops(&flops);
    let speedup = engine.serial_makespan_ns(&duo_set, cfg.seed + 150)
        / duo.makespan_ns;

    let mut t = Table::new(
        "Fig 15 — two concurrent FP8 workloads",
        &["metric", "1 instance", "2 instances"],
    );
    t.row(vec![
        "aggregate GFLOPS".into(),
        format!("{agg_solo:.0}"),
        format!("{agg_duo:.0}"),
    ]);
    t.row(vec![
        "makespan (ms)".into(),
        format!("{:.2}", solo.makespan_ns / 1e6),
        format!("{:.2}", duo.makespan_ns / 1e6),
    ]);
    t.row(vec![
        "overlap efficiency".into(),
        "-".into(),
        format!("{:.1}%", duo.overlap_efficiency * 100.0),
    ]);
    let totals = duo.per_stream_totals();
    let spread = (totals[0] - totals[1]).abs()
        / (totals.iter().sum::<f64>() / 2.0);
    t.row(vec![
        "per-stream spread".into(),
        "-".into(),
        format!("{:.1}%", spread * 100.0),
    ]);
    ExperimentReport {
        id: "fig15",
        title: "Concurrent FP8 workloads with asynchronous execution".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            format!("concurrent speedup vs serial: {speedup:.2}x \
                     (paper: limited overlap + visible variability)"),
        ],
        json: Json::obj(vec![
            ("agg_solo_gflops", Json::Num(agg_solo)),
            ("agg_duo_gflops", Json::Num(agg_duo)),
            ("speedup", Json::Num(speedup)),
            ("overlap", Json::Num(duo.overlap_efficiency)),
            ("spread", Json::Num(spread)),
        ]),
    }
}

/// Fig 16: mixed-precision workload — per-operation execution time by
/// precision, isolated vs concurrent.
pub fn fig16(cfg: &Config) -> ExperimentReport {
    let cost = CostModel::new(cfg);
    let engine = Engine::new(cfg, ConcurrencyProfile::case_study());
    let chain = MixedChain::new(1024);

    let mut t = Table::new(
        "Fig 16 — mixed-precision chain: per-op execution time",
        &["op", "isolated (µs)", "concurrent x4 (µs)", "slowdown", "cv"],
    );
    let mut json_rows = Vec::new();
    // Concurrent context: the three precisions co-run on separate
    // streams (the §8.3 pipeline), iteration counts equalized so the
    // mix persists for the whole window. Short FP8 iterations then see
    // frequent co-run-set changes — the paper's "greater variability
    // under contention" for FP8.
    let iso: Vec<f64> = chain
        .ops
        .iter()
        .map(|op| cost.solo_work_ns(&op.kernel))
        .collect();
    let max_iso = iso.iter().cloned().fold(0.0, f64::max);
    let base_iters = 10usize;
    let mixed_set: Vec<KernelDesc> = chain
        .ops
        .iter()
        .zip(&iso)
        .map(|(op, &t)| {
            let iters = (base_iters as f64 * max_iso / t).round() as usize;
            op.kernel.clone().with_iters(iters.clamp(base_iters, 600))
        })
        .collect();
    let run = engine.run(&mixed_set, cfg.seed + 160);
    for ((op, iso_ns), stream) in
        chain.ops.iter().zip(iso.clone()).zip(&run.streams)
    {
        let sm = Summary::of(&stream.iter_ns);
        let conc_ns = sm.mean;
        t.row(vec![
            op.name.into(),
            format!("{:.1}", iso_ns / 1e3),
            format!("{:.1}", conc_ns / 1e3),
            format!("{:.2}x", conc_ns / iso_ns),
            format!("{:.2}", sm.cv()),
        ]);
        json_rows.push(Json::obj(vec![
            ("op", Json::Str(op.name.into())),
            ("isolated_ns", Json::Num(iso_ns)),
            ("concurrent_ns", Json::Num(conc_ns)),
            ("cv", Json::Num(sm.cv())),
        ]));
    }
    ExperimentReport {
        id: "fig16",
        title: "Mixed-precision workload analysis".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            "paper: FP8 ops benefit from batching/occupancy, FP32 less \
             sensitive; under concurrency FP8 shows greater variability \
             -> precision-aware scheduling".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_small_dims_underutilize() {
        let r = fig14(&Config::mi300a());
        let rows = r.json.as_arr().unwrap();
        let n64 = rows[0].get("normalized").unwrap().as_f64().unwrap();
        let best = rows
            .iter()
            .map(|x| x.get("normalized").unwrap().as_f64().unwrap())
            .fold(0.0, f64::max);
        assert!(n64 < 0.3, "64^3 should be far from best: {n64}");
        assert!((best - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fig15_two_instances_beat_one_but_not_2x() {
        let r = fig15(&Config::mi300a());
        let sp = r.json.get("speedup").unwrap().as_f64().unwrap();
        assert!(sp > 1.0 && sp < 2.0, "limited overlap: {sp}");
    }

    #[test]
    fn fig16_fp8_more_variable_under_contention() {
        let r = fig16(&Config::mi300a());
        let rows = r.json.as_arr().unwrap();
        let cv = |name: &str| {
            rows.iter()
                .find(|x| x.get("op").unwrap().as_str() == Some(name))
                .unwrap()
                .get("cv").unwrap().as_f64().unwrap()
        };
        assert!(
            cv("fp8_gemm") >= cv("fp32_gemm") * 0.5,
            "FP8 variability should be visible (fp8 {} vs fp32 {})",
            cv("fp8_gemm"),
            cv("fp32_gemm")
        );
    }
}
