//! §7 experiments: structured sparsity (Figs 10-13).

use super::ExperimentReport;
use crate::config::Config;
use crate::isa::Precision;
use crate::metrics::fairness_minmax;
use crate::report::{ascii_plot, Table};
use crate::sim::{ConcurrencyProfile, CostModel, Engine, KernelDesc, SparsityMode};
use crate::sparsity::{OverheadModel, SpeedupModel};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;

const SIZES: [usize; 4] = [256, 512, 2048, 8192];
const PATTERNS: [SparsityMode; 3] = [
    SparsityMode::SparseLhs,
    SparsityMode::SparseRhs,
    SparsityMode::SparseBoth,
];

/// Fig 10: sparsity encoding overhead vs matrix size (constant).
pub fn fig10(cfg: &Config) -> ExperimentReport {
    let model = OverheadModel::new(cfg);
    let mut t = Table::new(
        "Fig 10 — sparsity encoding overhead vs matrix size (µs)",
        &["size", "LHS-only", "RHS-only", "both-side"],
    );
    let mut json_rows = Vec::new();
    let mut series: Vec<(&str, Vec<f64>)> = vec![
        ("LHS", Vec::new()),
        ("RHS", Vec::new()),
        ("both", Vec::new()),
    ];
    // Per-size replication sets are independent; each derives its own
    // RNG stream from (seed, size index), so the fan-out stays
    // byte-identical for any worker count.
    let cells: Vec<Vec<f64>> =
        pool::scoped_map(&SIZES, pool::default_workers(), |si, &n| {
            let mut rng = Rng::new(
                cfg.seed ^ 0xf16_10 ^ ((si as u64 + 1) * 0x9E37_79B9),
            );
            PATTERNS
                .iter()
                .map(|&mode| {
                    // Stable average over repeated samples (paper: 50
                    // runs).
                    (0..50)
                        .map(|_| model.sample_ns(mode, n, &mut rng) / 1e3)
                        .sum::<f64>()
                        / 50.0
                })
                .collect()
        });
    for (&n, us_row) in SIZES.iter().zip(&cells) {
        let mut row = vec![format!("{n}^3")];
        let mut jrow = vec![("size", Json::Num(n as f64))];
        for (i, &mode) in PATTERNS.iter().enumerate() {
            let us = us_row[i];
            row.push(format!("{us:.2}"));
            jrow.push((mode.name(), Json::Num(us)));
            series[i].1.push(us);
        }
        t.row(row);
        json_rows.push(Json::obj(jrow));
    }
    let x: Vec<f64> = SIZES.iter().map(|&n| (n as f64).log2()).collect();
    let plot = ascii_plot("Fig 10: overhead (µs) vs log2 size", &x, &series, 8);
    // Component breakdown (paper §7.1.1 rocprof profile).
    let b = model.mean(SparsityMode::SparseLhs);
    let mut tb = Table::new(
        "overhead components (rocprof-equivalent decomposition)",
        &["component", "µs"],
    );
    tb.row(vec!["format conversion".into(),
                format!("{:.1}", b.format_conversion_ns / 1e3)]);
    tb.row(vec!["metadata alloc".into(),
                format!("{:.1}", b.metadata_alloc_ns / 1e3)]);
    tb.row(vec!["API dispatch".into(), format!("{:.1}", b.dispatch_ns / 1e3)]);
    ExperimentReport {
        id: "fig10",
        title: "Sparsity overhead characterization".into(),
        tables: vec![t, tb],
        plots: vec![plot],
        notes: vec![
            "paper: 3.5-3.9 µs single-side, 5.3-5.8 µs both-side, \
             constant across sizes (prevents amortization)".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 11: isolated sparsity speedup vs matrix size per pattern.
pub fn fig11(cfg: &Config) -> ExperimentReport {
    let model = SpeedupModel::new(cfg);
    let mut t = Table::new(
        "Fig 11 — isolated sparse speedup vs size",
        &["size", "LHS-only", "RHS-only", "both-side"],
    );
    let mut json_rows = Vec::new();
    for &n in &SIZES {
        let dense = KernelDesc::gemm(n, Precision::Fp8);
        let mut row = vec![format!("{n}^3")];
        let mut jrow = vec![("size", Json::Num(n as f64))];
        for &mode in &PATTERNS {
            let s = model.isolated(&dense, mode).speedup();
            row.push(format!("{s:.3}x"));
            jrow.push((mode.name(), Json::Num(s)));
        }
        t.row(row);
        json_rows.push(Json::obj(jrow));
    }
    // The §7.1.2 rectangular exception.
    let rect = KernelDesc::gemm(512, Precision::Fp8).with_shape(512, 2048, 1024);
    let rect_speedup = model.isolated(&rect, SparsityMode::SparseLhs).speedup();
    ExperimentReport {
        id: "fig11",
        title: "Sparsity speedup across problem sizes".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            "paper: 0.98-1.02x across all square sizes (break-even)".into(),
            format!(
                "rectangular 512x2048x1024: {rect_speedup:.2}x (paper \
                 1.6-1.76x)"
            ),
        ],
        json: Json::obj(vec![
            ("square", Json::Arr(json_rows)),
            ("rect_512x2048x1024", Json::Num(rect_speedup)),
        ]),
    }
}

/// Fig 12: the 60-configuration speedup heatmap (4 sizes x 5 aspect
/// ratios x 3 patterns), isolated execution.
pub fn fig12(cfg: &Config) -> ExperimentReport {
    let model = SpeedupModel::new(cfg);
    let aspects: [f64; 5] = [0.25, 0.5, 1.0, 2.0, 4.0];
    let mut t = Table::new(
        "Fig 12 — speedup heatmap (rows: size x pattern, cols: aspect)",
        &["config", "0.25", "0.5", "1.0", "2.0", "4.0"],
    );
    let mut cells = Vec::new();
    let (mut min_s, mut max_s) = (f64::INFINITY, 0.0f64);
    for &n in &SIZES {
        for &mode in &PATTERNS {
            let mut row = vec![format!("{n}^3 {}", mode.name())];
            for &a in &aspects {
                // Aspect-swept square-total-work shape: M = n*sqrt(a),
                // N = n/sqrt(a) (total work constant), K = n.
                let m = ((n as f64) * a.sqrt()).round() as usize;
                let nn = ((n as f64) / a.sqrt()).round() as usize;
                let k = KernelDesc::gemm(n, Precision::Fp8)
                    .with_shape(m.max(4), nn.max(4), n);
                // Square-equivalent policy: the heatmap varies aspect but
                // the paper reports square configs as break-even; only
                // >=2x skews trigger the rectangular overlap path.
                let s = model.isolated(&k, mode).speedup();
                min_s = min_s.min(s);
                max_s = max_s.max(s);
                row.push(format!("{s:.2}"));
                cells.push(Json::obj(vec![
                    ("size", Json::Num(n as f64)),
                    ("aspect", Json::Num(a)),
                    ("pattern", Json::Str(mode.name().into())),
                    ("speedup", Json::Num(s)),
                ]));
            }
            t.row(row);
        }
    }
    ExperimentReport {
        id: "fig12",
        title: "Comprehensive parameter sweep (60 configs)".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            format!("speedup range {min_s:.2}-{max_s:.2} over 60 configs"),
            "paper: predominantly 0.97-1.02x (break-even) for square-work \
             configs; strong skews benefit from overhead overlap".into(),
        ],
        json: Json::Arr(cells),
    }
}

/// Fig 13: sparsity under contention — (a) min/max fairness,
/// (b) aggregate throughput, (c) per-stream sparse/dense speedup.
pub fn fig13(cfg: &Config) -> ExperimentReport {
    let engine = Engine::new(cfg, ConcurrencyProfile::sparsity());
    let speedup_model = SpeedupModel::new(cfg);
    let cost = CostModel::new(cfg);
    let dense_k = KernelDesc::gemm(512, Precision::Fp8).with_iters(50);
    let sparse_k = dense_k.clone().with_sparsity(SparsityMode::SparseLhs);

    let mut ta = Table::new(
        "Fig 13a — fairness (min/max) vs streams",
        &["streams", "dense", "sparse", "mixed"],
    );
    let mut tb = Table::new(
        "Fig 13b — aggregate throughput (GFLOPS) vs streams",
        &["streams", "dense", "sparse", "mixed"],
    );
    let mut json_rows = Vec::new();
    // Per-stream-count replication cells (the paper's repeated-run
    // protocol) are independent and seed-derived: fan out across the
    // pool.
    let counts = [1usize, 2, 4];
    let cells: Vec<Vec<(&'static str, f64, f64)>> =
        pool::scoped_map(&counts, pool::default_workers(), |_, &s| {
            let dense_set = vec![dense_k.clone(); s];
            let sparse_set = vec![sparse_k.clone(); s];
            let mixed_set: Vec<KernelDesc> = (0..s)
                .map(|i| {
                    if i % 2 == 0 {
                        sparse_k.clone()
                    } else {
                        dense_k.clone()
                    }
                })
                .collect();
            let runs = [
                ("dense", &dense_set),
                ("sparse", &sparse_set),
                ("mixed", &mixed_set),
            ];
            runs.iter()
                .map(|&(name, set)| {
                    // Fairness is a stable average over repeated runs
                    // (the paper's 50-run protocol); throughput from
                    // the first run.
                    let reps = 12u64;
                    let f = if s == 1 {
                        1.0
                    } else {
                        (0..reps)
                            .map(|r| {
                                fairness_minmax(
                                    &engine
                                        .run(set, cfg.seed + 130 + r * 7)
                                        .per_stream_totals(),
                                )
                            })
                            .sum::<f64>()
                            / reps as f64
                    };
                    let run = engine.run(set, cfg.seed + 130);
                    // Dense-equivalent FLOPs per iteration per stream.
                    let flops: Vec<f64> = vec![dense_k.flops(); s];
                    let gflops = run.aggregate_gflops(&flops);
                    (name, f, gflops)
                })
                .collect()
        });
    for (&s, cell) in counts.iter().zip(&cells) {
        let mut fa = vec![s.to_string()];
        let mut fb = vec![s.to_string()];
        let mut jrow = vec![("streams", Json::Num(s as f64))];
        for &(name, f, gflops) in cell {
            fa.push(format!("{f:.2}"));
            fb.push(format!("{gflops:.1}"));
            jrow.push((
                name,
                Json::obj(vec![
                    ("fairness", Json::Num(f)),
                    ("gflops", Json::Num(gflops)),
                ]),
            ));
        }
        ta.row(fa);
        tb.row(fb);
        json_rows.push(Json::obj(jrow));
    }

    // (c) per-stream sparse/dense speedup: model + DES cross-check.
    let mut tc = Table::new(
        "Fig 13c — per-stream sparse vs dense speedup",
        &["streams", "speedup"],
    );
    let mut json_c = Vec::new();
    for &s in &[1usize, 2, 3, 4] {
        let sp = speedup_model.concurrent_per_stream(&dense_k, s);
        tc.row(vec![s.to_string(), format!("{sp:.2}x")]);
        json_c.push(Json::obj(vec![
            ("streams", Json::Num(s as f64)),
            ("speedup", Json::Num(sp)),
        ]));
    }

    let d1 = cost.solo_gflops(&dense_k);
    ExperimentReport {
        id: "fig13",
        title: "Sparsity under resource contention".into(),
        tables: vec![ta, tb, tc],
        plots: vec![],
        notes: vec![
            format!("modeled dense solo rate: {d1:.0} GFLOPS (scaled by the \
                     §7 profile's work_scale to the paper's 59.98)"),
            "paper: dense 59.98/116.69/213.93, sparse 52.1/109.4/234.2, \
             mixed 60.8/112.1/235.5 GFLOPS; fairness @4: dense 0.91, \
             sparse 0.98, mixed 0.97; per-stream speedup constant 1.3x".into(),
        ],
        json: Json::obj(vec![
            ("scaling", Json::Arr(json_rows)),
            ("per_stream", Json::Arr(json_c)),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_overhead_constant_across_sizes() {
        let r = fig10(&Config::mi300a());
        let rows = r.json.as_arr().unwrap();
        let first = rows[0].get("lhs").unwrap().as_f64().unwrap();
        let last = rows.last().unwrap().get("lhs").unwrap().as_f64().unwrap();
        assert!(
            (first - last).abs() < 0.5,
            "overhead must be ~constant: {first} vs {last} µs"
        );
    }

    #[test]
    fn fig11_square_break_even() {
        let r = fig11(&Config::mi300a());
        for row in r.json.get("square").unwrap().as_arr().unwrap() {
            for mode in ["lhs", "rhs", "both"] {
                let s = row.get(mode).unwrap().as_f64().unwrap();
                assert!((0.9..=1.1).contains(&s), "{mode}: {s}");
            }
        }
        let rect = r.json.get("rect_512x2048x1024").unwrap().as_f64().unwrap();
        assert!(rect > 1.3, "rectangular exception: {rect}");
    }

    #[test]
    fn fig12_has_60_cells() {
        let r = fig12(&Config::mi300a());
        assert_eq!(r.json.as_arr().unwrap().len(), 60);
    }

    #[test]
    fn fig13_sparse_overtakes_dense_at_4_streams() {
        let r = fig13(&Config::mi300a());
        let rows = r.json.get("scaling").unwrap().as_arr().unwrap();
        let at = |s: f64, name: &str, field: &str| {
            rows.iter()
                .find(|x| x.get("streams").unwrap().as_f64() == Some(s))
                .unwrap()
                .get(name).unwrap()
                .get(field).unwrap()
                .as_f64().unwrap()
        };
        // Crossover: dense wins solo, sparse wins at 4 streams.
        assert!(at(1.0, "dense", "gflops") > at(1.0, "sparse", "gflops"));
        assert!(at(4.0, "sparse", "gflops") > at(4.0, "dense", "gflops"));
        // Fairness: sparse at 4 streams more balanced than dense.
        assert!(at(4.0, "sparse", "fairness") > at(4.0, "dense", "fairness"));
    }

    #[test]
    fn fig13c_speedup_stable() {
        let r = fig13(&Config::mi300a());
        let c = r.json.get("per_stream").unwrap().as_arr().unwrap();
        for row in c.iter().skip(1) {
            let s = row.get("speedup").unwrap().as_f64().unwrap();
            assert!((1.2..=1.4).contains(&s), "~1.3x expected: {s}");
        }
    }
}
