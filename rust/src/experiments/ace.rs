//! §6 experiments: ACE concurrency (Figs 4-9).

use super::ExperimentReport;
use crate::config::Config;
use crate::hw::lds::lds_utilization;
use crate::hw::L2Model;
use crate::isa::Precision;
use crate::metrics::{fairness, overlap_efficiency, Summary};
use crate::report::{ascii_plot, Table};
use crate::sim::{ConcurrencyProfile, Engine, KernelDesc};
use crate::util::json::Json;
use crate::util::pool;

const PRECISIONS: [Precision; 3] =
    [Precision::F32, Precision::F16, Precision::Fp8];

fn baseline(p: Precision, iters: usize) -> KernelDesc {
    KernelDesc::gemm(512, p).with_iters(iters)
}

/// Fig 4: speedup vs concurrent streams (512^3, no contention).
pub fn fig4(cfg: &Config) -> ExperimentReport {
    let engine = Engine::new(cfg, ConcurrencyProfile::ace());
    let stream_counts = [1usize, 2, 4, 8];
    let mut t = Table::new(
        "Fig 4 — speedup vs concurrent streams (512^3, 100 iters)",
        &["streams", "FP32", "FP16", "FP8", "overlap FP32"],
    );
    let mut json_rows = Vec::new();
    let mut series: Vec<(&str, Vec<f64>)> =
        PRECISIONS.iter().map(|p| (p.name(), Vec::new())).collect();
    // Per-stream-count replications are independent and deterministic:
    // fan out across the pool. One concurrent run per cell — speedup is
    // derived from it plus the serial makespan, not re-simulated.
    let cells: Vec<(Vec<f64>, f64)> =
        pool::scoped_map(&stream_counts, pool::default_workers(), |_, &s| {
            let mut sps = Vec::with_capacity(PRECISIONS.len());
            let mut overlap32 = 0.0;
            for &p in &PRECISIONS {
                let ks = vec![baseline(p, 100); s];
                let run = engine.run(&ks, cfg.seed + 40);
                let sp = engine.serial_makespan_ns(&ks, cfg.seed + 40)
                    / run.makespan_ns;
                if p == Precision::F32 {
                    overlap32 = run.overlap_efficiency;
                }
                sps.push(sp);
            }
            (sps, overlap32)
        });
    for (&s, (sps, overlap32)) in stream_counts.iter().zip(&cells) {
        let mut row = vec![s.to_string()];
        let mut jrow = vec![("streams", Json::Num(s as f64))];
        for (pi, &p) in PRECISIONS.iter().enumerate() {
            let sp = sps[pi];
            series[pi].1.push(sp);
            row.push(format!("{sp:.2}x"));
            jrow.push((p.name(), Json::Num(sp)));
        }
        row.push(format!("{:.1}%", overlap32 * 100.0));
        jrow.push(("overlap_fp32", Json::Num(*overlap32)));
        t.row(row);
        json_rows.push(Json::obj(jrow));
    }
    let x: Vec<f64> = stream_counts.iter().map(|&s| s as f64).collect();
    let plot = ascii_plot("Fig 4: speedup vs streams", &x, &series, 10);
    ExperimentReport {
        id: "fig4",
        title: "ACE concurrency scaling".into(),
        tables: vec![t],
        plots: vec![plot],
        notes: vec![
            "paper: 1.78-1.83x at 4 streams (overlap 43-46%), 2.79-2.87x \
             at 8 (overlap 64-65%)".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 5: (a) overlap vs fairness per precision/stream-count;
/// (b) contention sweep for FP32 at 4 streams.
pub fn fig5(cfg: &Config) -> ExperimentReport {
    let engine = Engine::new(cfg, ConcurrencyProfile::ace());
    let mut ta = Table::new(
        "Fig 5a — overlap efficiency vs fairness",
        &["precision", "streams", "overlap", "fairness", "cv"],
    );
    let mut json_a = Vec::new();
    // (stream count x precision) cells are independent runs: fan out.
    let combos: Vec<(usize, Precision)> = [4usize, 8]
        .iter()
        .flat_map(|&s| PRECISIONS.iter().map(move |&p| (s, p)))
        .collect();
    let cells_a: Vec<(f64, f64, f64, f64)> =
        pool::scoped_map(&combos, pool::default_workers(), |_, &(s, p)| {
            let run = engine.run(&vec![baseline(p, 100); s], cfg.seed + 50);
            let totals = run.per_stream_totals();
            let f = fairness(&totals);
            let cv = Summary::of(&totals).cv();
            let intervals: Vec<(f64, f64)> = run
                .streams
                .iter()
                .map(|st| (st.start_ns, st.end_ns))
                .collect();
            let ov = overlap_efficiency(&intervals)
                .max(run.overlap_efficiency);
            (run.overlap_efficiency, ov, f, cv)
        });
    for (&(s, p), &(overlap, ov, f, cv)) in combos.iter().zip(&cells_a) {
        ta.row(vec![
            p.name().into(),
            s.to_string(),
            format!("{:.1}%", overlap * 100.0),
            format!("{f:.3}"),
            format!("{cv:.2}"),
        ]);
        json_a.push(Json::obj(vec![
            ("precision", Json::Str(p.name().into())),
            ("streams", Json::Num(s as f64)),
            ("overlap", Json::Num(overlap)),
            ("overlap_interval", Json::Num(ov)),
            ("fairness", Json::Num(f)),
            ("cv", Json::Num(cv)),
        ]));
    }

    let mut tb = Table::new(
        "Fig 5b — contention sweep (FP32, 4 streams)",
        &["level", "overlap", "speedup", "fairness"],
    );
    let mut json_b = Vec::new();
    // Contention levels are independent sweeps: one engine per level
    // (contention_level is per-engine state), fanned out.
    let levels: [f64; 6] = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
    let cells_b: Vec<(f64, f64, f64)> =
        pool::scoped_map(&levels, pool::default_workers(), |_, &level| {
            let mut sweep =
                Engine::new(cfg, ConcurrencyProfile::contention_sweep());
            sweep.contention_level = level;
            let ks = vec![baseline(Precision::F32, 100); 4];
            let run = sweep.run(&ks, cfg.seed + 51);
            let sp = sweep.serial_makespan_ns(&ks, cfg.seed + 51)
                / run.makespan_ns;
            let f = fairness(&run.per_stream_totals());
            (run.overlap_efficiency, sp, f)
        });
    for (level, &(overlap, sp, f)) in (0..=5).zip(&cells_b) {
        tb.row(vec![
            level.to_string(),
            format!("{:.1}%", overlap * 100.0),
            format!("{sp:.2}x"),
            format!("{f:.3}"),
        ]);
        json_b.push(Json::obj(vec![
            ("level", Json::Num(level as f64)),
            ("overlap", Json::Num(overlap)),
            ("speedup", Json::Num(sp)),
            ("fairness", Json::Num(f)),
        ]));
    }
    ExperimentReport {
        id: "fig5",
        title: "Fairness and overlap characterization".into(),
        tables: vec![ta, tb],
        plots: vec![],
        notes: vec![
            "paper 5a: fairness 0.51-0.61 @4 (CV 0.19-0.22); @8 FP16 \
             0.016 (CV 0.41), FP32 0.052 (CV 0.40), FP8 0.138 (CV 0.31)".into(),
            "paper 5b: overlap ~60.4% stable, speedup 2.52-2.53x, \
             fairness 0.263 -> 0.250-0.252".into(),
        ],
        json: Json::obj(vec![
            ("fig5a", Json::Arr(json_a)),
            ("fig5b", Json::Arr(json_b)),
        ]),
    }
}

/// Fig 6: L2 miss ratio vs streams for thin/medium/thick kernels.
pub fn fig6(cfg: &Config) -> ExperimentReport {
    let l2 = L2Model::new(cfg);
    let classes: [(&str, usize); 3] =
        [("thin (256^3)", 256), ("medium (512^3)", 512), ("thick (2048^3)", 2048)];
    let mut t = Table::new(
        "Fig 6 — L2 miss ratio vs concurrent streams",
        &["kernel", "1 stream", "2 streams", "3 streams", "4 streams"],
    );
    let mut json_rows = Vec::new();
    let mut series = Vec::new();
    for (name, n) in classes {
        let ws = KernelDesc::gemm(n, Precision::F32).working_set();
        let misses: Vec<f64> =
            (1..=4).map(|s| l2.miss_ratio(ws, s)).collect();
        t.row(vec![
            name.into(),
            format!("{:.1}%", misses[0] * 100.0),
            format!("{:.1}%", misses[1] * 100.0),
            format!("{:.1}%", misses[2] * 100.0),
            format!("{:.1}%", misses[3] * 100.0),
        ]);
        json_rows.push(Json::obj(vec![
            ("kernel", Json::Str(name.into())),
            ("miss", Json::Arr(misses.iter().map(|&m| Json::Num(m)).collect())),
        ]));
        series.push((name, misses));
    }
    let plot = ascii_plot(
        "Fig 6: L2 miss ratio vs streams",
        &[1.0, 2.0, 3.0, 4.0],
        &series.iter().map(|(n, m)| (*n, m.clone())).collect::<Vec<_>>(),
        10,
    );
    ExperimentReport {
        id: "fig6",
        title: "L2 contention".into(),
        tables: vec![t],
        plots: vec![plot],
        notes: vec![
            "paper: thin 5->6%, medium 15->19%, thick 35->43% (1 -> 4 \
             streams)".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 7: LDS utilization heatmap (occupancy class x stream count).
pub fn fig7(cfg: &Config) -> ExperimentReport {
    let classes: [(&str, usize); 3] =
        [("thin", 256), ("medium", 512), ("thick", 2048)];
    let mut t = Table::new(
        "Fig 7 — LDS utilization heatmap",
        &["occupancy", "1 stream", "2 streams", "3 streams", "4 streams"],
    );
    let mut json_rows = Vec::new();
    for (name, n) in classes {
        let utils: Vec<f64> = (1..=4)
            .map(|s| {
                lds_utilization(
                    n,
                    s,
                    cfg.total_cus(),
                    cfg.lds_bytes_per_cu() as usize,
                    cfg.calib.lds_double_buffer,
                )
            })
            .collect();
        t.row(
            std::iter::once(name.to_string())
                .chain(utils.iter().map(|u| format!("{:.0}%", u * 100.0)))
                .collect(),
        );
        json_rows.push(Json::obj(vec![
            ("class", Json::Str(name.into())),
            ("util", Json::Arr(utils.iter().map(|&u| Json::Num(u)).collect())),
        ]));
    }
    ExperimentReport {
        id: "fig7",
        title: "LDS saturation".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            "paper: thin 25% -> 36% @4; medium 87% @4; thick 100% @3 \
             (forces time-multiplexing)".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 8: per-stream kernel latency distribution across stream counts.
pub fn fig8(cfg: &Config) -> ExperimentReport {
    let engine = Engine::new(cfg, ConcurrencyProfile::ace());
    let mut t = Table::new(
        "Fig 8 — per-stream iteration latency distribution (512^3 FP32)",
        &["streams", "p50 (ms)", "p95 (ms)", "max (ms)", "max/p50"],
    );
    let mut json_rows = Vec::new();
    let counts = [1usize, 2, 4];
    let summaries: Vec<Summary> =
        pool::scoped_map(&counts, pool::default_workers(), |_, &s| {
            let run = engine.run(
                &vec![baseline(Precision::F32, 100); s],
                cfg.seed + 80,
            );
            let all: Vec<f64> = run
                .streams
                .iter()
                .flat_map(|st| st.iter_ns.iter().cloned())
                .collect();
            Summary::of(&all)
        });
    for (&s, sm) in counts.iter().zip(&summaries) {
        t.row(vec![
            s.to_string(),
            format!("{:.3}", sm.p50 / 1e6),
            format!("{:.3}", sm.p95 / 1e6),
            format!("{:.3}", sm.max / 1e6),
            format!("{:.2}x", sm.max / sm.p50),
        ]);
        json_rows.push(Json::obj(vec![
            ("streams", Json::Num(s as f64)),
            ("p50_ns", Json::Num(sm.p50)),
            ("p95_ns", Json::Num(sm.p95)),
            ("max_ns", Json::Num(sm.max)),
        ]));
    }
    ExperimentReport {
        id: "fig8",
        title: "Execution-time variance under contention".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            "paper: tight distribution at 1 stream; some streams 2-3x \
             longer at 4 streams (L2 conflicts, not scheduler \
             unfairness)".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

/// Fig 9: occupancy fragmentation — per-stream speedup and fairness at
/// occupancy ratios 1:1, 2:1, 4:1.
pub fn fig9(cfg: &Config) -> ExperimentReport {
    let engine = Engine::new(cfg, ConcurrencyProfile::fragmentation());
    let pairs: [(&str, usize, usize); 3] = [
        ("1:1", 512, 512),
        ("2:1", 1024, 512),
        ("4:1", 2048, 512),
    ];
    let mut t = Table::new(
        "Fig 9 — occupancy imbalance (pairs on one ACE)",
        &["ratio", "large speedup", "small speedup", "fairness"],
    );
    let mut json_rows = Vec::new();
    // Each occupancy-ratio pair is an independent trio of runs: fan out.
    let cells: Vec<(f64, f64, f64)> =
        pool::scoped_map(&pairs, pool::default_workers(), |_, &(_, big_n, small_n)| {
            // The §6.3 harness is launch-dominated (fragmentation
            // profile), so equal iteration counts already co-execute
            // the whole window.
            let big = KernelDesc::gemm(big_n, Precision::F32).with_iters(30);
            let small =
                KernelDesc::gemm(small_n, Precision::F32).with_iters(30);
            let solo_big =
                engine.run_solo(&big, cfg.seed + 90).streams[0].total_ns();
            let solo_small =
                engine.run_solo(&small, cfg.seed + 91).streams[0].total_ns();
            let pair = engine.run(&[big, small], cfg.seed + 92);
            let sp_big = solo_big / pair.streams[0].total_ns();
            let sp_small = solo_small / pair.streams[1].total_ns();
            // §6.3 fairness: §4.2 formula on raw per-stream times — the
            // launch-dominated regime plus proportional allocation keeps
            // them balanced despite the size gap (paper: 0.93-0.99).
            let f = fairness(&pair.per_stream_totals());
            (sp_big, sp_small, f)
        });
    for (&(name, _, _), &(sp_big, sp_small, f)) in pairs.iter().zip(&cells) {
        t.row(vec![
            name.into(),
            format!("{sp_big:.2}x"),
            format!("{sp_small:.2}x"),
            format!("{f:.2}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("ratio", Json::Str(name.into())),
            ("speedup_large", Json::Num(sp_big)),
            ("speedup_small", Json::Num(sp_small)),
            ("fairness", Json::Num(f)),
        ]));
    }
    ExperimentReport {
        id: "fig9",
        title: "Occupancy fragmentation".into(),
        tables: vec![t],
        plots: vec![],
        notes: vec![
            "paper: 1:1 near-unity (0.87-1.14x); 4:1 large up to 2.4x, \
             small may slow to 0.63x; fairness stays 0.93-0.99 \
             (proportional allocation)".into(),
        ],
        json: Json::Arr(json_rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_speedup_monotone_in_streams() {
        let r = fig4(&Config::mi300a());
        let rows = r.json.as_arr().unwrap();
        for p in ["FP32", "FP16", "FP8"] {
            let sp: Vec<f64> = rows
                .iter()
                .map(|row| row.get(p).unwrap().as_f64().unwrap())
                .collect();
            for w in sp.windows(2) {
                assert!(w[1] >= w[0] * 0.98, "{p}: speedup should not drop");
            }
            assert!(*sp.last().unwrap() < 8.0, "{p}: sublinear");
        }
    }

    #[test]
    fn fig5_fairness_degrades_with_streams() {
        let r = fig5(&Config::mi300a());
        let a = r.json.get("fig5a").unwrap().as_arr().unwrap();
        for p in ["FP32", "FP16", "FP8"] {
            let f4 = a
                .iter()
                .find(|x| {
                    x.get("precision").unwrap().as_str() == Some(p)
                        && x.get("streams").unwrap().as_f64() == Some(4.0)
                })
                .unwrap()
                .get("fairness").unwrap().as_f64().unwrap();
            let f8 = a
                .iter()
                .find(|x| {
                    x.get("precision").unwrap().as_str() == Some(p)
                        && x.get("streams").unwrap().as_f64() == Some(8.0)
                })
                .unwrap()
                .get("fairness").unwrap().as_f64().unwrap();
            assert!(f8 < f4, "{p}: fairness must collapse at 8 streams");
        }
    }

    #[test]
    fn fig6_rows_increase_with_streams() {
        let r = fig6(&Config::mi300a());
        for row in r.json.as_arr().unwrap() {
            let m = row.get("miss").unwrap().as_arr().unwrap();
            let m1 = m[0].as_f64().unwrap();
            let m4 = m[3].as_f64().unwrap();
            assert!(m4 > m1);
        }
    }

    #[test]
    fn fig7_thick_saturates() {
        let r = fig7(&Config::mi300a());
        let rows = r.json.as_arr().unwrap();
        let thick = rows
            .iter()
            .find(|x| x.get("class").unwrap().as_str() == Some("thick"))
            .unwrap();
        let u = thick.get("util").unwrap().as_arr().unwrap();
        assert!(u[2].as_f64().unwrap() >= 0.99, "thick @3 streams ~100%");
    }

    #[test]
    fn fig9_fairness_stays_high() {
        let r = fig9(&Config::mi300a());
        for row in r.json.as_arr().unwrap() {
            let f = row.get("fairness").unwrap().as_f64().unwrap();
            assert!(f > 0.7, "proportional allocation keeps fairness high: {f}");
        }
    }
}
