//! Occupancy-aware continuous batcher (paper §9.2 "Batching strategies").
//!
//! vLLM-style continuous batching driven by the paper's occupancy
//! thresholds: requests accumulate until the batch reaches the
//! precision's wavefront target (256 for FP8) or a deadline expires —
//! trading latency for matrix-core utilization exactly as §9.2
//! prescribes.

use super::occupancy::occupancy_target;
use crate::isa::Precision;
use std::collections::VecDeque;

/// A queued inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Wavefronts this request contributes when batched.
    pub waves: usize,
    /// Arrival time, ns (monotonic virtual clock).
    pub arrival_ns: f64,
}

/// A formed batch ready for dispatch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed_at_ns: f64,
}

impl Batch {
    pub fn waves(&self) -> usize {
        self.requests.iter().map(|r| r.waves).sum()
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub precision: Precision,
    /// Max time a request may wait before the batch is cut anyway, ns.
    pub deadline_ns: f64,
    /// Hard cap on requests per batch (memory bound).
    pub max_requests: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            precision: Precision::Fp8,
            deadline_ns: 2_000_000.0, // 2 ms
            max_requests: 128,
        }
    }
}

/// The continuous batcher.
#[derive(Debug)]
pub struct Batcher {
    pub cfg: BatcherConfig,
    queue: VecDeque<Request>,
    next_id: u64,
    /// Counters for conservation invariants.
    pub submitted: u64,
    pub dispatched: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher { cfg, queue: VecDeque::new(), next_id: 0, submitted: 0, dispatched: 0 }
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, waves: usize, now_ns: f64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.submitted += 1;
        self.queue.push_back(Request { id, waves, arrival_ns: now_ns });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Occupancy target for the configured precision.
    pub fn target_waves(&self) -> usize {
        occupancy_target(self.cfg.precision)
    }

    /// Try to form a batch at `now_ns`. Cuts when (a) queued wavefronts
    /// reach the occupancy target, (b) the oldest request hits its
    /// deadline, or (c) the request cap is reached.
    pub fn poll(&mut self, now_ns: f64) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let queued_waves: usize = self.queue.iter().map(|r| r.waves).sum();
        let oldest_wait = now_ns - self.queue.front().unwrap().arrival_ns;
        let target_hit = queued_waves >= self.target_waves();
        let deadline_hit = oldest_wait >= self.cfg.deadline_ns;
        let cap_hit = self.queue.len() >= self.cfg.max_requests;
        if !(target_hit || deadline_hit || cap_hit) {
            return None;
        }
        // Take requests until the target (or cap/queue end); never split
        // a request.
        let mut requests = Vec::new();
        let mut waves = 0;
        while let Some(front) = self.queue.front() {
            if requests.len() >= self.cfg.max_requests
                || (waves >= self.target_waves() && !requests.is_empty())
            {
                break;
            }
            waves += front.waves;
            requests.push(self.queue.pop_front().unwrap());
        }
        self.dispatched += requests.len() as u64;
        Some(Batch { requests, formed_at_ns: now_ns })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        Batcher::new(BatcherConfig::default())
    }

    #[test]
    fn holds_until_occupancy_target() {
        let mut b = batcher();
        // 8 waves/request: target 256 -> needs 32 requests.
        for i in 0..31 {
            b.submit(8, i as f64);
            assert!(b.poll(i as f64).is_none(), "must hold below target");
        }
        b.submit(8, 31.0);
        let batch = b.poll(31.0).expect("target reached");
        assert!(batch.waves() >= 256);
        assert_eq!(batch.requests.len(), 32);
    }

    #[test]
    fn deadline_cuts_partial_batch() {
        let mut b = batcher();
        b.submit(8, 0.0);
        assert!(b.poll(1000.0).is_none());
        let batch = b.poll(2_000_001.0).expect("deadline hit");
        assert_eq!(batch.requests.len(), 1);
    }

    #[test]
    fn request_cap_cuts_batch() {
        let mut b = Batcher::new(BatcherConfig {
            max_requests: 4,
            ..Default::default()
        });
        for _ in 0..10 {
            b.submit(1, 0.0);
        }
        let batch = b.poll(0.0).expect("cap hit");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.pending(), 6);
    }

    #[test]
    fn conservation_no_drop_no_duplicate() {
        use crate::util::proptest::check;
        check(100, 31, |g| {
            let mut b = Batcher::new(BatcherConfig {
                precision: Precision::Fp8,
                deadline_ns: g.f64_in(10.0, 1e6),
                max_requests: g.usize_in(1, 64),
            });
            let mut seen = std::collections::HashSet::new();
            let mut now = 0.0;
            let n = g.usize_in(1, 200);
            for _ in 0..n {
                now += g.f64_in(0.0, 1e5);
                b.submit(g.usize_in(1, 64), now);
                if g.bool() {
                    if let Some(batch) = b.poll(now) {
                        for r in &batch.requests {
                            if !seen.insert(r.id) {
                                return Err(format!("duplicate id {}", r.id));
                            }
                        }
                    }
                }
            }
            // Drain.
            now += 1e12;
            while let Some(batch) = b.poll(now) {
                for r in &batch.requests {
                    if !seen.insert(r.id) {
                        return Err(format!("duplicate id {}", r.id));
                    }
                }
            }
            if seen.len() as u64 != b.submitted {
                return Err(format!(
                    "dropped requests: {} submitted, {} dispatched",
                    b.submitted,
                    seen.len()
                ));
            }
            if b.submitted != b.dispatched {
                return Err("counter mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = batcher();
        for i in 0..40 {
            b.submit(8, i as f64);
        }
        let batch = b.poll(40.0).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort();
        assert_eq!(ids, sorted, "batch must preserve arrival order");
        assert_eq!(ids[0], 0);
    }
}
