//! Occupancy estimation and targets (paper §9.1).
//!
//! The paper's headline scheduling insight: FP8 matrix cores need 256+
//! active wavefronts to approach peak (more than FP16's 192 or FP32's
//! 128, despite 4x lower arithmetic intensity), because the cores retire
//! FP8 ops faster than memory supplies data.

use crate::isa::Precision;
use crate::sim::kernel::KernelDesc;

/// Wavefronts at which a precision approaches its steady-state
/// throughput on MI300A (paper §9.1).
pub fn occupancy_target(p: Precision) -> usize {
    match p {
        Precision::Fp8 | Precision::Bf8 => 256,
        Precision::F16 | Precision::Bf16 => 192,
        Precision::F32 | Precision::F64 => 128,
    }
}

/// Estimated wavefronts a kernel puts in flight (one per output-tile
/// block, the paper's microbenchmark convention).
pub fn wavefronts(k: &KernelDesc) -> usize {
    k.blocks()
}

/// Occupancy adequacy in [0, 1]: in-flight wavefronts over the target.
pub fn adequacy(k: &KernelDesc) -> f64 {
    (wavefronts(k) as f64 / occupancy_target(k.precision) as f64).min(1.0)
}

/// The §9.2 batching decision: smallest batch multiplier that reaches
/// the occupancy target, given per-request wavefronts.
pub fn batch_for_target(p: Precision, waves_per_request: usize) -> usize {
    if waves_per_request == 0 {
        return 1;
    }
    occupancy_target(p).div_ceil(waves_per_request)
}

/// §9.2 "Use FP16 for lower occupancy": when the achievable wavefront
/// count is below FP8's threshold but above FP16's knee, FP16 wins
/// despite 2x arithmetic intensity.
pub fn preferred_precision(achievable_waves: usize) -> Precision {
    if achievable_waves >= occupancy_target(Precision::Fp8) {
        Precision::Fp8
    } else {
        Precision::F16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_match_section_9_1() {
        assert_eq!(occupancy_target(Precision::Fp8), 256);
        assert_eq!(occupancy_target(Precision::F16), 192);
        assert_eq!(occupancy_target(Precision::F32), 128);
    }

    #[test]
    fn decoder_batch_32_underutilizes_fp8() {
        // Paper §9.1: "a transformer decoder with batch size 32 achieves
        // only 128 wavefronts ... leaving FP8 matrix cores underutilized".
        let waves = 128;
        assert!(waves < occupancy_target(Precision::Fp8));
        assert_eq!(preferred_precision(waves), Precision::F16);
        assert_eq!(preferred_precision(256), Precision::Fp8);
    }

    #[test]
    fn batch_for_target_reaches_threshold() {
        // 4 wavefronts per request at FP8: need 64 requests.
        assert_eq!(batch_for_target(Precision::Fp8, 4), 64);
        // Never zero.
        assert_eq!(batch_for_target(Precision::Fp8, 0), 1);
        // Already-large requests need batch 1.
        assert_eq!(batch_for_target(Precision::F32, 300), 1);
    }

    #[test]
    fn adequacy_saturates_at_one() {
        let big = KernelDesc::gemm(8192, Precision::F32);
        assert_eq!(adequacy(&big), 1.0);
        let small = KernelDesc::gemm(256, Precision::Fp8);
        assert!(adequacy(&small) < 0.1);
    }
}
