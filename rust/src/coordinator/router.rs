//! Request router: batches -> streams/queues -> ACEs.
//!
//! The dispatch layer under the policies: it owns stream state, applies
//! backpressure (bounded in-flight per stream), and maps streams onto
//! the ACE set the way ROCm's HSA runtime does (round-robin). Invariant
//! (property-tested): every submitted batch is dispatched exactly once
//! and completions balance dispatches.

use crate::sim::ace::{AceSet, QueueId};
use std::collections::VecDeque;

/// A dispatchable unit (an already-formed batch or a whole kernel).
#[derive(Debug, Clone, PartialEq)]
pub struct Dispatch {
    pub id: u64,
    /// Which stream it was routed to.
    pub stream: usize,
    /// Which hardware ACE that stream's queue maps to.
    pub ace: usize,
}

/// Per-stream bookkeeping.
#[derive(Debug, Clone)]
struct StreamState {
    queue: QueueId,
    in_flight: usize,
}

/// The router.
#[derive(Debug)]
pub struct Router {
    aces: AceSet,
    streams: Vec<StreamState>,
    max_in_flight: usize,
    backlog: VecDeque<u64>,
    next_stream: usize,
    pub dispatched: u64,
    pub completed: u64,
}

impl Router {
    /// `n_streams` concurrent streams (from the concurrency governor),
    /// `max_in_flight` per-stream backpressure bound.
    pub fn new(n_streams: usize, n_aces: usize, max_in_flight: usize) -> Router {
        assert!(n_streams > 0 && max_in_flight > 0);
        let mut aces = AceSet::new(n_aces);
        let streams = (0..n_streams)
            .map(|_| StreamState { queue: aces.create_queue().0, in_flight: 0 })
            .collect();
        Router {
            aces,
            streams,
            max_in_flight,
            backlog: VecDeque::new(),
            next_stream: 0,
            dispatched: 0,
            completed: 0,
        }
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Submit a unit; returns the dispatch if a stream had capacity, or
    /// queues it in the backlog (drained by `complete`).
    pub fn submit(&mut self, id: u64) -> Option<Dispatch> {
        self.backlog.push_back(id);
        self.try_dispatch()
    }

    fn try_dispatch(&mut self) -> Option<Dispatch> {
        let id = *self.backlog.front()?;
        // Round-robin over streams with capacity.
        let n = self.streams.len();
        for probe in 0..n {
            let s = (self.next_stream + probe) % n;
            if self.streams[s].in_flight < self.max_in_flight {
                self.backlog.pop_front();
                self.streams[s].in_flight += 1;
                self.next_stream = (s + 1) % n;
                self.dispatched += 1;
                return Some(Dispatch {
                    id,
                    stream: s,
                    ace: self.aces.ace_of(self.streams[s].queue),
                });
            }
        }
        None // all streams at capacity: stays in backlog
    }

    /// Mark one unit complete on `stream`; drains the backlog if
    /// possible.
    pub fn complete(&mut self, stream: usize) -> Option<Dispatch> {
        assert!(
            self.streams[stream].in_flight > 0,
            "completion on idle stream {stream}"
        );
        self.streams[stream].in_flight -= 1;
        self.completed += 1;
        self.try_dispatch()
    }

    pub fn backlog_len(&self) -> usize {
        self.backlog.len()
    }

    pub fn in_flight(&self) -> usize {
        self.streams.iter().map(|s| s.in_flight).sum()
    }

    /// Launch-serialization factor of a stream (queues sharing its ACE).
    pub fn serialization(&self, stream: usize) -> usize {
        self.aces.serialization(self.streams[stream].queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatches_round_robin() {
        let mut r = Router::new(4, 8, 2);
        let ds: Vec<Dispatch> =
            (0..4).filter_map(|i| r.submit(i)).collect();
        let streams: Vec<usize> = ds.iter().map(|d| d.stream).collect();
        assert_eq!(streams, vec![0, 1, 2, 3]);
    }

    #[test]
    fn backpressure_holds_excess() {
        let mut r = Router::new(2, 8, 1);
        assert!(r.submit(0).is_some());
        assert!(r.submit(1).is_some());
        assert!(r.submit(2).is_none(), "both streams full");
        assert_eq!(r.backlog_len(), 1);
        let d = r.complete(0).expect("backlog drained on completion");
        assert_eq!(d.id, 2);
        assert_eq!(d.stream, 0);
    }

    #[test]
    fn streams_beyond_aces_share() {
        let r = Router::new(8, 4, 1);
        // 8 queues over 4 ACEs: each shared by exactly 2.
        for s in 0..8 {
            assert_eq!(r.serialization(s), 2);
        }
    }

    #[test]
    fn conservation_property() {
        use crate::util::proptest::check;
        check(100, 5, |g| {
            let mut r = Router::new(g.usize_in(1, 8), g.usize_in(1, 8),
                                    g.usize_in(1, 4));
            let mut issued: Vec<Dispatch> = Vec::new();
            let mut next_id = 0u64;
            let steps = g.usize_in(1, 300);
            for _ in 0..steps {
                if g.bool() {
                    if let Some(d) = r.submit(next_id) {
                        issued.push(d);
                    }
                    next_id += 1;
                } else if r.in_flight() > 0 {
                    // Complete on a random busy stream.
                    let busy: Vec<usize> = (0..r.n_streams())
                        .filter(|&s| r.streams[s].in_flight > 0)
                        .collect();
                    let s = *g.pick(&busy);
                    if let Some(d) = r.complete(s) {
                        issued.push(d);
                    }
                }
            }
            // Drain: complete everything, collecting backlog dispatches.
            while r.in_flight() > 0 {
                let busy: Vec<usize> = (0..r.n_streams())
                    .filter(|&s| r.streams[s].in_flight > 0)
                    .collect();
                let s = busy[0];
                if let Some(d) = r.complete(s) {
                    issued.push(d);
                }
            }
            // Every submitted id dispatched exactly once.
            let mut ids: Vec<u64> = issued.iter().map(|d| d.id).collect();
            ids.sort();
            let expect: Vec<u64> = (0..next_id).collect();
            if ids != expect {
                return Err(format!(
                    "ids not conserved: {} dispatched of {} submitted",
                    ids.len(),
                    next_id
                ));
            }
            if r.dispatched != r.completed {
                return Err("dispatch/completion imbalance after drain".into());
            }
            Ok(())
        });
    }
}
