//! Precision-aware co-scheduler (paper §9.2 "Mixed-precision
//! scheduling").
//!
//! "Co-schedule kernels with similar wavefront requirements to avoid
//! occupancy fragmentation. Limit FP16 concurrency more aggressively
//! than FP32. Co-locate memory-bound FP8 with compute-bound FP32 to
//! reduce L2 cache conflicts."

use super::concurrency::max_streams_for_fairness;
use super::occupancy::wavefronts;
use crate::isa::Precision;
use crate::sim::kernel::KernelDesc;

/// A co-scheduling group: kernels placed on concurrently-executing
/// streams.
#[derive(Debug, Clone)]
pub struct CoScheduleGroup {
    pub kernels: Vec<KernelDesc>,
}

impl CoScheduleGroup {
    /// Max/min wavefront ratio within the group (1.0 = perfectly
    /// occupancy-matched).
    pub fn occupancy_ratio(&self) -> f64 {
        let ws: Vec<f64> =
            self.kernels.iter().map(|k| wavefronts(k) as f64).collect();
        let max = ws.iter().cloned().fold(0.0, f64::max);
        let min = ws.iter().cloned().fold(f64::INFINITY, f64::min);
        if min > 0.0 {
            max / min
        } else {
            f64::INFINITY
        }
    }
}

/// Plan co-scheduling groups from a kernel pool:
///
/// 1. Sort by wavefront count, group neighbours (occupancy matching —
///    avoids the Fig-9 fragmentation regime).
/// 2. Cap each group's size by the fairness-floor stream limit of its
///    most fairness-fragile precision (FP16 < FP32 < FP8).
/// 3. Where possible, pair memory-bound FP8 kernels with compute-bound
///    FP32 kernels of similar occupancy (L2-conflict reduction).
pub fn plan(pool: &[KernelDesc], fairness_floor: f64) -> Vec<CoScheduleGroup> {
    if pool.is_empty() {
        return Vec::new();
    }
    let mut sorted: Vec<KernelDesc> = pool.to_vec();
    sorted.sort_by_key(|k| wavefronts(k));

    let mut groups: Vec<CoScheduleGroup> = Vec::new();
    let mut current: Vec<KernelDesc> = Vec::new();
    for k in sorted {
        let cap = current
            .iter()
            .chain(std::iter::once(&k))
            .map(|k| max_streams_for_fairness(k.precision, fairness_floor))
            .min()
            .unwrap_or(1);
        let matched = current.last().map_or(true, |last| {
            let r = wavefronts(&k).max(1) as f64
                / wavefronts(last).max(1) as f64;
            r <= 1.5 // occupancy-matched neighbours only
        });
        if current.len() < cap && matched {
            current.push(k);
        } else {
            groups.push(CoScheduleGroup { kernels: std::mem::take(&mut current) });
            current.push(k);
        }
    }
    if !current.is_empty() {
        groups.push(CoScheduleGroup { kernels: current });
    }
    groups
}

/// §9.2 pairing hint: is co-locating these two kernels L2-friendly
/// (memory-bound FP8 + compute-bound FP32)?
pub fn l2_friendly_pair(a: &KernelDesc, b: &KernelDesc) -> bool {
    let is_fp8 = |p: Precision| matches!(p, Precision::Fp8 | Precision::Bf8);
    let is_f32 = |p: Precision| matches!(p, Precision::F32 | Precision::F64);
    (is_fp8(a.precision) && is_f32(b.precision))
        || (is_f32(a.precision) && is_fp8(b.precision))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn groups_are_occupancy_matched() {
        let pool = vec![
            KernelDesc::gemm(256, Precision::F32),
            KernelDesc::gemm(256, Precision::F32),
            KernelDesc::gemm(2048, Precision::F32),
            KernelDesc::gemm(2048, Precision::F32),
        ];
        let groups = plan(&pool, 0.3);
        for g in &groups {
            assert!(
                g.occupancy_ratio() <= 1.5,
                "fragmented group: ratio {}",
                g.occupancy_ratio()
            );
        }
    }

    #[test]
    fn fp16_groups_smaller_than_fp32_groups() {
        let fp16_pool = vec![KernelDesc::gemm(512, Precision::F16); 8];
        let fp32_pool = vec![KernelDesc::gemm(512, Precision::F32); 8];
        let floor = 0.05;
        let max16 = plan(&fp16_pool, floor).iter().map(|g| g.kernels.len()).max().unwrap();
        let max32 = plan(&fp32_pool, floor).iter().map(|g| g.kernels.len()).max().unwrap();
        assert!(
            max16 <= max32,
            "FP16 concurrency ({max16}) must be limited at least as hard \
             as FP32 ({max32})"
        );
    }

    #[test]
    fn l2_pairing_rule() {
        let fp8 = KernelDesc::gemm(512, Precision::Fp8);
        let f32_ = KernelDesc::gemm(512, Precision::F32);
        let f16 = KernelDesc::gemm(512, Precision::F16);
        assert!(l2_friendly_pair(&fp8, &f32_));
        assert!(l2_friendly_pair(&f32_, &fp8));
        assert!(!l2_friendly_pair(&fp8, &f16));
        assert!(!l2_friendly_pair(&f32_, &f32_));
    }

    #[test]
    fn plan_conserves_kernels_property() {
        check(100, 77, |g| {
            let n = g.usize_in(0, 24);
            let pool: Vec<KernelDesc> = (0..n)
                .map(|_| {
                    let dim = *g.pick(&[256usize, 512, 1024, 2048]);
                    let p = *g.pick(&[
                        Precision::Fp8,
                        Precision::F16,
                        Precision::F32,
                    ]);
                    KernelDesc::gemm(dim, p)
                })
                .collect();
            let floor = g.f64_in(0.0, 0.9);
            let groups = plan(&pool, floor);
            let total: usize = groups.iter().map(|g| g.kernels.len()).sum();
            if total != pool.len() {
                return Err(format!(
                    "plan lost kernels: {total} != {}",
                    pool.len()
                ));
            }
            for grp in &groups {
                if grp.kernels.is_empty() {
                    return Err("empty group".into());
                }
                if grp.kernels.len() > 1 && grp.occupancy_ratio() > 1.5 + 1e-9 {
                    return Err(format!(
                        "fragmented group ratio {}",
                        grp.occupancy_ratio()
                    ));
                }
            }
            Ok(())
        });
    }
}
