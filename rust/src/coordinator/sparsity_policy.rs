//! Context-dependent sparsity enablement (paper §9.2 "Sparsity
//! decisions").
//!
//! "Enable sparsity for concurrent execution (multi-tenant serving,
//! batch inference): 1.3x speedup + 7% fairness improvement. Disable
//! sparsity for isolated kernels: break-even performance with added
//! 3.7-5.5 µs latency. Ignore the matrix size/shape — the concurrency
//! level is the sole determining factor." (With the §7.1.2 exception:
//! strongly rectangular shapes win even in isolation.)

use crate::sim::kernel::KernelDesc;

/// Why the policy decided what it decided (logged by the coordinator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsityReason {
    /// >= 2 concurrent streams: contention-avoidance pays (1.3x).
    ConcurrentContext,
    /// Isolated + square: break-even minus overhead -> keep dense.
    IsolatedBreakEven,
    /// Isolated but strongly rectangular: overhead overlaps (1.6-1.76x).
    RectangularShape,
    /// Kernel cannot be pruned (caller said weights are not 2:4-able).
    NotPrunable,
}

/// The decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparsityDecision {
    pub enable: bool,
    pub reason: SparsityReason,
}

/// Decide whether to run `kernel` through the sparse path given the
/// current concurrency level and whether its weights admit a 2:4
/// pattern.
pub fn decide(kernel: &KernelDesc, concurrent_streams: usize,
              prunable: bool) -> SparsityDecision {
    if !prunable {
        return SparsityDecision { enable: false, reason: SparsityReason::NotPrunable };
    }
    if concurrent_streams >= 2 {
        return SparsityDecision {
            enable: true,
            reason: SparsityReason::ConcurrentContext,
        };
    }
    if kernel.is_rectangular() {
        return SparsityDecision {
            enable: true,
            reason: SparsityReason::RectangularShape,
        };
    }
    SparsityDecision { enable: false, reason: SparsityReason::IsolatedBreakEven }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    fn square() -> KernelDesc {
        KernelDesc::gemm(512, Precision::Fp8)
    }

    #[test]
    fn concurrent_enables_regardless_of_size() {
        // "Ignore the matrix size/shape — the concurrency level is the
        // sole determining factor."
        for n in [256usize, 512, 2048, 8192] {
            let d = decide(&KernelDesc::gemm(n, Precision::Fp8), 4, true);
            assert!(d.enable, "n={n}");
            assert_eq!(d.reason, SparsityReason::ConcurrentContext);
        }
    }

    #[test]
    fn isolated_square_stays_dense() {
        let d = decide(&square(), 1, true);
        assert!(!d.enable);
        assert_eq!(d.reason, SparsityReason::IsolatedBreakEven);
    }

    #[test]
    fn isolated_rectangular_enables() {
        let rect = square().with_shape(512, 2048, 1024);
        let d = decide(&rect, 1, true);
        assert!(d.enable);
        assert_eq!(d.reason, SparsityReason::RectangularShape);
    }

    #[test]
    fn unprunable_never_sparse() {
        let d = decide(&square(), 8, false);
        assert!(!d.enable);
        assert_eq!(d.reason, SparsityReason::NotPrunable);
    }

    #[test]
    fn two_streams_is_the_threshold() {
        assert!(!decide(&square(), 1, true).enable);
        assert!(decide(&square(), 2, true).enable);
    }
}
