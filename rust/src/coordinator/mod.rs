//! The execution-aware coordinator — the runtime layer the paper's §9
//! says MI300A-class nodes need. It composes:
//!
//! * [`occupancy`] — wavefront targets (FP8 needs 256+, §9.1);
//! * [`batcher`] — occupancy-aware continuous batching (§9.2);
//! * [`concurrency`] — the fairness/throughput stream governor (§9.2);
//! * [`sparsity_policy`] — context-dependent 2:4 enablement (§9.2);
//! * [`precision_sched`] — occupancy-matched, precision-aware
//!   co-scheduling (§9.2);
//! * [`router`] — stream/ACE dispatch with backpressure.
//!
//! [`Coordinator`] is the facade the examples and the e2e serving driver
//! use: submit kernels with an objective, get an execution plan whose
//! decisions are all traceable to a paper finding.

pub mod batcher;
pub mod concurrency;
pub mod occupancy;
pub mod precision_sched;
pub mod router;
pub mod sparsity_policy;

pub use batcher::{Batch, Batcher, BatcherConfig, Request};
pub use concurrency::{decide as decide_concurrency, expected_fairness,
                      ConcurrencyDecision, Objective};
pub use occupancy::{adequacy, batch_for_target, occupancy_target,
                    preferred_precision};
pub use precision_sched::{l2_friendly_pair, plan as plan_coschedule,
                          CoScheduleGroup};
pub use router::{Dispatch, Router};
pub use sparsity_policy::{decide as decide_sparsity, SparsityDecision,
                          SparsityReason};

use crate::config::Config;
use crate::sim::kernel::{KernelDesc, SparsityMode};

/// A fully-resolved execution plan for a pool of kernels.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Co-schedule groups, each to run with `streams(group)` concurrency.
    pub groups: Vec<PlannedGroup>,
    pub objective: Objective,
}

#[derive(Debug, Clone)]
pub struct PlannedGroup {
    pub kernels: Vec<KernelDesc>,
    pub streams: usize,
    pub expected_fairness: f64,
    pub process_isolation: bool,
}

/// The coordinator facade.
pub struct Coordinator {
    pub cfg: Config,
    pub objective: Objective,
    /// Fairness floor used for co-scheduling caps.
    pub fairness_floor: f64,
}

impl Coordinator {
    pub fn new(cfg: Config, objective: Objective) -> Coordinator {
        let fairness_floor = match objective {
            Objective::LatencySensitive => 0.5,
            Objective::ThroughputOriented => 0.01,
            Objective::StrictIsolation => 1.0,
        };
        Coordinator { cfg, objective, fairness_floor }
    }

    /// Plan execution for a kernel pool: co-schedule by occupancy,
    /// decide concurrency per group, and apply the sparsity policy to
    /// every kernel given its group's concurrency context.
    pub fn plan(&self, pool: &[KernelDesc], prunable: bool) -> ExecutionPlan {
        let groups = plan_coschedule(pool, self.fairness_floor);
        let planned = groups
            .into_iter()
            .map(|g| {
                let p = g.kernels[0].precision;
                let d = decide_concurrency(self.objective, p, g.kernels.len());
                let streams = d.streams.min(g.kernels.len()).max(1);
                let kernels = g
                    .kernels
                    .into_iter()
                    .map(|k| {
                        let sd = decide_sparsity(&k, streams, prunable);
                        if sd.enable {
                            k.with_sparsity(SparsityMode::SparseLhs)
                        } else {
                            k
                        }
                    })
                    .collect();
                PlannedGroup {
                    kernels,
                    streams,
                    expected_fairness: d.expected_fairness,
                    process_isolation: d.use_process_isolation,
                }
            })
            .collect();
        ExecutionPlan { groups: planned, objective: self.objective }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    fn pool() -> Vec<KernelDesc> {
        vec![KernelDesc::gemm(512, Precision::Fp8).with_iters(10); 4]
    }

    #[test]
    fn plan_conserves_kernels() {
        let c = Coordinator::new(Config::mi300a(), Objective::ThroughputOriented);
        let plan = c.plan(&pool(), true);
        let total: usize = plan.groups.iter().map(|g| g.kernels.len()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn throughput_plan_enables_sparsity_in_concurrent_groups() {
        let c = Coordinator::new(Config::mi300a(), Objective::ThroughputOriented);
        let plan = c.plan(&pool(), true);
        for g in &plan.groups {
            if g.streams >= 2 {
                assert!(g.kernels.iter().all(|k| k.sparsity.is_sparse()));
            }
        }
    }

    #[test]
    fn isolation_plan_disables_sparsity_and_streams() {
        let c = Coordinator::new(Config::mi300a(), Objective::StrictIsolation);
        let plan = c.plan(&pool(), true);
        for g in &plan.groups {
            assert_eq!(g.streams, 1);
            assert!(g.process_isolation);
            assert!(g.kernels.iter().all(|k| !k.sparsity.is_sparse()));
        }
    }

    #[test]
    fn latency_plan_respects_fairness_floor() {
        let c = Coordinator::new(Config::mi300a(), Objective::LatencySensitive);
        let plan = c.plan(&pool(), true);
        for g in &plan.groups {
            assert!(g.streams <= 4);
            assert!(g.expected_fairness > 0.5);
        }
    }
}
