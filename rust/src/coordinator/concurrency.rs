//! Concurrency governor (paper §9.2 "Concurrency decisions").
//!
//! "Limit to 2-4 streams for latency-sensitive workloads (fairness
//! >0.5); use 6-8 streams for throughput-oriented workloads (accepting
//! 0.016-0.138 fairness). For strict isolation, use process-level
//! separation instead of stream-level concurrency."

use crate::isa::Precision;

/// What the tenant cares about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Per-request SLOs: predictable latency beats aggregate throughput.
    LatencySensitive,
    /// Batch jobs: maximize aggregate throughput.
    ThroughputOriented,
    /// Multi-tenant SLA: no cross-stream interference tolerated.
    StrictIsolation,
}

impl Objective {
    /// Every objective, for protocol round-trip tests and sweep docs.
    pub const ALL: [Objective; 3] = [
        Objective::LatencySensitive,
        Objective::ThroughputOriented,
        Objective::StrictIsolation,
    ];
}

/// Governor decision.
#[derive(Debug, Clone, PartialEq)]
pub struct ConcurrencyDecision {
    pub streams: usize,
    /// Expected fairness at that stream count (from the paper's §6.1
    /// measurements, used as the decision table).
    pub expected_fairness: f64,
    /// Process-level separation instead of streams (§9.2).
    pub use_process_isolation: bool,
}

/// Paper-measured fairness by stream count for FP32/FP16/FP8 at 512^3
/// (Fig 5a). Linear interpolation between the anchors; beyond 8 streams
/// fairness is ~0.
pub fn expected_fairness(p: Precision, streams: usize) -> f64 {
    let anchors: [(usize, f64); 3] = match p {
        Precision::F16 | Precision::Bf16 => [(1, 1.0), (4, 0.61), (8, 0.016)],
        Precision::Fp8 | Precision::Bf8 => [(1, 1.0), (4, 0.51), (8, 0.138)],
        Precision::F32 | Precision::F64 => [(1, 1.0), (4, 0.57), (8, 0.052)],
    };
    let s = streams as f64;
    if streams <= 1 {
        return 1.0;
    }
    for w in anchors.windows(2) {
        let (s0, f0) = (w[0].0 as f64, w[0].1);
        let (s1, f1) = (w[1].0 as f64, w[1].1);
        if s <= s1 {
            return f0 + (f1 - f0) * (s - s0) / (s1 - s0);
        }
    }
    0.0
}

/// The governor: pick a stream count for a tenant's objective, given
/// how many concurrent kernels are on offer.
pub fn decide(objective: Objective, p: Precision, offered: usize)
    -> ConcurrencyDecision {
    match objective {
        Objective::StrictIsolation => ConcurrencyDecision {
            streams: 1,
            expected_fairness: 1.0,
            use_process_isolation: true,
        },
        Objective::LatencySensitive => {
            // Largest stream count (<= offered, <= 4) keeping fairness
            // > 0.5.
            let mut best = 1;
            for s in 2..=offered.min(4) {
                if expected_fairness(p, s) > 0.5 {
                    best = s;
                }
            }
            ConcurrencyDecision {
                streams: best,
                expected_fairness: expected_fairness(p, best),
                use_process_isolation: false,
            }
        }
        Objective::ThroughputOriented => {
            // 6-8 streams: speedup saturates at 8 (paper §6.1).
            let s = offered.clamp(1, 8);
            ConcurrencyDecision {
                streams: s,
                expected_fairness: expected_fairness(p, s),
                use_process_isolation: false,
            }
        }
    }
}

/// §9.2 "Limit FP16 concurrency more aggressively than FP32": max
/// streams whose expected fairness stays above a floor.
pub fn max_streams_for_fairness(p: Precision, floor: f64) -> usize {
    let mut best = 1;
    for s in 2..=8 {
        if expected_fairness(p, s) >= floor {
            best = s;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_fig5a() {
        assert!((expected_fairness(Precision::F16, 8) - 0.016).abs() < 1e-9);
        assert!((expected_fairness(Precision::Fp8, 8) - 0.138).abs() < 1e-9);
        assert!((expected_fairness(Precision::F32, 8) - 0.052).abs() < 1e-9);
        assert_eq!(expected_fairness(Precision::F32, 1), 1.0);
    }

    #[test]
    fn fairness_monotone_decreasing_in_streams() {
        for p in [Precision::F16, Precision::F32, Precision::Fp8] {
            let mut prev = 1.0;
            for s in 1..=10 {
                let f = expected_fairness(p, s);
                assert!(f <= prev + 1e-12, "{p} at {s} streams");
                prev = f;
            }
        }
    }

    #[test]
    fn latency_sensitive_keeps_fairness_above_half() {
        for p in [Precision::F16, Precision::F32, Precision::Fp8] {
            let d = decide(Objective::LatencySensitive, p, 8);
            assert!(d.streams <= 4);
            assert!(
                d.expected_fairness > 0.5,
                "{p}: fairness {} at {} streams",
                d.expected_fairness,
                d.streams
            );
        }
    }

    #[test]
    fn throughput_oriented_uses_up_to_eight() {
        let d = decide(Objective::ThroughputOriented, Precision::Fp8, 16);
        assert_eq!(d.streams, 8);
        assert!(d.expected_fairness < 0.2, "accepts low fairness");
    }

    #[test]
    fn strict_isolation_goes_process_level() {
        let d = decide(Objective::StrictIsolation, Precision::F16, 8);
        assert!(d.use_process_isolation);
        assert_eq!(d.streams, 1);
    }

    #[test]
    fn fp16_limited_harder_than_fp32() {
        // §9.2: FP16 fairness collapses hardest, so its stream cap at a
        // given floor must not exceed FP32's.
        for floor in [0.1, 0.3, 0.5] {
            assert!(
                max_streams_for_fairness(Precision::F16, floor)
                    <= max_streams_for_fairness(Precision::F32, floor),
                "floor {floor}"
            );
        }
    }
}
