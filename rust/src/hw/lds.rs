//! Local Data Share (LDS) models (paper §6.2, Fig 7).
//!
//! [`LdsTracker`] is the exact per-CU allocator the DES uses to decide
//! how many wavefronts can be resident (LDS-limited occupancy); the
//! analytic [`lds_utilization`] reproduces the Fig-7 heatmap for the
//! experiment driver.

/// Per-CU LDS allocator: fixed capacity, block-granular allocations.
#[derive(Debug, Clone)]
pub struct LdsTracker {
    capacity: usize,
    allocated: usize,
    allocs: Vec<(u64, usize)>, // (wave id, bytes)
}

impl LdsTracker {
    pub fn new(capacity_bytes: usize) -> LdsTracker {
        LdsTracker { capacity: capacity_bytes, allocated: 0, allocs: Vec::new() }
    }

    /// Try to allocate `bytes` for wavefront `wave`; false if full.
    pub fn alloc(&mut self, wave: u64, bytes: usize) -> bool {
        if self.allocated + bytes > self.capacity {
            return false;
        }
        self.allocated += bytes;
        self.allocs.push((wave, bytes));
        true
    }

    /// Release wavefront `wave`'s allocation (no-op if absent).
    pub fn free(&mut self, wave: u64) {
        if let Some(i) = self.allocs.iter().position(|(w, _)| *w == wave) {
            let (_, bytes) = self.allocs.swap_remove(i);
            self.allocated -= bytes;
        }
    }

    pub fn utilization(&self) -> f64 {
        self.allocated as f64 / self.capacity as f64
    }

    /// Max additional wavefronts of `bytes` each that still fit.
    pub fn headroom(&self, bytes: usize) -> usize {
        if bytes == 0 {
            return usize::MAX;
        }
        (self.capacity - self.allocated) / bytes
    }
}

/// LDS staging bytes per wavefront for a GEMM with the given macro-tile:
/// double-buffered A and B tile slabs (paper kernels stage operands
/// through LDS; DESIGN.md §Hardware-Adaptation).
pub fn lds_bytes_per_wave(tile: usize, k_slice: usize, elem_bytes: usize,
                          double_buffer: f64) -> usize {
    ((2 * tile * k_slice * elem_bytes) as f64 * double_buffer) as usize
}

/// GEMM macro-tile side used by the stream-level model, growing with the
/// problem so large GEMMs stage bigger slabs (thin 256 -> 64, medium
/// 512 -> 128, thick 2048+ -> 256).
pub fn gemm_macro_tile(n: usize) -> usize {
    (n / 4).clamp(64, 256)
}

/// Analytic Fig-7 utilization: average LDS occupancy across *occupied*
/// CUs for `streams` concurrent copies of an n^3 GEMM.
///
/// Per-stream resident wavefronts per CU grow with the kernel's block
/// count; the packing term models queue->ACE clustering (dispatch is not
/// perfectly spread, so co-scheduled streams stack on overlapping CUs).
pub fn lds_utilization(n: usize, streams: usize, total_cus: usize,
                       lds_capacity: usize, double_buffer: f64) -> f64 {
    let tile = gemm_macro_tile(n);
    let per_wave = lds_bytes_per_wave(tile, 16, 4, double_buffer);
    let blocks = ((n + tile - 1) / tile).pow(2) as f64;
    let blocks_per_cu = blocks / total_cus as f64;
    // Clustering calibration (DESIGN.md §7): co-scheduled streams stack
    // on overlapping CUs, and kernels with wider macro-tiles stage wider
    // K-panels per CU; 1.65 * (tile/64) matches the paper's medium
    // kernel at 87% with four streams while keeping thin at ~36%.
    let packing = 1.0 + (streams.saturating_sub(1)) as f64
        * blocks_per_cu.min(1.0) * 1.65 * (tile as f64 / 64.0);
    let waves_per_cu = packing.max(1.0)
        + (blocks_per_cu - 1.0).max(0.0) * streams as f64 * 0.25;
    (waves_per_cu * per_wave as f64 / lds_capacity as f64).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_alloc_free_roundtrip() {
        let mut t = LdsTracker::new(64 * 1024);
        assert!(t.alloc(1, 16 * 1024));
        assert!(t.alloc(2, 16 * 1024));
        assert!((t.utilization() - 0.5).abs() < 1e-12);
        t.free(1);
        assert!((t.utilization() - 0.25).abs() < 1e-12);
        t.free(42); // unknown wave: no-op
        assert!((t.utilization() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn tracker_rejects_oversubscription() {
        let mut t = LdsTracker::new(64 * 1024);
        assert!(t.alloc(1, 48 * 1024));
        assert!(!t.alloc(2, 32 * 1024), "must refuse past capacity");
        assert_eq!(t.headroom(16 * 1024), 1);
    }

    #[test]
    fn staging_bytes_formula() {
        // tile 64, k-slice 16, fp32, double-buffered: 2*64*16*4*2 = 16 KiB.
        assert_eq!(lds_bytes_per_wave(64, 16, 4, 2.0), 16 * 1024);
    }

    #[test]
    fn macro_tile_classes() {
        assert_eq!(gemm_macro_tile(256), 64);
        assert_eq!(gemm_macro_tile(512), 128);
        assert_eq!(gemm_macro_tile(2048), 256);
        assert_eq!(gemm_macro_tile(8192), 256); // clamped
    }

    #[test]
    fn fig7_shape_thin_vs_thick() {
        let lds = 64 * 1024;
        // Isolated: thin kernels sit at modest utilization (~25%).
        let thin1 = lds_utilization(256, 1, 240, lds, 2.0);
        assert!((0.2..0.32).contains(&thin1), "thin isolated {thin1}");
        // Thin at 4 streams grows but stays far from saturation (~36%).
        let thin4 = lds_utilization(256, 4, 240, lds, 2.0);
        assert!(thin4 > thin1 && thin4 < 0.5, "thin @4 {thin4}");
        // Medium reaches high utilization at 4 streams (~87%).
        let med4 = lds_utilization(512, 4, 240, lds, 2.0);
        assert!((0.75..=1.0).contains(&med4), "medium @4 {med4}");
        // Thick saturates by 3 streams (100%).
        let thick3 = lds_utilization(2048, 3, 240, lds, 2.0);
        assert!(thick3 >= 0.99, "thick @3 {thick3}");
    }

    #[test]
    fn utilization_monotone_in_streams() {
        for n in [256usize, 512, 2048] {
            let mut prev = 0.0;
            for s in 1..=4 {
                let u = lds_utilization(n, s, 240, 64 * 1024, 2.0);
                assert!(u >= prev, "n={n} s={s}: {u} < {prev}");
                prev = u;
            }
        }
    }
}
