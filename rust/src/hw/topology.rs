//! CU pool and placement: which wavefronts sit on which compute unit.
//!
//! The DES dispatches wavefront-granular blocks onto this pool; the pool
//! enforces per-CU wavefront and LDS limits and answers occupancy
//! queries (waves per CU drive latency hiding, Fig 2; LDS residency
//! drives Fig 7).

use super::lds::LdsTracker;

/// One compute unit's resident state.
#[derive(Debug, Clone)]
pub struct Cu {
    pub waves: Vec<u64>,
    pub lds: LdsTracker,
    max_waves: usize,
}

impl Cu {
    fn new(lds_bytes: usize, max_waves: usize) -> Cu {
        Cu { waves: Vec::new(), lds: LdsTracker::new(lds_bytes), max_waves }
    }

    fn can_host(&self, lds_bytes: usize) -> bool {
        self.waves.len() < self.max_waves
            && self.lds.headroom(lds_bytes.max(1)) >= 1
    }
}

/// The full CU pool (all XCDs flattened; the paper's study is
/// single-GCD-scope, §9 Limitations, so no inter-XCD placement policy).
#[derive(Debug)]
pub struct CuPool {
    pub cus: Vec<Cu>,
    next_rr: usize,
    resident: std::collections::HashMap<u64, usize>, // wave -> cu index
}

impl CuPool {
    pub fn new(n_cus: usize, lds_bytes_per_cu: usize, max_waves: usize) -> CuPool {
        CuPool {
            cus: (0..n_cus).map(|_| Cu::new(lds_bytes_per_cu, max_waves)).collect(),
            next_rr: 0,
            resident: Default::default(),
        }
    }

    /// Place a wavefront (round-robin over CUs with space). Returns the
    /// CU index, or None if no CU can host it.
    pub fn place(&mut self, wave: u64, lds_bytes: usize) -> Option<usize> {
        let n = self.cus.len();
        for probe in 0..n {
            let idx = (self.next_rr + probe) % n;
            if self.cus[idx].can_host(lds_bytes) {
                self.cus[idx].waves.push(wave);
                self.cus[idx].lds.alloc(wave, lds_bytes);
                self.resident.insert(wave, idx);
                self.next_rr = (idx + 1) % n;
                return Some(idx);
            }
        }
        None
    }

    /// Retire a wavefront, freeing its CU slot and LDS.
    pub fn retire(&mut self, wave: u64) {
        if let Some(idx) = self.resident.remove(&wave) {
            let cu = &mut self.cus[idx];
            if let Some(pos) = cu.waves.iter().position(|w| *w == wave) {
                cu.waves.swap_remove(pos);
            }
            cu.lds.free(wave);
        }
    }

    /// Total resident wavefronts.
    pub fn resident_waves(&self) -> usize {
        self.resident.len()
    }

    /// Wavefronts on the CU hosting `wave` (the latency-hiding pool).
    pub fn waves_on_cu_of(&self, wave: u64) -> usize {
        self.resident
            .get(&wave)
            .map(|&i| self.cus[i].waves.len())
            .unwrap_or(0)
    }

    /// Mean LDS utilization across CUs hosting at least one wavefront.
    pub fn mean_lds_utilization_occupied(&self) -> f64 {
        let occupied: Vec<&Cu> =
            self.cus.iter().filter(|c| !c.waves.is_empty()).collect();
        if occupied.is_empty() {
            return 0.0;
        }
        occupied.iter().map(|c| c.lds.utilization()).sum::<f64>()
            / occupied.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_spreads_waves() {
        let mut pool = CuPool::new(4, 64 * 1024, 8);
        for w in 0..4 {
            pool.place(w, 1024).unwrap();
        }
        for cu in &pool.cus {
            assert_eq!(cu.waves.len(), 1, "one wave per CU before doubling up");
        }
    }

    #[test]
    fn stacks_when_pool_wraps() {
        let mut pool = CuPool::new(2, 64 * 1024, 8);
        for w in 0..6 {
            pool.place(w, 0).unwrap();
        }
        assert_eq!(pool.cus[0].waves.len(), 3);
        assert_eq!(pool.cus[1].waves.len(), 3);
        assert_eq!(pool.resident_waves(), 6);
    }

    #[test]
    fn respects_max_waves() {
        let mut pool = CuPool::new(1, 64 * 1024, 2);
        assert!(pool.place(0, 0).is_some());
        assert!(pool.place(1, 0).is_some());
        assert!(pool.place(2, 0).is_none(), "max_waves=2 must refuse");
    }

    #[test]
    fn respects_lds_capacity() {
        let mut pool = CuPool::new(1, 32 * 1024, 8);
        assert!(pool.place(0, 24 * 1024).is_some());
        assert!(pool.place(1, 24 * 1024).is_none(), "LDS-full CU must refuse");
        pool.retire(0);
        assert!(pool.place(1, 24 * 1024).is_some(), "freed LDS is reusable");
    }

    #[test]
    fn retire_then_occupancy_queries() {
        let mut pool = CuPool::new(2, 64 * 1024, 8);
        pool.place(0, 16 * 1024);
        pool.place(1, 16 * 1024);
        pool.place(2, 16 * 1024); // stacks on cu 0
        assert_eq!(pool.waves_on_cu_of(2), 2);
        pool.retire(0);
        assert_eq!(pool.waves_on_cu_of(2), 1);
        assert_eq!(pool.resident_waves(), 2);
        assert!(pool.mean_lds_utilization_occupied() > 0.0);
    }
}
