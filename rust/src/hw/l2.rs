//! L2 cache models (paper §6.2, Fig 6).
//!
//! Two layers, per DESIGN.md §7:
//!
//! * [`CacheSim`] — a real set-associative cache with LRU replacement and
//!   per-stream accounting. Used by unit/property tests and small
//!   workloads, where a full address trace is tractable.
//! * [`L2Model`] — the analytic capacity/contention model the DES uses
//!   for large GEMMs (a 2048^3 sweep would need ~10^9 trace events).
//!   Anchored on the paper's measured isolated miss ratios (thin 5%,
//!   medium 15%, thick 35%) and the ~+8%/stream relative growth; a test
//!   checks the analytic model agrees with [`CacheSim`] on the direction
//!   and rough magnitude of the contention trend.

use std::collections::HashMap;

pub const CACHE_LINE: usize = 128;

/// Set-associative cache with per-stream hit/miss statistics.
#[derive(Debug)]
pub struct CacheSim {
    sets: Vec<Vec<(u64, u64)>>, // per set: (tag, lru_stamp)
    ways: usize,
    stamp: u64,
    pub stats: HashMap<usize, CacheStats>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn miss_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }
}

impl CacheSim {
    /// `size_bytes` total capacity, `ways`-way associative, 128 B lines.
    pub fn new(size_bytes: usize, ways: usize) -> CacheSim {
        let lines = (size_bytes / CACHE_LINE).max(ways);
        let n_sets = (lines / ways).max(1);
        CacheSim {
            sets: vec![Vec::with_capacity(ways); n_sets],
            ways,
            stamp: 0,
            stats: HashMap::new(),
        }
    }

    /// Access `addr` on behalf of `stream`; returns true on hit.
    pub fn access(&mut self, addr: u64, stream: usize) -> bool {
        self.stamp += 1;
        let line = addr / CACHE_LINE as u64;
        let set_idx = (line % self.sets.len() as u64) as usize;
        let tag = line / self.sets.len() as u64;
        let set = &mut self.sets[set_idx];
        let stats = self.stats.entry(stream).or_default();
        if let Some(slot) = set.iter_mut().find(|(t, _)| *t == tag) {
            slot.1 = self.stamp;
            stats.hits += 1;
            return true;
        }
        stats.misses += 1;
        if set.len() < self.ways {
            set.push((tag, self.stamp));
        } else {
            // Evict LRU.
            let lru = set
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, s))| *s)
                .map(|(i, _)| i)
                .unwrap();
            set[lru] = (tag, self.stamp);
        }
        false
    }

    pub fn total(&self) -> CacheStats {
        let mut agg = CacheStats::default();
        for s in self.stats.values() {
            agg.hits += s.hits;
            agg.misses += s.misses;
        }
        agg
    }
}

/// Analytic L2 miss-ratio model anchored on Fig 6.
#[derive(Debug, Clone)]
pub struct L2Model {
    /// Anchor points in log-log space: (ln working-set bytes, ln miss
    /// ratio). Precomputed at construction — `isolated_miss` sits on
    /// the DES rate path and must not allocate or re-take logs.
    ln_anchors: [(f64, f64); 3],
    /// Relative miss growth per added concurrent stream.
    stream_slope: f64,
    /// Total L2 bytes (for the capacity asymptote).
    l2_bytes: f64,
}

/// FP32 GEMM working set: A + B + C at n^3.
pub fn gemm_working_set(n: usize, elem_bytes: usize) -> f64 {
    3.0 * (n as f64) * (n as f64) * elem_bytes as f64
}

impl L2Model {
    pub fn new(cfg: &crate::config::Config) -> L2Model {
        let anchors = [
            (gemm_working_set(256, 4), cfg.calib.l2_miss_thin),
            (gemm_working_set(512, 4), cfg.calib.l2_miss_medium),
            (gemm_working_set(2048, 4), cfg.calib.l2_miss_thick),
        ];
        L2Model {
            ln_anchors: anchors.map(|(w, m)| (w.ln(), m.ln())),
            stream_slope: cfg.calib.l2_miss_stream_slope,
            l2_bytes: cfg.l2_bytes(),
        }
    }

    /// Isolated (single-stream) miss ratio for a working set, log-log
    /// interpolated through the paper's anchors and clamped to [0.01, 0.95].
    /// Allocation-free: the DES evaluates this on its rate path.
    pub fn isolated_miss(&self, working_set_bytes: f64) -> f64 {
        let ws = working_set_bytes.max(1.0).ln();
        let pts = &self.ln_anchors;
        let y = if ws <= pts[0].0 {
            interp(pts[0], pts[1], ws)
        } else if ws >= pts[2].0 {
            interp(pts[1], pts[2], ws)
        } else if ws <= pts[1].0 {
            interp(pts[0], pts[1], ws)
        } else {
            interp(pts[1], pts[2], ws)
        };
        y.exp().clamp(0.01, 0.95)
    }

    /// Miss ratio under `streams` concurrent homogeneous kernels: shared
    /// capacity shrinks per stream and cross-stream evictions add a
    /// relative penalty (paper Fig 6: ~+24% relative for thin kernels at
    /// 4 streams).
    pub fn miss_ratio(&self, working_set_bytes: f64, streams: usize) -> f64 {
        let base = self.isolated_miss(working_set_bytes);
        let s = streams.max(1) as f64;
        // Relative contention growth, attenuated once the aggregate
        // working set dwarfs L2 (capacity misses already dominate).
        let pressure = (working_set_bytes * s / self.l2_bytes).min(4.0);
        let growth = 1.0 + self.stream_slope * (s - 1.0) * (0.5 + 0.5 * (pressure / 4.0));
        (base * growth).clamp(0.0, 0.98)
    }

    /// Average memory-access penalty in ns per cache line, given a miss
    /// ratio and the HBM latency.
    pub fn penalty_ns(&self, miss_ratio: f64, miss_penalty_ns: f64) -> f64 {
        miss_ratio * miss_penalty_ns
    }
}

fn interp(a: (f64, f64), b: (f64, f64), x: f64) -> f64 {
    if (b.0 - a.0).abs() < 1e-12 {
        return a.1;
    }
    a.1 + (b.1 - a.1) * (x - a.0) / (b.0 - a.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn cache_sim_basic_hit_miss() {
        let mut c = CacheSim::new(4 * CACHE_LINE, 2);
        assert!(!c.access(0, 0)); // cold miss
        assert!(c.access(0, 0)); // hit
        assert!(c.access(64, 0)); // same line
        assert!(!c.access(1024, 0)); // different line
        assert_eq!(c.stats[&0].hits, 2);
        assert_eq!(c.stats[&0].misses, 2);
    }

    #[test]
    fn cache_sim_lru_eviction() {
        // 2 sets x 2 ways; lines mapping to set 0: 0, 2, 4 (line index).
        let mut c = CacheSim::new(4 * CACHE_LINE, 2);
        let line = |i: u64| i * CACHE_LINE as u64;
        c.access(line(0), 0);
        c.access(line(2), 0);
        c.access(line(0), 0); // refresh line 0
        c.access(line(4), 0); // evicts line 2 (LRU)
        assert!(c.access(line(0), 0), "line 0 should survive");
        assert!(!c.access(line(2), 0), "line 2 was evicted");
    }

    #[test]
    fn working_set_beyond_capacity_thrashes() {
        let mut c = CacheSim::new(8 * CACHE_LINE, 2);
        // Stream over 64 lines twice: second pass still ~all misses.
        for pass in 0..2 {
            for i in 0..64u64 {
                c.access(i * CACHE_LINE as u64, pass);
            }
        }
        assert!(c.total().miss_ratio() > 0.9);
    }

    #[test]
    fn per_stream_contention_raises_misses() {
        // One stream fits; two interleaved streams thrash each other.
        let size = 32 * CACHE_LINE;
        let mut solo = CacheSim::new(size, 4);
        for _ in 0..8 {
            for i in 0..24u64 {
                solo.access(i * CACHE_LINE as u64, 0);
            }
        }
        let mut duo = CacheSim::new(size, 4);
        for _ in 0..8 {
            for i in 0..24u64 {
                duo.access(i * CACHE_LINE as u64, 0);
                duo.access((1000 + i) * CACHE_LINE as u64, 1);
            }
        }
        assert!(
            duo.total().miss_ratio() > solo.total().miss_ratio(),
            "contention must raise the miss ratio"
        );
    }

    #[test]
    fn analytic_anchors_match_fig6() {
        let m = L2Model::new(&Config::mi300a());
        assert!((m.isolated_miss(gemm_working_set(256, 4)) - 0.05).abs() < 1e-9);
        assert!((m.isolated_miss(gemm_working_set(512, 4)) - 0.15).abs() < 1e-9);
        assert!((m.isolated_miss(gemm_working_set(2048, 4)) - 0.35).abs() < 1e-9);
    }

    #[test]
    fn analytic_stream_growth_matches_fig6_direction() {
        let m = L2Model::new(&Config::mi300a());
        for n in [256usize, 512, 2048] {
            let ws = gemm_working_set(n, 4);
            let m1 = m.miss_ratio(ws, 1);
            let m4 = m.miss_ratio(ws, 4);
            assert!(m4 > m1, "n={n}: miss must grow with streams");
            let rel = m4 / m1;
            assert!(
                (1.05..1.45).contains(&rel),
                "n={n}: relative growth {rel:.3} outside paper band"
            );
        }
    }

    #[test]
    fn analytic_agrees_with_cache_sim_trend() {
        // Direction-of-effect agreement between the analytic model and
        // the true cache on a scaled-down configuration.
        let mut small = CacheSim::new(64 * CACHE_LINE, 8);
        let mut big = CacheSim::new(64 * CACHE_LINE, 8);
        for _ in 0..4 {
            for i in 0..32u64 {
                small.access(i * CACHE_LINE as u64, 0);
            }
            for i in 0..256u64 {
                big.access(i * CACHE_LINE as u64, 0);
            }
        }
        let m = L2Model::new(&Config::mi300a());
        let small_analytic = m.isolated_miss(32.0 * CACHE_LINE as f64 * 4096.0);
        let big_analytic = m.isolated_miss(256.0 * CACHE_LINE as f64 * 4096.0);
        assert!(small.total().miss_ratio() < big.total().miss_ratio());
        assert!(small_analytic < big_analytic);
    }
}
