//! HBM bandwidth model: shared-channel saturation and per-stream shares.
//!
//! The APU's HBM3 is shared by all XCDs (paper §2); concurrent kernels
//! split effective bandwidth, and aggregate bandwidth saturates with
//! demand rather than scaling linearly. The DES queries this model to
//! price each kernel's memory phase.

/// Aggregate + per-stream HBM bandwidth calculator.
#[derive(Debug, Clone)]
pub struct HbmModel {
    /// Peak bandwidth, bytes per nanosecond (1 TB/s == 1000 B/ns).
    pub peak_bpns: f64,
    /// Demand level (B/ns) at which effective bandwidth is at half of
    /// the linear-scaling shortfall (soft saturation knee).
    pub knee_bpns: f64,
}

impl HbmModel {
    pub fn new(cfg: &crate::config::Config) -> HbmModel {
        let peak = cfg.hbm_bytes_per_ns();
        HbmModel { peak_bpns: peak, knee_bpns: 0.6 * peak }
    }

    /// Effective aggregate bandwidth for a total demand (B/ns): linear at
    /// low demand, asymptotic to peak.
    pub fn effective(&self, demand_bpns: f64) -> f64 {
        if demand_bpns <= 0.0 {
            return 0.0;
        }
        // Smooth saturating curve: eff = peak * d / (d + knee), scaled so
        // eff ~= demand when demand << knee.
        let sat = self.peak_bpns * demand_bpns / (demand_bpns + self.knee_bpns);
        sat.min(demand_bpns)
    }

    /// Bandwidth share of one stream demanding `demand` when total
    /// demand across streams is `total`: proportional split of the
    /// effective aggregate.
    pub fn share(&self, demand_bpns: f64, total_demand_bpns: f64) -> f64 {
        if total_demand_bpns <= 0.0 {
            return 0.0;
        }
        self.effective(total_demand_bpns) * demand_bpns / total_demand_bpns
    }

    /// Time (ns) to move `bytes` given this stream's share.
    pub fn transfer_ns(&self, bytes: f64, share_bpns: f64) -> f64 {
        if share_bpns <= 0.0 {
            return f64::INFINITY;
        }
        bytes / share_bpns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn model() -> HbmModel {
        HbmModel::new(&Config::mi300a())
    }

    #[test]
    fn peak_matches_config() {
        let m = model();
        assert!((m.peak_bpns - 5300.0).abs() < 1.0); // 5.3 TB/s
    }

    #[test]
    fn low_demand_is_served_fully() {
        let m = model();
        let d = m.peak_bpns * 0.01;
        let eff = m.effective(d);
        assert!(eff > 0.95 * d, "low demand should be ~unthrottled: {eff}");
    }

    #[test]
    fn saturates_below_peak() {
        let m = model();
        let eff = m.effective(m.peak_bpns * 100.0);
        assert!(eff <= m.peak_bpns);
        assert!(eff > 0.95 * m.peak_bpns, "huge demand approaches peak");
    }

    #[test]
    fn effective_monotone_in_demand() {
        let m = model();
        let mut prev = 0.0;
        for i in 1..100 {
            let eff = m.effective(m.peak_bpns * i as f64 / 20.0);
            assert!(eff >= prev);
            prev = eff;
        }
    }

    #[test]
    fn shares_are_proportional_and_sum_to_effective() {
        let m = model();
        let demands = [1000.0, 2000.0, 3000.0];
        let total: f64 = demands.iter().sum();
        let shares: Vec<f64> = demands.iter().map(|d| m.share(*d, total)).collect();
        let sum: f64 = shares.iter().sum();
        assert!((sum - m.effective(total)).abs() < 1e-6);
        assert!((shares[1] / shares[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_time_scales_inversely_with_share() {
        let m = model();
        let t1 = m.transfer_ns(1e6, 1000.0);
        let t2 = m.transfer_ns(1e6, 2000.0);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
        assert!(m.transfer_ns(1.0, 0.0).is_infinite());
    }
}
