//! Hardware substrate models: CU pool/topology, LDS, L2, HBM.

pub mod hbm;
pub mod l2;
pub mod lds;
pub mod topology;

pub use hbm::HbmModel;
pub use l2::{CacheSim, L2Model};
pub use lds::LdsTracker;
pub use topology::CuPool;
