//! Operand precisions of the CDNA3 matrix engines (paper §2, §5).

use std::fmt;

/// Matrix-operand precision. `Fp8` is E4M3, `Bf8` is E5M2 (OCP OFP8
/// naming, paper ref [1]); both multiply into an FP32 accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    F64,
    F32,
    F16,
    Bf16,
    Fp8,
    Bf8,
}

impl Precision {
    /// The five precisions the paper's occupancy sweep covers (Fig 2).
    /// FP8 stands for the whole E4M3/E5M2 family there.
    pub const SWEEP: [Precision; 5] = [
        Precision::F64,
        Precision::F32,
        Precision::F16,
        Precision::Bf16,
        Precision::Fp8,
    ];

    /// Operand size in bytes.
    pub fn bytes(self) -> usize {
        match self {
            Precision::F64 => 8,
            Precision::F32 => 4,
            Precision::F16 | Precision::Bf16 => 2,
            Precision::Fp8 | Precision::Bf8 => 1,
        }
    }

    /// Published MI300A dense matrix peak for this precision, in GFLOPS
    /// (vendor numbers the paper normalizes against: FP64/FP32 matrix
    /// 122.6 TF, FP16/BF16 980.6 TF, FP8 1961.2 TF).
    pub fn peak_gflops(self) -> f64 {
        match self {
            Precision::F64 | Precision::F32 => 122_600.0,
            Precision::F16 | Precision::Bf16 => 980_600.0,
            Precision::Fp8 | Precision::Bf8 => 1_961_200.0,
        }
    }

    /// Theoretical throughput multiple over FP16 (paper §2: FP8 is 2x
    /// FP16; FP32/FP64 are 1/8 of FP16 on the matrix path).
    pub fn relative_rate(self) -> f64 {
        self.peak_gflops() / Precision::F16.peak_gflops()
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "FP64",
            Precision::F32 => "FP32",
            Precision::F16 => "FP16",
            Precision::Bf16 => "BF16",
            Precision::Fp8 => "FP8",
            Precision::Bf8 => "BF8",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "fp64" | "f64" => Some(Precision::F64),
            "fp32" | "f32" => Some(Precision::F32),
            "fp16" | "f16" => Some(Precision::F16),
            "bf16" => Some(Precision::Bf16),
            "fp8" | "f8" | "e4m3" => Some(Precision::Fp8),
            "bf8" | "e5m2" => Some(Precision::Bf8),
            _ => None,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_is_2x_fp16_and_16x_fp32() {
        assert_eq!(Precision::Fp8.relative_rate(), 2.0);
        // Vendor sheets round: 122.6 vs 980.6/8 = 122.575.
        assert!((Precision::F32.relative_rate() - 0.125).abs() < 1e-3);
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(Precision::F64.bytes(), 8);
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::Bf16.bytes(), 2);
        assert_eq!(Precision::Fp8.bytes(), 1);
    }

    #[test]
    fn parse_roundtrip() {
        for p in Precision::SWEEP {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("e5m2"), Some(Precision::Bf8));
        assert_eq!(Precision::parse("int4"), None);
    }
}
