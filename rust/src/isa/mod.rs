//! CDNA3 MFMA instruction-set model: precisions, tiles, and the opcode
//! registry carrying the paper's Table 3 latency measurements.

pub mod opcode;
pub mod precision;
pub mod tile;

pub use opcode::{by_precision, lookup, primary_opcode, MfmaOpcode, OPCODES};
pub use precision::Precision;
pub use tile::Tile;
