//! MFMA tile shapes and FLOP accounting.

use std::fmt;

/// An MxNxK matrix-instruction tile (wavefront-level block operation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Tile {
    pub const fn new(m: usize, n: usize, k: usize) -> Tile {
        Tile { m, n, k }
    }

    /// FLOPs of one tile op: 2*M*N*K multiply-accumulates.
    pub fn flops(self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Operand bytes moved per tile op at `elem_bytes` per element
    /// (A tile + B tile; the accumulator stays in registers, matching the
    /// paper's minimal-register-pressure microbenchmarks §5.4).
    pub fn operand_bytes(self, elem_bytes: usize) -> usize {
        (self.m * self.k + self.k * self.n) * elem_bytes
    }

    /// Arithmetic intensity (FLOPs per operand byte).
    pub fn intensity(self, elem_bytes: usize) -> f64 {
        self.flops() / self.operand_bytes(elem_bytes) as f64
    }

    /// Whether this is a "preferred" 16x16 geometry. The paper's Table 3
    /// finds 32x32 variants consistently slower than 16x16 across all
    /// precisions (§5.4).
    pub fn is_preferred(self) -> bool {
        self.m == 16 && self.n == 16
    }
}

impl fmt::Display for Tile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.n, self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_of_fp8_tile() {
        // 16x16x32 -> 2*16*16*32 = 16384 FLOPs per MFMA.
        assert_eq!(Tile::new(16, 16, 32).flops(), 16384.0);
    }

    #[test]
    fn intensity_rises_with_narrow_dtype() {
        let t = Tile::new(16, 16, 32);
        // FP8 moves 1/4 the bytes of FP32 for the same tile -> 4x intensity.
        assert_eq!(t.intensity(1), 4.0 * t.intensity(4));
    }

    #[test]
    fn preferred_shapes() {
        assert!(Tile::new(16, 16, 32).is_preferred());
        assert!(!Tile::new(32, 32, 16).is_preferred());
        assert!(!Tile::new(4, 4, 4).is_preferred());
    }

    #[test]
    fn display() {
        assert_eq!(Tile::new(16, 16, 32).to_string(), "16x16x32");
    }
}
