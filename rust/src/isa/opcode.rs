//! MFMA opcode registry with the paper's measured single-issue latencies.
//!
//! Table 3 of the paper reports dependency-chain latency per MFMA VALU
//! opcode in units of 1e-5 ms (= 10 ns). Those measurements are the
//! *calibration inputs* of the simulator (DESIGN.md §7): `experiments::
//! table3` re-measures them through the simulated dependency-chain
//! microbenchmark and must recover this table.

use super::precision::Precision;
use super::tile::Tile;

/// One MFMA opcode: instruction mnemonic, operand precisions, tile, and
/// measured dependency-chain latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MfmaOpcode {
    /// CDNA3 mnemonic, e.g. `V_MFMA_F32_16X16X32_FP8_FP8`.
    pub name: &'static str,
    /// A-operand precision.
    pub a: Precision,
    /// B-operand precision (differs from `a` only for the FP8/BF8 mixes).
    pub b: Precision,
    /// Accumulator precision (F32 except for the F64 opcode).
    pub acc: Precision,
    pub tile: Tile,
    /// Single-issue (dependency-chain) latency in nanoseconds
    /// (paper Table 3 value x 10).
    pub latency_ns: f64,
}

impl MfmaOpcode {
    pub const fn new(
        name: &'static str,
        a: Precision,
        b: Precision,
        acc: Precision,
        m: usize,
        n: usize,
        k: usize,
        latency_e5_ms: f64,
    ) -> MfmaOpcode {
        MfmaOpcode {
            name,
            a,
            b,
            acc,
            tile: Tile::new(m, n, k),
            // 1e-5 ms = 10 ns.
            latency_ns: latency_e5_ms * 10.0,
        }
    }

    /// Paper Table 3 latency in the paper's own unit (1e-5 ms).
    pub fn latency_e5_ms(&self) -> f64 {
        self.latency_ns / 10.0
    }

    /// Dependency-chain throughput of a single wavefront issuing this
    /// opcode back-to-back: FLOPs / latency.
    pub fn chain_gflops(&self) -> f64 {
        self.tile.flops() / self.latency_ns
    }
}

use Precision::*;

/// The complete Table 3: 25 opcodes across 6 instruction families.
pub const OPCODES: &[MfmaOpcode] = &[
    // V_MFMA_F32_{}_F16
    MfmaOpcode::new("V_MFMA_F32_32X32X4_F16", F16, F16, F32, 32, 32, 4, 3.628),
    MfmaOpcode::new("V_MFMA_F32_16X16X4_F16", F16, F16, F32, 16, 16, 4, 2.584),
    MfmaOpcode::new("V_MFMA_F32_4X4X4_F16", F16, F16, F32, 4, 4, 4, 2.864),
    MfmaOpcode::new("V_MFMA_F32_32X32X8_F16", F16, F16, F32, 32, 32, 8, 2.672),
    MfmaOpcode::new("V_MFMA_F32_16X16X16_F16", F16, F16, F32, 16, 16, 16, 2.468),
    // V_MFMA_F32_{}_F32
    MfmaOpcode::new("V_MFMA_F32_32X32X1_F32", F32, F32, F32, 32, 32, 1, 3.912),
    MfmaOpcode::new("V_MFMA_F32_16X16X1_F32", F32, F32, F32, 16, 16, 1, 3.144),
    MfmaOpcode::new("V_MFMA_F32_4X4X1_F32", F32, F32, F32, 4, 4, 1, 2.484),
    MfmaOpcode::new("V_MFMA_F32_32X32X2_F32", F32, F32, F32, 32, 32, 2, 3.536),
    MfmaOpcode::new("V_MFMA_F32_16X16X4_F32", F32, F32, F32, 16, 16, 4, 2.616),
    // V_MFMA_F64_{}_F64
    MfmaOpcode::new("V_MFMA_F64_16X16X4_F64", F64, F64, F64, 16, 16, 4, 3.316),
    MfmaOpcode::new("V_MFMA_F64_4X4X4_F64", F64, F64, F64, 4, 4, 4, 2.844),
    // V_MFMA_F32_{}_BF16
    MfmaOpcode::new("V_MFMA_F32_32X32X4_BF16", Bf16, Bf16, F32, 32, 32, 4, 3.528),
    MfmaOpcode::new("V_MFMA_F32_16X16X4_BF16", Bf16, Bf16, F32, 16, 16, 4, 2.468),
    MfmaOpcode::new("V_MFMA_F32_4X4X4_BF16", Bf16, Bf16, F32, 4, 4, 4, 2.992),
    MfmaOpcode::new("V_MFMA_F32_32X32X8_BF16", Bf16, Bf16, F32, 32, 32, 8, 2.660),
    MfmaOpcode::new("V_MFMA_F32_16X16X16_BF16", Bf16, Bf16, F32, 16, 16, 16, 2.812),
    // V_MFMA_F32_{}_BF8_BF8
    MfmaOpcode::new("V_MFMA_F32_16X16X32_BF8_BF8", Bf8, Bf8, F32, 16, 16, 32, 2.528),
    MfmaOpcode::new("V_MFMA_F32_32X32X16_BF8_BF8", Bf8, Bf8, F32, 32, 32, 16, 2.828),
    // V_MFMA_F32_{}_BF8_FP8
    MfmaOpcode::new("V_MFMA_F32_16X16X32_BF8_FP8", Bf8, Fp8, F32, 16, 16, 32, 2.492),
    MfmaOpcode::new("V_MFMA_F32_32X32X16_BF8_FP8", Bf8, Fp8, F32, 32, 32, 16, 2.832),
    // V_MFMA_F32_{}_FP8_BF8
    MfmaOpcode::new("V_MFMA_F32_16X16X32_FP8_BF8", Fp8, Bf8, F32, 16, 16, 32, 2.540),
    MfmaOpcode::new("V_MFMA_F32_32X32X16_FP8_BF8", Fp8, Bf8, F32, 32, 32, 16, 2.736),
    // V_MFMA_F32_{}_FP8_FP8
    MfmaOpcode::new("V_MFMA_F32_16X16X32_FP8_FP8", Fp8, Fp8, F32, 16, 16, 32, 2.460),
    MfmaOpcode::new("V_MFMA_F32_32X32X16_FP8_FP8", Fp8, Fp8, F32, 32, 32, 16, 2.736),
];

/// The primary (preferred) opcode per precision — the tile each precision
/// uses in the paper's Fig 2/3 microbenchmarks (§5.1): FP64 and
/// FP16/BF16 use 16x16x4, FP32 uses 32x32x1, FP8 uses 16x16x32.
pub fn primary_opcode(p: Precision) -> &'static MfmaOpcode {
    let name = match p {
        F64 => "V_MFMA_F64_16X16X4_F64",
        F32 => "V_MFMA_F32_32X32X1_F32",
        F16 => "V_MFMA_F32_16X16X4_F16",
        Bf16 => "V_MFMA_F32_16X16X4_BF16",
        Fp8 => "V_MFMA_F32_16X16X32_FP8_FP8",
        Bf8 => "V_MFMA_F32_16X16X32_BF8_BF8",
    };
    lookup(name).expect("primary opcode present in table")
}

/// Find an opcode by mnemonic.
pub fn lookup(name: &str) -> Option<&'static MfmaOpcode> {
    OPCODES.iter().find(|o| o.name == name)
}

/// All opcodes for a given A-operand precision.
pub fn by_precision(p: Precision) -> Vec<&'static MfmaOpcode> {
    OPCODES.iter().filter(|o| o.a == p).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_25_rows() {
        assert_eq!(OPCODES.len(), 25);
    }

    #[test]
    fn fp8_fp8_16x16x32_matches_paper() {
        let op = lookup("V_MFMA_F32_16X16X32_FP8_FP8").unwrap();
        assert!((op.latency_e5_ms() - 2.460).abs() < 1e-9);
        assert_eq!(op.tile, Tile::new(16, 16, 32));
        assert_eq!(op.latency_ns, 24.6);
    }

    #[test]
    fn all_32x32_slower_than_16x16_within_family() {
        // Paper §5.4: "32x32 tiles consistently incur higher latency than
        // their 16x16 counterparts" (same family, nearest K).
        for fam in [
            ("V_MFMA_F32_32X32X16_FP8_FP8", "V_MFMA_F32_16X16X32_FP8_FP8"),
            ("V_MFMA_F32_32X32X16_BF8_BF8", "V_MFMA_F32_16X16X32_BF8_BF8"),
            ("V_MFMA_F32_32X32X4_F16", "V_MFMA_F32_16X16X4_F16"),
            ("V_MFMA_F32_32X32X1_F32", "V_MFMA_F32_16X16X1_F32"),
            ("V_MFMA_F32_32X32X4_BF16", "V_MFMA_F32_16X16X4_BF16"),
        ] {
            let (big, small) = (lookup(fam.0).unwrap(), lookup(fam.1).unwrap());
            assert!(
                big.latency_ns > small.latency_ns,
                "{} should be slower than {}",
                fam.0,
                fam.1
            );
        }
    }

    #[test]
    fn fp8_has_lowest_latency_of_16x16x32_family() {
        // Paper: FP8_FP8 16x16x32 at 2.460 is the fastest FP8-family row.
        let fp8 = lookup("V_MFMA_F32_16X16X32_FP8_FP8").unwrap();
        for o in OPCODES {
            if o.tile == Tile::new(16, 16, 32) {
                assert!(o.latency_ns >= fp8.latency_ns);
            }
        }
    }

    #[test]
    fn primary_opcodes_match_section_5_1() {
        assert_eq!(primary_opcode(F64).tile, Tile::new(16, 16, 4));
        assert_eq!(primary_opcode(F32).tile, Tile::new(32, 32, 1));
        assert_eq!(primary_opcode(F16).tile, Tile::new(16, 16, 4));
        assert_eq!(primary_opcode(Bf16).tile, Tile::new(16, 16, 4));
        assert_eq!(primary_opcode(Fp8).tile, Tile::new(16, 16, 32));
    }

    #[test]
    fn mnemonics_unique() {
        let mut names: Vec<_> = OPCODES.iter().map(|o| o.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), OPCODES.len());
    }

    #[test]
    fn chain_gflops_orders_precisions_as_fig2() {
        // Per-wavefront dependency-chain throughput: FP8 >> FP16 > FP32.
        let fp8 = primary_opcode(Fp8).chain_gflops();
        let f16 = primary_opcode(F16).chain_gflops();
        let f32_ = primary_opcode(F32).chain_gflops();
        assert!(fp8 > f16 && f16 > f32_);
    }
}
