//! `mi300a-char loadgen` — a built-in closed-loop load generator for
//! the serve transport, measuring sustained request throughput and
//! latency percentiles under either io model (`docs/performance.md`).
//!
//! The generator drives N worker threads, each owning one
//! [`crate::api::Client`] connection (closed loop: a worker issues its
//! next request only after the previous response arrives, so offered
//! load self-regulates instead of queueing unboundedly). A run has
//! three phases flipped by a wall-clock timer on the main thread:
//! warm-up (requests run but are not counted — connections settle and
//! the hot cache entry warms), the measured window (every completed
//! request records a wall-clock latency), and stop. Throughput is
//! completed-requests-in-window over the window's measured duration;
//! percentiles are nearest-rank over the merged latency samples.
//!
//! ## Request mix
//!
//! Three mixes ([`Mix`], the CLI's `--mix`) exercise different serve
//! paths:
//!
//! * `hot` — one repeated `sim` point: after warm-up every request is a
//!   result-cache hit, so the number measures transport + framing +
//!   cache-read overhead (the sharded cache's contended read path).
//! * `cold` — unique `sparsity` points (per-worker disjoint strides
//!   over the validated keyspace): every request misses and executes,
//!   measuring the dispatch/execution path.
//! * `mixed` (default) — ~84% hot, ~9% cold, ~5% two-point `scenario`
//!   sweeps, and ~1.6% watched job submits awaited to their terminal
//!   state (progress frames and all), approximating a polling fleet
//!   with occasional heavy work. A watched job counts as one logical
//!   request.
//!
//! Typed `overloaded` rejections (the bounded job queue refusing a
//! submit) are retryable by design, so they are counted separately and
//! fail nothing; any other typed error is unexpected under this
//! request mix and fails the run. Results land in `BENCH_serve.json`
//! (schema `mi300a-char/bench-v1`, PERF.md) via [`crate::util::bench`],
//! with throughput/percentiles/hit-rate in the `extra` block.

use crate::api::{
    Ask, CachePolicy, Client, ErrorCode, Request, Response, ScenarioSpec,
    Service,
};
use crate::backend::BackendId;
use crate::config::Config;
use crate::isa::Precision;
use crate::serve::{serve_on, IoModel};
use crate::util::bench::{BenchResult, Bencher};
use crate::util::json::Json;
use std::io;
use std::net::TcpListener;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Run phases, shared with the workers as one atomic.
const PHASE_WARMUP: u8 = 0;
const PHASE_MEASURE: u8 = 1;
const PHASE_STOP: u8 = 2;

/// Which request mix the workers issue (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// One repeated cacheable `sim` point (cache-hit path).
    Hot,
    /// Unique `sparsity` points per request (cold execution path).
    Cold,
    /// Mostly hot with cold, scenario, and watched-job traffic mixed in.
    Mixed,
}

impl Mix {
    pub const ALL: [Mix; 3] = [Mix::Hot, Mix::Cold, Mix::Mixed];

    pub fn as_str(self) -> &'static str {
        match self {
            Mix::Hot => "hot",
            Mix::Cold => "cold",
            Mix::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<Mix> {
        Mix::ALL.iter().copied().find(|m| m.as_str() == s)
    }

    /// Every accepted spelling joined with `|` — the single source for
    /// the CLI's unknown-mix usage error, so the error can never drift
    /// from the registry (mirrors [`BackendId::names`]).
    pub fn names() -> String {
        Mix::ALL
            .iter()
            .map(|m| m.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

/// Load-generator options (the `loadgen` subcommand's flags).
pub struct LoadgenOptions {
    /// Service configuration for a self-hosted target (ignored with
    /// [`LoadgenOptions::addr`] set).
    pub cfg: Config,
    /// Measure an already-running server at this address instead of
    /// self-hosting one. Self-hosting (None) binds an ephemeral
    /// 127.0.0.1 port and serves from a background thread, so the
    /// measurement includes a known-fresh cache.
    pub addr: Option<String>,
    /// Concurrent closed-loop connections (workers).
    pub connections: usize,
    /// Warm-up before the measured window, milliseconds.
    pub warmup_ms: u64,
    /// Measured-window length, milliseconds.
    pub duration_ms: u64,
    /// Request mix.
    pub mix: Mix,
    /// Io model for the self-hosted server (ignored with `addr`).
    pub io: IoModel,
    /// `false` sends `"cache":false` on every request *and* disables
    /// the self-hosted server's cache — the `--no-cache` measurement
    /// escape hatch.
    pub cache: bool,
    /// Default execution backend for the self-hosted server.
    pub default_backend: BackendId,
}

impl LoadgenOptions {
    pub fn new(cfg: Config) -> LoadgenOptions {
        LoadgenOptions {
            cfg,
            addr: None,
            connections: 64,
            warmup_ms: 500,
            duration_ms: 2000,
            mix: Mix::Mixed,
            io: IoModel::default_for_platform(),
            cache: true,
            default_backend: crate::backend::DEFAULT,
        }
    }
}

/// One finished run's numbers (everything `BENCH_serve.json` records).
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Requests completed inside the measured window.
    pub requests: u64,
    /// Sustained completed-requests per second over the window.
    pub req_per_sec: f64,
    /// Nearest-rank latency percentiles over the window, nanoseconds.
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p99_ns: u64,
    /// Worker connections driven.
    pub connections: usize,
    /// Io model measured (self-host) or `None` for a remote target
    /// whose model the client cannot observe (by design).
    pub io: Option<IoModel>,
    /// Measured window length, milliseconds (wall clock, not the
    /// requested `duration_ms`).
    pub measured_ms: f64,
    /// Typed `overloaded` rejections (retryable; not failures).
    pub overloaded: u64,
    /// Unexpected typed errors (any is a run failure).
    pub errors: u64,
    /// First unexpected error message, for the failure report.
    pub first_error: Option<String>,
    /// Server result-cache hit rate after the run (`hits / lookups`),
    /// if a final `stats` request answered.
    pub cache_hit_rate: Option<f64>,
}

/// Per-worker tally, merged after the stop flag.
#[derive(Default)]
struct WorkerStats {
    latencies_ns: Vec<u64>,
    measured: u64,
    overloaded: u64,
    errors: u64,
    first_error: Option<String>,
    transport: Option<String>,
}

/// What one issued operation came back as.
enum Outcome {
    Served,
    Overloaded,
    TypedError(String),
}

/// The hot request: one cacheable point repeated by every worker, so
/// after warm-up it is the cache-hit fast path.
fn hot_request() -> Request {
    Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 }
}

/// The `k`-th cold request of worker `w`: a `sparsity` point nobody
/// else asks for. Worker-strided indexing keeps the keyspace disjoint
/// across workers (unique for the first ~1M points — far beyond any
/// window), so every cold request is a genuine miss.
fn cold_request(worker: usize, k: u64, connections: usize) -> Request {
    let idx = worker as u64 + connections as u64 * k;
    Request::Sparsity {
        n: 1 + (idx % 16384) as usize,
        streams: 1 + ((idx / 16384) % 64) as usize,
    }
}

/// A small synchronous two-point sweep (the `scenario` serve path).
fn scenario_spec() -> ScenarioSpec {
    let mut spec = ScenarioSpec::new(Ask::Sim);
    spec.sweep.streams = vec![1, 2];
    spec
}

/// Issue one operation per the mix and classify its outcome. `cold_k`
/// advances only when a cold point was actually spent.
fn issue(
    client: &mut Client,
    mix: Mix,
    worker: usize,
    connections: usize,
    k: u64,
    cold_k: &mut u64,
    cache: bool,
) -> io::Result<Outcome> {
    let classify = |resp: Response| match resp {
        Response::Error { code: ErrorCode::Overloaded, .. } => {
            Outcome::Overloaded
        }
        Response::Error { code, message } => Outcome::TypedError(format!(
            "{}: {message}",
            code.as_str()
        )),
        _ => Outcome::Served,
    };
    let simple = |client: &mut Client, req: &Request| {
        client.request_opts(req, cache).map(classify)
    };
    match mix {
        Mix::Hot => simple(client, &hot_request()),
        Mix::Cold => {
            let req = cold_request(worker, *cold_k, connections);
            *cold_k += 1;
            simple(client, &req)
        }
        Mix::Mixed => match k % 64 {
            // One watched job per 64 ops: submit, stream every progress
            // frame, fetch the result — one logical request end to end.
            0 => client
                .submit_and_wait(&scenario_spec(), |_| {})
                .map(classify),
            1..=3 => {
                simple(client, &Request::Scenario { spec: scenario_spec() })
            }
            4..=9 => {
                let req = cold_request(worker, *cold_k, connections);
                *cold_k += 1;
                simple(client, &req)
            }
            _ => simple(client, &hot_request()),
        },
    }
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted_ns: &[u64], p: f64) -> u64 {
    if sorted_ns.is_empty() {
        return 0;
    }
    let rank = (p / 100.0 * sorted_ns.len() as f64).ceil() as usize;
    sorted_ns[rank.clamp(1, sorted_ns.len()) - 1]
}

/// Run the load generator. Self-hosts a server when
/// [`LoadgenOptions::addr`] is `None`. `Ok` means the run *executed*;
/// inspect [`LoadgenReport::errors`] / `requests` for pass/fail (the
/// CLI and ci.sh fail on any unexpected typed error or a zero-request
/// window).
pub fn run(opts: &LoadgenOptions) -> io::Result<LoadgenReport> {
    // Self-host if no target was given: bind the ephemeral port
    // ourselves so the address is known without parsing stdout, and
    // cap accepts at exactly our connection count (workers + the final
    // stats probe) so the server thread exits cleanly when we do.
    let accepts = opts.connections + 1;
    let (addr, server) = match &opts.addr {
        Some(a) => (a.clone(), None),
        None => {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?.to_string();
            let policy = if opts.cache {
                CachePolicy::default()
            } else {
                CachePolicy::disabled()
            };
            let svc = Arc::new(Service::with_default_backend(
                opts.cfg.clone(),
                policy,
                opts.default_backend,
            ));
            let io = opts.io;
            let handle = thread::Builder::new()
                .name("loadgen-server".into())
                .spawn(move || serve_on(listener, svc, Some(accepts), io))?;
            (addr, Some(handle))
        }
    };

    let phase = Arc::new(AtomicU8::new(PHASE_WARMUP));
    let mut workers = Vec::with_capacity(opts.connections);
    for w in 0..opts.connections {
        let phase = Arc::clone(&phase);
        let addr = addr.clone();
        let mix = opts.mix;
        let cache = opts.cache;
        let connections = opts.connections;
        workers.push(
            thread::Builder::new()
                .name(format!("loadgen-worker-{w}"))
                .spawn(move || -> WorkerStats {
                    let mut stats = WorkerStats::default();
                    let mut client =
                        match Client::connect_retry(addr.as_str(), 400) {
                            Ok(c) => c,
                            Err(e) => {
                                stats.transport =
                                    Some(format!("connect: {e}"));
                                return stats;
                            }
                        };
                    let mut k = 0u64;
                    let mut cold_k = 0u64;
                    loop {
                        let p = phase.load(Ordering::Acquire);
                        if p == PHASE_STOP {
                            break;
                        }
                        let start = Instant::now();
                        let outcome = issue(
                            &mut client,
                            mix,
                            w,
                            connections,
                            k,
                            &mut cold_k,
                            cache,
                        );
                        k += 1;
                        match outcome {
                            Ok(Outcome::Served) => {
                                if p == PHASE_MEASURE {
                                    stats.measured += 1;
                                    stats.latencies_ns.push(
                                        start.elapsed().as_nanos() as u64,
                                    );
                                }
                            }
                            Ok(Outcome::Overloaded) => {
                                if p == PHASE_MEASURE {
                                    stats.overloaded += 1;
                                }
                                // Retryable by design: back off a touch
                                // so the queue can drain.
                                thread::sleep(Duration::from_millis(2));
                            }
                            Ok(Outcome::TypedError(msg)) => {
                                stats.errors += 1;
                                stats.first_error.get_or_insert(msg);
                            }
                            Err(e) => {
                                stats.transport =
                                    Some(format!("request: {e}"));
                                break;
                            }
                        }
                    }
                    stats
                })?,
        );
    }

    // Phase timer (this thread): warm up, open the window, close it.
    thread::sleep(Duration::from_millis(opts.warmup_ms));
    let window_open = Instant::now();
    phase.store(PHASE_MEASURE, Ordering::Release);
    thread::sleep(Duration::from_millis(opts.duration_ms));
    phase.store(PHASE_STOP, Ordering::Release);
    let measured_ms = window_open.elapsed().as_secs_f64() * 1e3;

    let mut all = WorkerStats::default();
    for h in workers {
        let s = h.join().map_err(|_| {
            io::Error::new(io::ErrorKind::Other, "loadgen worker panicked")
        })?;
        all.measured += s.measured;
        all.overloaded += s.overloaded;
        all.errors += s.errors;
        all.latencies_ns.extend(s.latencies_ns);
        if all.first_error.is_none() {
            all.first_error = s.first_error;
        }
        // A worker that lost its transport mid-run is a failure too.
        if let Some(t) = s.transport {
            all.errors += 1;
            all.first_error.get_or_insert(t);
        }
    }

    // Final probe: the server-side cache hit rate (also the +1 accept
    // that lets a self-hosted server finish).
    let cache_hit_rate = Client::connect_retry(addr.as_str(), 100)
        .ok()
        .and_then(|mut c| c.request(&Request::Stats).ok())
        .and_then(|resp| match resp {
            Response::Stats { cache, .. } => {
                let lookups = cache.hits + cache.misses;
                if lookups > 0 {
                    Some(cache.hits as f64 / lookups as f64)
                } else {
                    Some(0.0)
                }
            }
            _ => None,
        });
    if let Some(h) = server {
        // Self-hosted: all accepts are spent, the server loop exits.
        let _ = h.join();
    }

    all.latencies_ns.sort_unstable();
    let window_s = measured_ms / 1e3;
    Ok(LoadgenReport {
        requests: all.measured,
        req_per_sec: if window_s > 0.0 {
            all.measured as f64 / window_s
        } else {
            0.0
        },
        p50_ns: percentile(&all.latencies_ns, 50.0),
        p90_ns: percentile(&all.latencies_ns, 90.0),
        p99_ns: percentile(&all.latencies_ns, 99.0),
        connections: opts.connections,
        io: if opts.addr.is_none() { Some(opts.io) } else { None },
        measured_ms,
        overloaded: all.overloaded,
        errors: all.errors,
        first_error: all.first_error,
        cache_hit_rate,
    })
}

/// Write a report as `BENCH_serve.json` (bench-v1; throughput,
/// percentiles, and run shape in `extra`) and return the path.
pub fn write_bench(
    report: &LoadgenReport,
    opts: &LoadgenOptions,
) -> io::Result<std::path::PathBuf> {
    let lat = if report.requests > 0 {
        // The summary row: mean is unavailable from percentiles alone,
        // so record the median as the representative per-request cost
        // and let `extra` carry the tail.
        BenchResult {
            name: format!("serve/request_{}", opts.mix.as_str()),
            iters: report.requests as usize,
            mean_ns: report.p50_ns as f64,
            std_ns: 0.0,
            min_ns: report.p50_ns as f64,
            max_ns: report.p99_ns as f64,
        }
    } else {
        BenchResult {
            name: format!("serve/request_{}", opts.mix.as_str()),
            iters: 0,
            mean_ns: 0.0,
            std_ns: 0.0,
            min_ns: 0.0,
            max_ns: 0.0,
        }
    };
    let mut bencher = Bencher::new(0, report.requests as usize);
    bencher.record(lat);
    let io_name = report
        .io
        .map(|m| m.as_str().to_string())
        .unwrap_or_else(|| "remote".to_string());
    bencher.write_json(
        "serve",
        vec![
            ("req_per_sec", Json::Num(report.req_per_sec)),
            ("requests", Json::Num(report.requests as f64)),
            ("p50_ns", Json::Num(report.p50_ns as f64)),
            ("p90_ns", Json::Num(report.p90_ns as f64)),
            ("p99_ns", Json::Num(report.p99_ns as f64)),
            ("connections", Json::Num(report.connections as f64)),
            ("io_model", Json::Str(io_name)),
            ("mix", Json::Str(opts.mix.as_str().to_string())),
            ("cache", Json::Bool(opts.cache)),
            (
                "cache_hit_rate",
                report
                    .cache_hit_rate
                    .map(Json::Num)
                    .unwrap_or(Json::Null),
            ),
            ("overloaded", Json::Num(report.overloaded as f64)),
            ("errors", Json::Num(report.errors as f64)),
            ("duration_ms", Json::Num(report.measured_ms)),
            ("warmup_ms", Json::Num(opts.warmup_ms as f64)),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_spellings_round_trip() {
        for m in Mix::ALL {
            assert_eq!(Mix::parse(m.as_str()), Some(m));
        }
        assert_eq!(Mix::parse("warm"), None);
    }

    #[test]
    fn mix_names_lists_every_spelling() {
        assert_eq!(Mix::names(), "hot|cold|mixed");
        for m in Mix::ALL {
            assert!(Mix::names().contains(m.as_str()));
        }
    }

    #[test]
    fn nearest_rank_percentiles() {
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 50.0), 50);
        assert_eq!(percentile(&s, 90.0), 90);
        assert_eq!(percentile(&s, 99.0), 99);
        assert_eq!(percentile(&s, 100.0), 100);
        assert_eq!(percentile(&[7], 50.0), 7);
        assert_eq!(percentile(&[], 99.0), 0);
    }

    #[test]
    fn cold_keyspace_is_disjoint_across_workers() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for w in 0..8 {
            for k in 0..200 {
                match cold_request(w, k, 8) {
                    Request::Sparsity { n, streams } => {
                        assert!((1..=16384).contains(&n));
                        assert!((1..=64).contains(&streams));
                        assert!(
                            seen.insert((n, streams)),
                            "duplicate cold point n={n} streams={streams}"
                        );
                    }
                    other => panic!("unexpected request {other:?}"),
                }
            }
        }
    }

    /// End-to-end smoke over a real self-hosted server: a short hot run
    /// must complete requests, no typed errors, and (cache on) a high
    /// hit rate. Uses the threads model so the test is portable; the
    /// epoll path is covered by tests/serve_integration.rs and the
    /// ci.sh loadgen smoke.
    #[test]
    fn self_hosted_hot_run_completes() {
        let mut opts = LoadgenOptions::new(Config::mi300a());
        opts.connections = 2;
        opts.warmup_ms = 50;
        opts.duration_ms = 150;
        opts.mix = Mix::Hot;
        opts.io = IoModel::Threads;
        let report = run(&opts).expect("loadgen run");
        assert_eq!(report.errors, 0, "{:?}", report.first_error);
        assert!(report.requests > 0, "zero throughput: {report:?}");
        assert!(report.p50_ns > 0);
        assert!(report.p99_ns >= report.p50_ns);
        let rate = report.cache_hit_rate.expect("stats probe");
        assert!(rate > 0.5, "hot mix should be cache-hit dominated: {rate}");
    }
}
