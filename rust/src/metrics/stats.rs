//! Summary statistics used throughout the experiment drivers.

/// Summary of a sample: mean, std, extremes, percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }

    /// Coefficient of variation (paper §4.2: "variability is quantified
    /// using coefficient of variation across multiple runs").
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constants() {
        let s = Summary::of(&[5.0, 5.0, 5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.cv(), 0.0);
        assert_eq!(s.p50, 5.0);
    }

    #[test]
    fn summary_hand_computed() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.5);
        // population std of 1..4 = sqrt(1.25)
        assert!((s.std - 1.25f64.sqrt()).abs() < 1e-12);
        assert!((s.cv() - 1.25f64.sqrt() / 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        Summary::of(&[]);
    }
}
