//! Measurement metrics (paper §4.2): fairness, overlap efficiency,
//! coefficient of variation, and summary statistics.

pub mod fairness;
pub mod stats;

pub use fairness::{fairness, fairness_minmax, overlap_efficiency};
pub use stats::Summary;
