//! Fairness and overlap metrics exactly as the paper defines them.

/// §4.2 fairness: `1 - (t_max - t_min) / t_mean` over per-stream
/// execution times. Ranges (-inf, 1]; the paper clamps display to
/// [0, 1], which we preserve — 1.0 means perfectly balanced progress.
pub fn fairness(per_stream_times: &[f64]) -> f64 {
    assert!(!per_stream_times.is_empty());
    let n = per_stream_times.len() as f64;
    let mean = per_stream_times.iter().sum::<f64>() / n;
    if mean <= 0.0 {
        return 1.0;
    }
    let max = per_stream_times.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_stream_times.iter().cloned().fold(f64::MAX, f64::min);
    (1.0 - (max - min) / mean).clamp(0.0, 1.0)
}

/// §7.2.1 fairness variant: `t_min / t_max` (the sparsity-under-
/// contention experiments report "minimum to maximum per-stream
/// execution time ratio, where 1.0 indicates perfect balance").
pub fn fairness_minmax(per_stream_times: &[f64]) -> f64 {
    assert!(!per_stream_times.is_empty());
    let max = per_stream_times.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_stream_times.iter().cloned().fold(f64::MAX, f64::min);
    if max <= 0.0 {
        return 1.0;
    }
    (min / max).clamp(0.0, 1.0)
}

/// §4.2 overlap efficiency: fraction of total execution time during
/// which multiple kernels execute concurrently, from per-stream
/// (start, end) intervals. Computed by sweeping interval boundaries.
pub fn overlap_efficiency(intervals: &[(f64, f64)]) -> f64 {
    if intervals.len() < 2 {
        return 0.0;
    }
    let t0 = intervals.iter().map(|i| i.0).fold(f64::MAX, f64::min);
    let t1 = intervals.iter().map(|i| i.1).fold(f64::MIN, f64::max);
    let total = t1 - t0;
    if total <= 0.0 {
        return 0.0;
    }
    // Event sweep over boundaries.
    let mut events: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for &(s, e) in intervals {
        if e > s {
            events.push((s, 1));
            events.push((e, -1));
        }
    }
    events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut active = 0i32;
    let mut last = t0;
    let mut overlapped = 0.0;
    for (t, d) in events {
        if active >= 2 {
            overlapped += t - last;
        }
        last = t;
        active += d;
    }
    overlapped / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fairness_perfect_balance() {
        assert_eq!(fairness(&[10.0, 10.0, 10.0]), 1.0);
        assert_eq!(fairness_minmax(&[10.0, 10.0]), 1.0);
    }

    #[test]
    fn fairness_hand_computed() {
        // times 8, 10, 12: mean 10, max-min = 4 -> 1 - 0.4 = 0.6.
        assert!((fairness(&[8.0, 10.0, 12.0]) - 0.6).abs() < 1e-12);
        // min/max variant: 8/12.
        assert!((fairness_minmax(&[8.0, 10.0, 12.0]) - 8.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn fairness_clamps_at_zero() {
        // Extreme spread: 1 - (100-1)/mean < 0 -> clamp to 0.
        assert_eq!(fairness(&[1.0, 100.0]), 0.0);
    }

    #[test]
    fn fairness_in_unit_interval_property() {
        use crate::util::proptest::check;
        check(200, 42, |g| {
            let n = g.usize_in(1, 16);
            let xs: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 1e6)).collect();
            let f = fairness(&xs);
            let fm = fairness_minmax(&xs);
            if !(0.0..=1.0).contains(&f) {
                return Err(format!("fairness {f} out of range"));
            }
            if !(0.0..=1.0).contains(&fm) {
                return Err(format!("fairness_minmax {fm} out of range"));
            }
            Ok(())
        });
    }

    #[test]
    fn overlap_disjoint_is_zero() {
        assert_eq!(overlap_efficiency(&[(0.0, 1.0), (1.0, 2.0)]), 0.0);
        assert_eq!(overlap_efficiency(&[(0.0, 5.0)]), 0.0);
    }

    #[test]
    fn overlap_full_is_one() {
        let o = overlap_efficiency(&[(0.0, 10.0), (0.0, 10.0)]);
        assert!((o - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_hand_computed() {
        // [0,10] and [5,15]: overlap 5 over total span 15 = 1/3.
        let o = overlap_efficiency(&[(0.0, 10.0), (5.0, 15.0)]);
        assert!((o - 5.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_three_streams_counts_pairwise_regions() {
        // [0,4],[2,6],[8,10]: >=2 active during [2,4] -> 2 / span 10.
        let o = overlap_efficiency(&[(0.0, 4.0), (2.0, 6.0), (8.0, 10.0)]);
        assert!((o - 0.2).abs() < 1e-12);
    }
}
