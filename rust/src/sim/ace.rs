//! Asynchronous Compute Engine (ACE) queue model (paper §2, §6).
//!
//! ROCm's HSA runtime maps user-level queues onto hardware command
//! processors round-robin (paper ref [20]); queues sharing an ACE
//! serialize their launch phases, which is visible as reduced overlap
//! when streams exceed the ACE count.

/// A user-visible stream/queue handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QueueId(pub usize);

/// The ACE set: fixed hardware command processors, round-robin queue
/// assignment (HSA semantics).
#[derive(Debug, Clone)]
pub struct AceSet {
    n_aces: usize,
    assignments: Vec<usize>, // queue index -> ace index
}

impl AceSet {
    pub fn new(n_aces: usize) -> AceSet {
        assert!(n_aces > 0);
        AceSet { n_aces, assignments: Vec::new() }
    }

    /// Create a queue; returns its id and the ACE it maps to.
    pub fn create_queue(&mut self) -> (QueueId, usize) {
        let q = QueueId(self.assignments.len());
        let ace = self.assignments.len() % self.n_aces;
        self.assignments.push(ace);
        (q, ace)
    }

    pub fn ace_of(&self, q: QueueId) -> usize {
        self.assignments[q.0]
    }

    pub fn n_aces(&self) -> usize {
        self.n_aces
    }

    /// Queues currently mapped to each ACE.
    pub fn load(&self) -> Vec<usize> {
        let mut load = vec![0; self.n_aces];
        for &a in &self.assignments {
            load[a] += 1;
        }
        load
    }

    /// Launch serialization factor for a queue: how many queues share
    /// its ACE (launch phases on one ACE are serialized).
    pub fn serialization(&self, q: QueueId) -> usize {
        let ace = self.ace_of(q);
        self.assignments.iter().filter(|&&a| a == ace).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_assignment() {
        let mut aces = AceSet::new(4);
        let ids: Vec<usize> = (0..8).map(|_| aces.create_queue().1).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn load_balanced_within_one() {
        let mut aces = AceSet::new(8);
        for _ in 0..11 {
            aces.create_queue();
        }
        let load = aces.load();
        let max = *load.iter().max().unwrap();
        let min = *load.iter().min().unwrap();
        assert!(max - min <= 1, "round robin keeps load within 1: {load:?}");
    }

    #[test]
    fn serialization_counts_sharers() {
        let mut aces = AceSet::new(2);
        let (q0, _) = aces.create_queue();
        let (q1, _) = aces.create_queue();
        let (q2, _) = aces.create_queue(); // shares ACE 0 with q0
        assert_eq!(aces.serialization(q0), 2);
        assert_eq!(aces.serialization(q1), 1);
        assert_eq!(aces.serialization(q2), 2);
    }

    #[test]
    fn up_to_ace_count_no_sharing() {
        let mut aces = AceSet::new(8);
        let qs: Vec<QueueId> = (0..8).map(|_| aces.create_queue().0).collect();
        for q in qs {
            assert_eq!(aces.serialization(q), 1);
        }
    }
}
