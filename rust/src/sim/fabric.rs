//! Fabric transfers as first-class DES events.
//!
//! The `des` backend answers multi-device points by composing two
//! event-stepped layers: the existing kernel engine
//! ([`crate::sim::engine`]) replays one device's compute, and this
//! module steps the inter-APU exchange of [`crate::fabric::Transfer`]s
//! the shape's schedule prescribes. Transfers share links and egress
//! ports by processor sharing — exactly the machinery the engine uses
//! for ACE lanes — so a transfer's instantaneous rate is the link
//! bandwidth divided by the congestion of its most contended resource,
//! re-evaluated at every start/finish event.
//!
//! On the uniform collective schedules of `data_parallel`, `pipeline`
//! and `halo` this stepping reproduces the closed-form link-saturation
//! bound ([`crate::fabric::Fabric::round_ns`]) exactly, which is what
//! keeps the DES and analytic backends byte-comparable on the
//! communication half of a multi-device point (the equivalence gap
//! comes from the compute estimate alone; `tests/backend_equivalence.rs`
//! pins the combined tolerance).

use crate::fabric::{Fabric, Transfer};

/// One stepped exchange: elapsed wall-clock and the discrete events
/// processed (one start + one completion per transfer).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FabricRun {
    pub elapsed_ns: f64,
    pub events: u64,
}

/// Processor-sharing event stepper over a [`Fabric`].
pub struct FabricSim {
    fabric: Fabric,
}

impl FabricSim {
    pub fn new(fabric: Fabric) -> FabricSim {
        FabricSim { fabric }
    }

    /// Step one synchronized round: every transfer pays the link
    /// latency, then drains concurrently under processor sharing.
    /// Returns when the last byte lands.
    pub fn run_round(&self, transfers: &[Transfer]) -> FabricRun {
        // (remaining bytes, resource indices) per live transfer.
        let mut live: Vec<(f64, Vec<usize>)> = transfers
            .iter()
            .filter(|t| t.src != t.dst && t.bytes > 0.0)
            .map(|t| (t.bytes, self.fabric.resources(t)))
            .collect();
        if live.is_empty() {
            return FabricRun { elapsed_ns: 0.0, events: 0 };
        }
        let mut events = live.len() as u64; // start events
        let mut clock = self.fabric.latency_ns;
        let n_res = self.fabric.devices
            + self.fabric.devices * self.fabric.devices.max(2) * 2;
        let mut congestion = vec![0u32; n_res];
        while !live.is_empty() {
            for c in &mut congestion {
                *c = 0;
            }
            for (_, res) in &live {
                for &r in res {
                    congestion[r] += 1;
                }
            }
            // Each transfer drains at bw / (most contended resource).
            let rate = |res: &[usize]| {
                let worst =
                    res.iter().map(|&r| congestion[r]).max().unwrap_or(1);
                self.fabric.bytes_per_ns / worst.max(1) as f64
            };
            // Advance to the earliest completion at current rates.
            let dt = live
                .iter()
                .map(|(rem, res)| rem / rate(res))
                .fold(f64::INFINITY, f64::min);
            clock += dt;
            for (rem, res) in &mut live {
                *rem -= rate(res) * dt;
            }
            live.retain(|(rem, _)| {
                let done = *rem <= 1e-9;
                if done {
                    events += 1;
                }
                !done
            });
        }
        FabricRun { elapsed_ns: clock, events }
    }

    /// Step a multi-round schedule (rounds run back to back, as the
    /// collectives synchronize between steps).
    pub fn run_schedule(&self, schedule: &[Vec<Transfer>]) -> FabricRun {
        let mut total = FabricRun { elapsed_ns: 0.0, events: 0 };
        for round in schedule {
            let r = self.run_round(round);
            total.elapsed_ns += r.elapsed_ns;
            total.events += r.events;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::scenario::Shape;
    use crate::fabric::{DeviceSet, Topology};

    fn sim(devices: usize, topology: Topology) -> (Fabric, FabricSim) {
        let f = Fabric::for_set(DeviceSet { devices, topology });
        (f, FabricSim::new(f))
    }

    #[test]
    fn single_transfer_costs_latency_plus_bytes_over_bw() {
        let (f, s) = sim(2, Topology::FullyConnected);
        let t = Transfer { src: 0, dst: 1, bytes: 4800.0 };
        let r = s.run_round(&[t]);
        assert!((r.elapsed_ns - f.transfer_ns(4800.0)).abs() < 1e-9);
        assert_eq!(r.events, 2, "one start + one completion");
    }

    #[test]
    fn stepped_collectives_match_the_closed_forms() {
        let bytes = 512.0 * 512.0 * 4.0;
        for t in Topology::ALL {
            for d in 2..=4 {
                let (f, s) = sim(d, t);
                for (shape, closed) in [
                    (Shape::DataParallel, f.allreduce_ns(bytes)),
                    (Shape::Halo, f.halo_ns(bytes)),
                ] {
                    let sched = f.shape_schedule(shape, bytes);
                    let r = s.run_schedule(&sched);
                    assert!(
                        (r.elapsed_ns - closed).abs() < 1e-6 * closed,
                        "{shape:?} {t:?} d={d}: stepped {} vs closed \
                         {closed}",
                        r.elapsed_ns
                    );
                    assert!(r.events > 0);
                }
            }
        }
    }

    #[test]
    fn egress_sharing_halves_the_rate_of_a_fan_out() {
        // One source, two destinations: both transfers share the
        // egress port, so both finish at latency + 2B/bw.
        let (f, s) = sim(3, Topology::FullyConnected);
        let b = 48_000.0;
        let r = s.run_round(&[
            Transfer { src: 0, dst: 1, bytes: b },
            Transfer { src: 0, dst: 2, bytes: b },
        ]);
        let want = f.latency_ns + 2.0 * b / f.bytes_per_ns;
        assert!((r.elapsed_ns - want).abs() < 1e-9, "{}", r.elapsed_ns);
        // Distinct sources keep full rate.
        let r = s.run_round(&[
            Transfer { src: 0, dst: 1, bytes: b },
            Transfer { src: 2, dst: 1, bytes: b },
        ]);
        let want = f.latency_ns + b / f.bytes_per_ns;
        assert!((r.elapsed_ns - want).abs() < 1e-9, "{}", r.elapsed_ns);
    }

    #[test]
    fn deterministic_and_empty_rounds_are_free() {
        let (f, s) = sim(4, Topology::Ring);
        let sched = f.shape_schedule(Shape::DataParallel, 1e6);
        assert_eq!(s.run_schedule(&sched), s.run_schedule(&sched));
        assert_eq!(
            s.run_round(&[]),
            FabricRun { elapsed_ns: 0.0, events: 0 }
        );
    }
}
