//! The MI300A execution simulator: kernel descriptors, solo cost model,
//! microbenchmark models (Figs 2-3, Table 3), ACE queue model, and the
//! processor-sharing DES for concurrent streams (Figs 4-9, 13).

pub mod ace;
pub mod cost;
pub mod engine;
pub mod fabric;
pub mod kernel;
pub mod microbench;
pub mod trace;

pub use ace::{AceSet, QueueId};
pub use cost::CostModel;
pub use engine::{ConcurrencyProfile, ConcurrentRun, Engine, StreamOutcome};
pub use fabric::{FabricRun, FabricSim};
pub use kernel::{KernelDesc, SparsityMode};
pub use microbench::{MicrobenchModel, OccupancyPoint};
