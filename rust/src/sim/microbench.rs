//! Wavefront-level microbenchmark models: Fig 2 (occupancy scaling),
//! Fig 3 (shape sensitivity), Table 3 (dependency-chain latency).
//!
//! These model the paper's §5 kernels: one wavefront per block, a single
//! MFMA opcode issued `iters` times, operands register/LDS resident with
//! a small streamed fraction (`mb_stream_fraction`) that produces the
//! memory-feed bend at high wavefront counts.

use crate::config::Config;
use crate::hw::HbmModel;
use crate::isa::{primary_opcode, MfmaOpcode, Precision};
use crate::util::rng::Rng;

/// Result of one occupancy point.
#[derive(Debug, Clone)]
pub struct OccupancyPoint {
    pub wavefronts: usize,
    pub gflops: f64,
    pub normalized: f64,
}

/// Fig-2 model: throughput vs total active wavefronts for a precision.
pub struct MicrobenchModel<'a> {
    cfg: &'a Config,
    hbm: HbmModel,
}

impl<'a> MicrobenchModel<'a> {
    pub fn new(cfg: &'a Config) -> MicrobenchModel<'a> {
        MicrobenchModel { cfg, hbm: HbmModel::new(cfg) }
    }

    /// Effective per-instruction interval (ns) for one wavefront of
    /// `opcode` when `waves` wavefronts are active machine-wide.
    pub fn instr_interval_ns(&self, opcode: &MfmaOpcode, waves: usize) -> f64 {
        let issue_eff = self.cfg.issue_eff(opcode.a);
        // Dependency-limited issue: Table-3 chain latency divided by the
        // effective independent chains of the microbenchmark.
        let t_issue = opcode.latency_ns / issue_eff;

        // Memory feed: a small fraction of operand bytes streams from
        // HBM; per-wavefront share of effective bandwidth sets the feed
        // rate. This is what bends the curve at high occupancy and makes
        // FP8 memory-latency-bound (paper §9.1).
        let bytes = opcode.tile.operand_bytes(opcode.a.bytes()) as f64
            * self.cfg.calib.mb_stream_fraction;
        let demand_per_wave = bytes / t_issue; // B/ns if unthrottled
        let total_demand = demand_per_wave * waves as f64;
        let share = self.hbm.share(demand_per_wave, total_demand).max(1e-9);
        let t_mem = bytes / share;

        // CU pipe sharing: beyond one wavefront per CU, wavefronts on the
        // same CU share its MFMA pipes.
        let cus = self.cfg.total_cus() as f64;
        let waves_per_cu = (waves as f64 / cus).max(1.0);
        let pipes = self.cfg.hw.mfma_per_cu;
        let pipe_factor = (waves_per_cu / pipes).max(1.0);

        t_issue.max(t_mem) * pipe_factor
    }

    /// Aggregate throughput (GFLOPS) at a wavefront count.
    pub fn throughput_gflops(&self, p: Precision, waves: usize) -> f64 {
        let op = primary_opcode(p);
        let t = self.instr_interval_ns(op, waves);
        waves as f64 * op.tile.flops() / t
    }

    /// Fig-2 sweep: normalized throughput for wavefront counts.
    pub fn occupancy_sweep(&self, p: Precision, counts: &[usize]) -> Vec<OccupancyPoint> {
        counts
            .iter()
            .map(|&w| {
                let gflops = self.throughput_gflops(p, w);
                OccupancyPoint {
                    wavefronts: w,
                    gflops,
                    normalized: gflops / p.peak_gflops(),
                }
            })
            .collect()
    }

    /// Shape factor for an aspect ratio (Fig 3): non-square launches lose
    /// effective tile utilization and scheduling efficiency, worst at
    /// 4:1. Penalty scales per precision with its calibrated maximum
    /// (FP8 16%, FP32 ~3%; others interpolate by tile skew).
    pub fn shape_factor(&self, p: Precision, aspect: f64) -> f64 {
        let max_pen = match p {
            Precision::Fp8 | Precision::Bf8 => self.cfg.calib.shape_penalty_fp8,
            Precision::F32 => self.cfg.calib.shape_penalty_f32,
            Precision::F16 => 0.09,
            Precision::Bf16 => 0.10,
            Precision::F64 => 0.05,
        };
        // |log2(aspect)| in [0, 2] over the paper's 1:4..4:1 sweep.
        let skew = aspect.max(1e-9).log2().abs().min(2.0) / 2.0;
        1.0 - max_pen * skew
    }

    /// Fig-3 point: absolute GFLOPS at fixed total blocks and an aspect
    /// ratio (M/N varies, total work constant).
    pub fn shape_throughput(&self, p: Precision, aspect: f64, blocks: usize) -> f64 {
        self.throughput_gflops(p, blocks) * self.shape_factor(p, aspect)
    }

    /// Table-3 measurement: dependency-chain latency of one opcode as
    /// the simulated instruction-targeted microbenchmark observes it
    /// (isolated single kernel, warmed up; only timer-grain noise).
    pub fn measure_chain_latency_ns(&self, opcode: &MfmaOpcode, rng: &mut Rng) -> f64 {
        let reps = 2000.0;
        // Timer granularity + loop overhead: sub-0.3% after warm-up.
        let noise = rng.normal_ms(1.0, 0.002);
        let total = opcode.latency_ns * reps * noise;
        total / reps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::lookup;

    fn model(cfg: &Config) -> MicrobenchModel<'_> {
        MicrobenchModel::new(cfg)
    }

    #[test]
    fn throughput_monotone_in_waves() {
        let cfg = Config::mi300a();
        let m = model(&cfg);
        for p in Precision::SWEEP {
            let mut prev = 0.0;
            for w in [1, 2, 4, 8, 16, 32, 64, 128, 256] {
                let t = m.throughput_gflops(p, w);
                assert!(t > prev, "{p} at {w} waves: {t} <= {prev}");
                prev = t;
            }
        }
    }

    #[test]
    fn scaling_is_sublinear_at_high_occupancy() {
        // Paper §5.2: "throughput scales sublinearly with wavefront count
        // for every precision".
        let cfg = Config::mi300a();
        let m = model(&cfg);
        for p in Precision::SWEEP {
            let t128 = m.throughput_gflops(p, 128);
            let t256 = m.throughput_gflops(p, 256);
            assert!(
                t256 < 2.0 * t128 * 1.001,
                "{p}: 128->256 waves must not superscale"
            );
        }
    }

    #[test]
    fn low_occupancy_strongly_underutilized() {
        // Paper: "at low occupancy, all precisions are strongly
        // underutilized".
        let cfg = Config::mi300a();
        let m = model(&cfg);
        for p in Precision::SWEEP {
            let pt = &m.occupancy_sweep(p, &[8])[0];
            assert!(pt.normalized < 0.01, "{p}: {:.4}", pt.normalized);
        }
    }

    #[test]
    fn fp8_highest_normalized_at_256() {
        let cfg = Config::mi300a();
        let m = model(&cfg);
        let at256: Vec<(Precision, f64)> = Precision::SWEEP
            .iter()
            .map(|&p| (p, m.occupancy_sweep(p, &[256])[0].normalized))
            .collect();
        let fp8 = at256.iter().find(|(p, _)| *p == Precision::Fp8).unwrap().1;
        for (p, norm) in &at256 {
            if *p != Precision::Fp8 {
                assert!(fp8 >= *norm, "{p} normalized {norm} > FP8 {fp8}");
            }
        }
    }

    #[test]
    fn shape_factor_worst_at_4_to_1_for_fp8() {
        let cfg = Config::mi300a();
        let m = model(&cfg);
        let at1 = m.shape_factor(Precision::Fp8, 1.0);
        let at4 = m.shape_factor(Precision::Fp8, 4.0);
        let at_quarter = m.shape_factor(Precision::Fp8, 0.25);
        assert_eq!(at1, 1.0);
        assert!((at1 - at4 - cfg.calib.shape_penalty_fp8).abs() < 1e-9);
        assert!((at4 - at_quarter).abs() < 1e-9, "penalty symmetric in log");
        // FP32 is much less shape sensitive (±3%).
        assert!(1.0 - m.shape_factor(Precision::F32, 4.0) <= 0.031);
    }

    #[test]
    fn chain_latency_recovers_table3_within_noise() {
        let cfg = Config::mi300a();
        let m = model(&cfg);
        let mut rng = Rng::new(1);
        let op = lookup("V_MFMA_F32_16X16X32_FP8_FP8").unwrap();
        let measured = m.measure_chain_latency_ns(op, &mut rng);
        assert!(
            (measured - op.latency_ns).abs() / op.latency_ns < 0.01,
            "measured {measured} vs table {}",
            op.latency_ns
        );
    }
}
