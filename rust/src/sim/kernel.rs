//! Kernel descriptors: the unit of work the simulator executes.

use crate::config::Config;
use crate::hw::lds::{gemm_macro_tile, lds_bytes_per_wave};
use crate::isa::{primary_opcode, Precision};

/// Sparsity mode of a GEMM (paper §7 patterns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SparsityMode {
    Dense,
    /// 2:4 structured sparsity on the LHS only.
    SparseLhs,
    /// 2:4 on the RHS only.
    SparseRhs,
    /// 2:4 on both operands.
    SparseBoth,
}

impl SparsityMode {
    pub fn is_sparse(self) -> bool {
        self != SparsityMode::Dense
    }

    pub fn name(self) -> &'static str {
        match self {
            SparsityMode::Dense => "dense",
            SparsityMode::SparseLhs => "lhs",
            SparsityMode::SparseRhs => "rhs",
            SparsityMode::SparseBoth => "both",
        }
    }

    /// Inverse of [`SparsityMode::name`] — the one parse table the wire
    /// decoder and the CLI both use.
    pub fn parse(s: &str) -> Option<SparsityMode> {
        [
            SparsityMode::Dense,
            SparsityMode::SparseLhs,
            SparsityMode::SparseRhs,
            SparsityMode::SparseBoth,
        ]
        .into_iter()
        .find(|m| m.name() == s)
    }
}

/// Kernel class: the dense/2:4-structured GEMM family the paper
/// characterizes, or an AsyncSparse-style data-sparse SpMM whose
/// sparsity lives in the operand *values* (CSR-like irregular reuse,
/// per-lane load imbalance) rather than in a structured weight pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelClass {
    Gemm,
    Spmm,
}

impl KernelClass {
    pub fn name(self) -> &'static str {
        match self {
            KernelClass::Gemm => "gemm",
            KernelClass::Spmm => "spmm",
        }
    }

    /// Inverse of [`KernelClass::name`] — the parse table trace records
    /// and the CLI share.
    pub fn parse(s: &str) -> Option<KernelClass> {
        [KernelClass::Gemm, KernelClass::Spmm]
            .into_iter()
            .find(|c| c.name() == s)
    }
}

/// Default nonzero density (percent) of an SpMM operand when the
/// workload doesn't pin one: sparse-transformer attention masks and
/// pruned MLP blocks land around this regime.
pub const DEFAULT_SPMM_DENSITY_PCT: usize = 20;

/// A GEMM kernel launch: C[M,N] += A[M,K] x B[K,N] at `precision`,
/// repeated `iters` times on one stream (the paper's microbenchmark and
/// case-study unit).
#[derive(Debug, Clone)]
pub struct KernelDesc {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub precision: Precision,
    pub sparsity: SparsityMode,
    /// Iterations per launch (paper: 500 for microbenchmarks, 100 for
    /// concurrency experiments, 50 for sparsity).
    pub iters: usize,
    /// Dense GEMM or data-sparse SpMM (CSR-like A operand).
    pub class: KernelClass,
    /// Nonzero density of the sparse operand, in percent (100 for
    /// dense GEMM; only meaningful for [`KernelClass::Spmm`]).
    pub density_pct: usize,
}

impl KernelDesc {
    pub fn gemm(n: usize, precision: Precision) -> KernelDesc {
        KernelDesc {
            m: n,
            n,
            k: n,
            precision,
            sparsity: SparsityMode::Dense,
            iters: 100,
            class: KernelClass::Gemm,
            density_pct: 100,
        }
    }

    /// Data-sparse SpMM: C[M,N] += A_csr[M,K] x B[K,N] where A keeps
    /// `density_pct`% nonzeros in CSR form. Executed FLOPs scale with
    /// the density; the CSR gather defeats B-operand reuse and skews
    /// per-lane work (see [`KernelDesc::irregularity`]).
    pub fn spmm(
        n: usize,
        precision: Precision,
        density_pct: usize,
    ) -> KernelDesc {
        KernelDesc {
            density_pct: density_pct.clamp(1, 100),
            class: KernelClass::Spmm,
            ..KernelDesc::gemm(n, precision)
        }
    }

    /// Nonzero fraction of the sparse operand in `[0.01, 1.0]`.
    pub fn density(&self) -> f64 {
        self.density_pct as f64 / 100.0
    }

    /// Per-lane load-imbalance factor in `[0, 1)`: 0 for dense GEMM
    /// (every wavefront sees identical work); grows as SpMM rows get
    /// sparser — CSR row-length variance leaves some lanes idle while
    /// the longest row finishes (the AsyncSparse motivation). The DES
    /// widens its per-stream placement spread by this factor, and the
    /// solo cost model discounts issue efficiency with it.
    pub fn irregularity(&self) -> f64 {
        match self.class {
            KernelClass::Gemm => 0.0,
            KernelClass::Spmm => 0.6 * (1.0 - self.density()),
        }
    }

    pub fn with_iters(mut self, iters: usize) -> KernelDesc {
        self.iters = iters;
        self
    }

    pub fn with_sparsity(mut self, s: SparsityMode) -> KernelDesc {
        self.sparsity = s;
        self
    }

    pub fn with_shape(mut self, m: usize, n: usize, k: usize) -> KernelDesc {
        self.m = m;
        self.n = n;
        self.k = k;
        self
    }

    /// Dense-equivalent FLOPs of one iteration.
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// FLOPs actually executed. For sparse kernels this is governed by
    /// `realized_flop_fraction`: the rocSPARSE software path executes
    /// dense-equivalent math (~1.0 — the paper's "software-limited"
    /// finding, §9.1); a custom sparse-MFMA kernel would realize
    /// `flop_fraction` (0.5).
    pub fn executed_flops(&self, cfg: &Config) -> f64 {
        // Data sparsity skips zero rows outright (a custom SpMM kernel
        // walks nonzeros only); structured 2:4 is then governed by the
        // software path's realized fraction as for GEMM.
        let mut f = self.flops();
        if self.class == KernelClass::Spmm {
            f *= self.density();
        }
        if self.sparsity.is_sparse() {
            f *= cfg.sparsity.realized_flop_fraction;
        }
        f
    }

    /// HBM bytes per iteration: A + B streamed once, C written once
    /// (blocked GEMM re-reads grow with K/tile; folded into the cost
    /// model's miss term instead).
    pub fn hbm_bytes(&self, cfg: &Config) -> f64 {
        let eb = self.precision.bytes() as f64;
        let mut a = self.m as f64 * self.k as f64 * eb;
        let mut b = self.k as f64 * self.n as f64 * eb;
        if self.class == KernelClass::Spmm {
            // CSR A: values at density plus 4-byte column indices per
            // nonzero plus row pointers; the irregular column gather
            // defeats B-row reuse (re-reads ~25% of B).
            let nnz = self.m as f64 * self.k as f64 * self.density();
            a = nnz * (eb + 4.0) + (self.m as f64 + 1.0) * 4.0;
            b *= 1.25;
        }
        let c = self.m as f64 * self.n as f64 * 4.0; // f32 accumulator out
        let mem_frac = |sparse: bool| {
            if sparse {
                cfg.sparsity.mem_fraction
            } else {
                1.0
            }
        };
        let (fa, fb) = match self.sparsity {
            SparsityMode::Dense => (1.0, 1.0),
            SparsityMode::SparseLhs => (mem_frac(true), 1.0),
            SparsityMode::SparseRhs => (1.0, mem_frac(true)),
            SparsityMode::SparseBoth => (mem_frac(true), mem_frac(true)),
        };
        a * fa + b * fb + c
    }

    /// Working set for the L2 model (A + B + C resident bytes; CSR
    /// values + indices for the SpMM A operand).
    pub fn working_set(&self) -> f64 {
        let eb = self.precision.bytes() as f64;
        let a = match self.class {
            KernelClass::Gemm => (self.m * self.k) as f64 * eb,
            KernelClass::Spmm => {
                (self.m * self.k) as f64 * self.density() * (eb + 4.0)
            }
        };
        a + (self.k * self.n) as f64 * eb + (self.m * self.n) as f64 * 4.0
    }

    /// GEMM macro-tile side for this kernel.
    pub fn macro_tile(&self) -> usize {
        gemm_macro_tile(self.m.max(self.n))
    }

    /// Output-tile blocks per iteration (one wavefront each).
    pub fn blocks(&self) -> usize {
        let t = self.macro_tile();
        ((self.m + t - 1) / t) * ((self.n + t - 1) / t)
    }

    /// LDS staging bytes per wavefront.
    pub fn lds_per_wave(&self, cfg: &Config) -> usize {
        lds_bytes_per_wave(
            self.macro_tile(),
            16,
            self.precision.bytes().max(2),
            cfg.calib.lds_double_buffer,
        )
    }

    /// The MFMA opcode this kernel's inner loop issues.
    pub fn opcode(&self) -> &'static crate::isa::MfmaOpcode {
        primary_opcode(self.precision)
    }

    /// Aspect ratio M/N (Fig 3's sweep axis).
    pub fn aspect_ratio(&self) -> f64 {
        self.m as f64 / self.n as f64
    }

    /// Strongly rectangular shapes (paper §7.1.2's 512x2048x1024 case).
    pub fn is_rectangular(&self) -> bool {
        let dims = [self.m, self.n, self.k];
        let max = *dims.iter().max().unwrap() as f64;
        let min = *dims.iter().min().unwrap() as f64;
        max / min >= 2.0
    }

    pub fn label(&self) -> String {
        match self.class {
            KernelClass::Gemm => format!(
                "{}x{}x{} {} {}",
                self.m,
                self.n,
                self.k,
                self.precision.name(),
                self.sparsity.name()
            ),
            KernelClass::Spmm => format!(
                "spmm[{}%] {}x{}x{} {} {}",
                self.density_pct,
                self.m,
                self.n,
                self.k,
                self.precision.name(),
                self.sparsity.name()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_512_cubed() {
        let k = KernelDesc::gemm(512, Precision::F32);
        assert_eq!(k.flops(), 2.0 * 512.0_f64.powi(3));
    }

    #[test]
    fn rocsparse_path_executes_dense_equivalent_flops() {
        // The software-limited default (§9.1): no realized FLOP saving.
        let cfg = Config::mi300a();
        let k = KernelDesc::gemm(512, Precision::Fp8)
            .with_sparsity(SparsityMode::SparseLhs);
        assert_eq!(k.executed_flops(&cfg), k.flops());
        // A custom-kernel config realizes the hardware's 50%.
        let mut custom = cfg.clone();
        custom.sparsity.realized_flop_fraction = 0.5;
        assert_eq!(k.executed_flops(&custom), k.flops() * 0.5);
    }

    #[test]
    fn sparse_reduces_hbm_bytes_on_the_sparse_side_only() {
        let cfg = Config::mi300a();
        let dense = KernelDesc::gemm(512, Precision::Fp8);
        let lhs = dense.clone().with_sparsity(SparsityMode::SparseLhs);
        let both = dense.clone().with_sparsity(SparsityMode::SparseBoth);
        assert!(lhs.hbm_bytes(&cfg) < dense.hbm_bytes(&cfg));
        assert!(both.hbm_bytes(&cfg) < lhs.hbm_bytes(&cfg));
    }

    #[test]
    fn blocks_scale_with_size() {
        let thin = KernelDesc::gemm(256, Precision::F32);
        let thick = KernelDesc::gemm(2048, Precision::F32);
        assert_eq!(thin.blocks(), 16); // (256/64)^2
        assert_eq!(thick.blocks(), 64); // (2048/256)^2
    }

    #[test]
    fn rectangular_detection() {
        assert!(!KernelDesc::gemm(512, Precision::Fp8).is_rectangular());
        assert!(KernelDesc::gemm(512, Precision::Fp8)
            .with_shape(512, 2048, 1024)
            .is_rectangular());
    }

    #[test]
    fn spmm_scales_flops_and_bytes_with_density() {
        let cfg = Config::mi300a();
        let dense = KernelDesc::gemm(512, Precision::Fp8);
        let sp20 = KernelDesc::spmm(512, Precision::Fp8, 20);
        let sp50 = KernelDesc::spmm(512, Precision::Fp8, 50);
        // Executed work tracks the nonzero count.
        assert_eq!(sp20.executed_flops(&cfg), dense.flops() * 0.2);
        assert!(sp20.executed_flops(&cfg) < sp50.executed_flops(&cfg));
        // CSR metadata + gathered B: bytes shrink with density but a
        // sparser matrix is also more irregular.
        assert!(sp20.hbm_bytes(&cfg) < sp50.hbm_bytes(&cfg));
        assert!(sp20.irregularity() > sp50.irregularity());
        assert_eq!(dense.irregularity(), 0.0);
        // Density clamps to a sane percent range.
        assert_eq!(KernelDesc::spmm(512, Precision::Fp8, 0).density_pct, 1);
        assert_eq!(
            KernelDesc::spmm(512, Precision::Fp8, 400).density_pct,
            100
        );
        assert!(sp20.label().starts_with("spmm[20%]"));
        assert_eq!(KernelClass::parse("spmm"), Some(KernelClass::Spmm));
        assert_eq!(KernelClass::parse("conv"), None);
    }

    #[test]
    fn opcode_tile_matches_precision() {
        assert_eq!(
            KernelDesc::gemm(512, Precision::Fp8).opcode().tile.k,
            32
        );
        assert_eq!(
            KernelDesc::gemm(512, Precision::F32).opcode().tile.m,
            32
        );
    }
}
