//! Kernel descriptors: the unit of work the simulator executes.

use crate::config::Config;
use crate::hw::lds::{gemm_macro_tile, lds_bytes_per_wave};
use crate::isa::{primary_opcode, Precision};

/// Sparsity mode of a GEMM (paper §7 patterns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SparsityMode {
    Dense,
    /// 2:4 structured sparsity on the LHS only.
    SparseLhs,
    /// 2:4 on the RHS only.
    SparseRhs,
    /// 2:4 on both operands.
    SparseBoth,
}

impl SparsityMode {
    pub fn is_sparse(self) -> bool {
        self != SparsityMode::Dense
    }

    pub fn name(self) -> &'static str {
        match self {
            SparsityMode::Dense => "dense",
            SparsityMode::SparseLhs => "lhs",
            SparsityMode::SparseRhs => "rhs",
            SparsityMode::SparseBoth => "both",
        }
    }

    /// Inverse of [`SparsityMode::name`] — the one parse table the wire
    /// decoder and the CLI both use.
    pub fn parse(s: &str) -> Option<SparsityMode> {
        [
            SparsityMode::Dense,
            SparsityMode::SparseLhs,
            SparsityMode::SparseRhs,
            SparsityMode::SparseBoth,
        ]
        .into_iter()
        .find(|m| m.name() == s)
    }
}

/// A GEMM kernel launch: C[M,N] += A[M,K] x B[K,N] at `precision`,
/// repeated `iters` times on one stream (the paper's microbenchmark and
/// case-study unit).
#[derive(Debug, Clone)]
pub struct KernelDesc {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub precision: Precision,
    pub sparsity: SparsityMode,
    /// Iterations per launch (paper: 500 for microbenchmarks, 100 for
    /// concurrency experiments, 50 for sparsity).
    pub iters: usize,
}

impl KernelDesc {
    pub fn gemm(n: usize, precision: Precision) -> KernelDesc {
        KernelDesc {
            m: n,
            n,
            k: n,
            precision,
            sparsity: SparsityMode::Dense,
            iters: 100,
        }
    }

    pub fn with_iters(mut self, iters: usize) -> KernelDesc {
        self.iters = iters;
        self
    }

    pub fn with_sparsity(mut self, s: SparsityMode) -> KernelDesc {
        self.sparsity = s;
        self
    }

    pub fn with_shape(mut self, m: usize, n: usize, k: usize) -> KernelDesc {
        self.m = m;
        self.n = n;
        self.k = k;
        self
    }

    /// Dense-equivalent FLOPs of one iteration.
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// FLOPs actually executed. For sparse kernels this is governed by
    /// `realized_flop_fraction`: the rocSPARSE software path executes
    /// dense-equivalent math (~1.0 — the paper's "software-limited"
    /// finding, §9.1); a custom sparse-MFMA kernel would realize
    /// `flop_fraction` (0.5).
    pub fn executed_flops(&self, cfg: &Config) -> f64 {
        if self.sparsity.is_sparse() {
            self.flops() * cfg.sparsity.realized_flop_fraction
        } else {
            self.flops()
        }
    }

    /// HBM bytes per iteration: A + B streamed once, C written once
    /// (blocked GEMM re-reads grow with K/tile; folded into the cost
    /// model's miss term instead).
    pub fn hbm_bytes(&self, cfg: &Config) -> f64 {
        let eb = self.precision.bytes() as f64;
        let a = self.m as f64 * self.k as f64 * eb;
        let b = self.k as f64 * self.n as f64 * eb;
        let c = self.m as f64 * self.n as f64 * 4.0; // f32 accumulator out
        let mem_frac = |sparse: bool| {
            if sparse {
                cfg.sparsity.mem_fraction
            } else {
                1.0
            }
        };
        let (fa, fb) = match self.sparsity {
            SparsityMode::Dense => (1.0, 1.0),
            SparsityMode::SparseLhs => (mem_frac(true), 1.0),
            SparsityMode::SparseRhs => (1.0, mem_frac(true)),
            SparsityMode::SparseBoth => (mem_frac(true), mem_frac(true)),
        };
        a * fa + b * fb + c
    }

    /// Working set for the L2 model (A + B + C resident bytes).
    pub fn working_set(&self) -> f64 {
        let eb = self.precision.bytes() as f64;
        (self.m * self.k) as f64 * eb
            + (self.k * self.n) as f64 * eb
            + (self.m * self.n) as f64 * 4.0
    }

    /// GEMM macro-tile side for this kernel.
    pub fn macro_tile(&self) -> usize {
        gemm_macro_tile(self.m.max(self.n))
    }

    /// Output-tile blocks per iteration (one wavefront each).
    pub fn blocks(&self) -> usize {
        let t = self.macro_tile();
        ((self.m + t - 1) / t) * ((self.n + t - 1) / t)
    }

    /// LDS staging bytes per wavefront.
    pub fn lds_per_wave(&self, cfg: &Config) -> usize {
        lds_bytes_per_wave(
            self.macro_tile(),
            16,
            self.precision.bytes().max(2),
            cfg.calib.lds_double_buffer,
        )
    }

    /// The MFMA opcode this kernel's inner loop issues.
    pub fn opcode(&self) -> &'static crate::isa::MfmaOpcode {
        primary_opcode(self.precision)
    }

    /// Aspect ratio M/N (Fig 3's sweep axis).
    pub fn aspect_ratio(&self) -> f64 {
        self.m as f64 / self.n as f64
    }

    /// Strongly rectangular shapes (paper §7.1.2's 512x2048x1024 case).
    pub fn is_rectangular(&self) -> bool {
        let dims = [self.m, self.n, self.k];
        let max = *dims.iter().max().unwrap() as f64;
        let min = *dims.iter().min().unwrap() as f64;
        max / min >= 2.0
    }

    pub fn label(&self) -> String {
        format!(
            "{}x{}x{} {} {}",
            self.m,
            self.n,
            self.k,
            self.precision.name(),
            self.sparsity.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_512_cubed() {
        let k = KernelDesc::gemm(512, Precision::F32);
        assert_eq!(k.flops(), 2.0 * 512.0_f64.powi(3));
    }

    #[test]
    fn rocsparse_path_executes_dense_equivalent_flops() {
        // The software-limited default (§9.1): no realized FLOP saving.
        let cfg = Config::mi300a();
        let k = KernelDesc::gemm(512, Precision::Fp8)
            .with_sparsity(SparsityMode::SparseLhs);
        assert_eq!(k.executed_flops(&cfg), k.flops());
        // A custom-kernel config realizes the hardware's 50%.
        let mut custom = cfg.clone();
        custom.sparsity.realized_flop_fraction = 0.5;
        assert_eq!(k.executed_flops(&custom), k.flops() * 0.5);
    }

    #[test]
    fn sparse_reduces_hbm_bytes_on_the_sparse_side_only() {
        let cfg = Config::mi300a();
        let dense = KernelDesc::gemm(512, Precision::Fp8);
        let lhs = dense.clone().with_sparsity(SparsityMode::SparseLhs);
        let both = dense.clone().with_sparsity(SparsityMode::SparseBoth);
        assert!(lhs.hbm_bytes(&cfg) < dense.hbm_bytes(&cfg));
        assert!(both.hbm_bytes(&cfg) < lhs.hbm_bytes(&cfg));
    }

    #[test]
    fn blocks_scale_with_size() {
        let thin = KernelDesc::gemm(256, Precision::F32);
        let thick = KernelDesc::gemm(2048, Precision::F32);
        assert_eq!(thin.blocks(), 16); // (256/64)^2
        assert_eq!(thick.blocks(), 64); // (2048/256)^2
    }

    #[test]
    fn rectangular_detection() {
        assert!(!KernelDesc::gemm(512, Precision::Fp8).is_rectangular());
        assert!(KernelDesc::gemm(512, Precision::Fp8)
            .with_shape(512, 2048, 1024)
            .is_rectangular());
    }

    #[test]
    fn opcode_tile_matches_precision() {
        assert_eq!(
            KernelDesc::gemm(512, Precision::Fp8).opcode().tile.k,
            32
        );
        assert_eq!(
            KernelDesc::gemm(512, Precision::F32).opcode().tile.m,
            32
        );
    }
}
