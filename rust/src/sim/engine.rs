//! Processor-sharing discrete-event engine for concurrent stream
//! execution (paper §6: ACE concurrency; §7.2: sparsity under
//! contention).
//!
//! Each stream executes `iters` kernel launches back-to-back. A launch
//! has two phases:
//!
//!  * **launch** — command-processor/API path (non-executing; overlaps
//!    freely with other streams' work). The launch:work ratio is what
//!    produces the paper's 43-46% overlap efficiency at four streams.
//!  * **work** — wavefronts execute under processor sharing; each
//!    running stream progresses at `gain / slowdown` of its solo rate,
//!    where the slowdown term aggregates LDS saturation, L2 miss growth,
//!    and external contention (Fig 5b's sweep knob), per DESIGN.md §7.
//!
//! Per-stream placement bias (drawn once per stream, lognormal with
//! contention-scaled sigma) models which CUs/L2 partitions a stream
//! lands on; it drives the cross-stream CV and the fairness collapse at
//! eight streams (Fig 5a) without biasing aggregate throughput.
//!
//! ## Hot path (§Perf)
//!
//! The event loop is allocation-free in steady state: the slowdown
//! model is a pure function of the *set* of running streams, so rates
//! are memoized per running-set bitmask in a flat direct-indexed table
//! (small stream counts) and handed out as borrows — no per-event
//! clones, no per-event hashing for the common <= 16-stream case. All
//! per-run invariants the slowdown model consumes (the L2 model, each
//! stream's working set and isolated miss ratio, memory weights) are
//! precomputed once per run in `RunStatics` (private to this module).

use super::cost::CostModel;
use super::kernel::KernelDesc;
use crate::config::Config;
use crate::hw::lds::lds_utilization;
use crate::hw::L2Model;
use crate::util::rng::Rng;

/// Calibration preset for one experiment family. The paper itself
/// measures different contention regimes in §6.1, §6.2 and §7.2 (same
/// 512^3 GEMM, different harnesses); each figure's driver selects the
/// profile calibrated for its section (EXPERIMENTS.md records all).
#[derive(Debug, Clone)]
pub struct ConcurrencyProfile {
    /// Launch/API overhead per iteration, as a fraction of the stream's
    /// own solo work time (`launch_ref = false`) or of the 512^3 FP32
    /// reference work (`launch_ref = true`; used when co-scheduled
    /// kernels of different sizes share one command path, Fig 9).
    pub launch_ratio: f64,
    /// See `launch_ratio`.
    pub launch_ref: bool,
    /// Parallel launch lanes (command processors servicing the launch
    /// path). 2 on MI300A-class parts; launches queue when all busy.
    pub launch_lanes: usize,
    /// Multiplier on modeled solo work (rocBLAS-path efficiency).
    pub work_scale: f64,
    /// Saturating (LDS) contention coefficient.
    pub k_lds: f64,
    /// Linear (L2/bandwidth) contention coefficient.
    pub k_l2: f64,
    /// External contention-level coefficient (Fig 5b sweep).
    pub k_level: f64,
    /// Per-stream placement-bias sigma at full pressure.
    pub bias_sigma: f64,
    /// Per-iteration noise sigma.
    pub iter_sigma: f64,
    /// Occupancy-fragmentation boost for the dominant kernel (Fig 9).
    pub frag_boost: f64,
    /// Occupancy-fragmentation penalty floor for the small kernel.
    pub frag_penalty: f64,
    /// Concurrent harnesses enqueue without per-iteration sync, so the
    /// API/launch phase pipelines behind the previous iteration's work
    /// (the paper's §7.2 harness: per-stream time can drop below solo,
    /// letting aggregate scaling exceed the stream count).
    pub pipelined_launch: bool,
}

impl ConcurrencyProfile {
    /// §6.1 ACE scaling (Figs 4, 5a, 8): calibrated to 1.78-1.83x at 4
    /// streams, 2.79-2.87x at 8, overlap 43-46% -> 64-65%.
    pub fn ace() -> ConcurrencyProfile {
        ConcurrencyProfile {
            launch_ratio: 1.10,
            launch_ref: false,
            launch_lanes: 2,
            work_scale: 1.0,
            k_lds: 1.19,
            k_l2: 0.0,
            k_level: 0.0,
            bias_sigma: 0.70,
            iter_sigma: 0.03,
            frag_boost: 1.0,
            frag_penalty: 1.0,
            pipelined_launch: false,
        }
    }

    /// §6.1 contention sweep (Fig 5b): overlap ~60.4%, speedup
    /// 2.52-2.53x at 4 streams, fairness 0.263 -> 0.250.
    pub fn contention_sweep() -> ConcurrencyProfile {
        ConcurrencyProfile {
            launch_ratio: 0.52,
            launch_ref: false,
            launch_lanes: 2,
            work_scale: 1.0,
            k_lds: 0.30,
            k_l2: 0.04,
            k_level: 0.022,
            bias_sigma: 0.528,
            iter_sigma: 0.03,
            frag_boost: 1.0,
            frag_penalty: 1.0,
            pipelined_launch: false,
        }
    }

    /// §6.3 occupancy fragmentation (Fig 9): proportional allocation,
    /// near-unity 1:1 speedups, large-kernel exploitation at 4:1.
    pub fn fragmentation() -> ConcurrencyProfile {
        ConcurrencyProfile {
            launch_ratio: 4.36,
            launch_ref: true,
            launch_lanes: 2,
            work_scale: 1.0,
            k_lds: 0.10,
            k_l2: 0.02,
            k_level: 0.0,
            bias_sigma: 0.05,
            iter_sigma: 0.04,
            frag_boost: 5.0,
            frag_penalty: 0.0,
            pipelined_launch: false,
        }
    }

    /// §7.2 sparsity under contention (Fig 13): rocSPARSE/rocBLAS API
    /// path; calibrated to dense 59.98 -> 213.93 GFLOPS and sparse
    /// crossover at 4 streams.
    pub fn sparsity() -> ConcurrencyProfile {
        ConcurrencyProfile {
            launch_ratio: 0.36,
            launch_ref: false,
            launch_lanes: 2,
            work_scale: 205.0,
            k_lds: 0.64,
            k_l2: 0.0,
            k_level: 0.0,
            bias_sigma: 0.09,
            iter_sigma: 0.02,
            frag_boost: 1.0,
            frag_penalty: 1.0,
            pipelined_launch: true,
        }
    }

    /// §8 case studies (Figs 14-16): moderate contention, visible
    /// variability.
    pub fn case_study() -> ConcurrencyProfile {
        ConcurrencyProfile {
            launch_ratio: 0.8,
            launch_ref: false,
            launch_lanes: 2,
            work_scale: 1.0,
            k_lds: 1.2,
            k_l2: 0.25,
            k_level: 0.0,
            bias_sigma: 0.28,
            iter_sigma: 0.05,
            frag_boost: 1.0,
            frag_penalty: 1.0,
            pipelined_launch: false,
        }
    }
}

/// Per-stream outcome.
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub label: String,
    /// Wall time of each iteration (launch + work), ns.
    pub iter_ns: Vec<f64>,
    pub start_ns: f64,
    pub end_ns: f64,
}

impl StreamOutcome {
    pub fn total_ns(&self) -> f64 {
        self.end_ns - self.start_ns
    }
}

/// Full concurrent-run result.
#[derive(Debug, Clone)]
pub struct ConcurrentRun {
    pub streams: Vec<StreamOutcome>,
    pub makespan_ns: f64,
    /// Fraction of makespan with >= 2 streams in their work phase
    /// (paper §4.2's overlap-efficiency definition).
    pub overlap_efficiency: f64,
    /// Per-stream L2 miss ratio under this concurrency level.
    pub l2_miss: Vec<f64>,
    /// Mean LDS utilization across occupied CUs.
    pub lds_util: f64,
    /// Discrete events the engine processed (perf accounting: the
    /// JSON-emitting bencher reports events/sec from this).
    pub events: u64,
}

impl ConcurrentRun {
    pub fn per_stream_totals(&self) -> Vec<f64> {
        self.streams.iter().map(|s| s.total_ns()).collect()
    }

    /// Aggregate dense-equivalent GFLOPS given each stream's per-iter
    /// FLOPs.
    pub fn aggregate_gflops(&self, flops_per_iter: &[f64]) -> f64 {
        let total_flops: f64 = self
            .streams
            .iter()
            .zip(flops_per_iter)
            .map(|(s, f)| s.iter_ns.len() as f64 * f)
            .sum();
        total_flops / self.makespan_ns
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Launching { until: f64 },
    Running { remaining: f64 }, // in solo-work ns
    Done,
}

struct StreamState {
    kernel: KernelDesc,
    phase: Phase,
    iters_done: usize,
    iter_start: f64,
    bias: f64,
    solo_work_ns: f64,
    launch_ns: f64,
    outcome: StreamOutcome,
}

/// Per-stream constants the slowdown model consumes, precomputed once
/// per run (previously recomputed on every event).
struct StreamStatic {
    /// max(M, N): the LDS occupancy-class proxy.
    size_max: usize,
    /// Memory-pressure weight (sparse kernels exert less, §7.2).
    mem_w: f64,
    /// LDS-pressure weight (quadratic discount for sparse streams).
    sparse_w: f64,
    /// L2 working set, bytes.
    working_set: f64,
    /// Isolated (single-stream) L2 miss ratio for that working set.
    isolated_miss: f64,
}

/// Per-run invariants shared by every rate evaluation.
struct RunStatics {
    l2: L2Model,
    total_cus: usize,
    lds_bytes: usize,
    lds_double_buffer: f64,
    streams: Vec<StreamStatic>,
}

/// Rate memo keyed by running-set bitmask. For small stream counts a
/// flat direct-indexed table avoids hashing entirely; the map fallback
/// covers 17..=64 streams. Either way callers borrow the memoized
/// slice — the event loop never clones a rates vector.
enum RateMemo {
    Flat(Vec<Option<Box<[f64]>>>),
    Map(std::collections::HashMap<u64, Box<[f64]>>),
}

/// Direct-indexed memo bound: 2^16 slots (1 MiB of `Option<Box>` tags)
/// is the largest table worth paying for up front.
const MEMO_FLAT_STREAMS: usize = 16;

/// Grab the earliest-free launch lane at time `t` for a `dur`-ns
/// launch; returns the completion time. Lane frees are always finite,
/// and index selection uses a plain `<` scan — no
/// `partial_cmp().unwrap()` NaN hazard on the hot path.
fn grab_lane(lanes: &mut [f64], t: f64, dur: f64) -> f64 {
    let mut idx = 0usize;
    for j in 1..lanes.len() {
        if lanes[j] < lanes[idx] {
            idx = j;
        }
    }
    let start = lanes[idx].max(t);
    lanes[idx] = start + dur;
    start + dur
}

/// The engine.
pub struct Engine<'a> {
    cfg: &'a Config,
    profile: ConcurrencyProfile,
    /// External contention level (Fig 5b sweep, 0-5).
    pub contention_level: f64,
}

impl<'a> Engine<'a> {
    pub fn new(cfg: &'a Config, profile: ConcurrencyProfile) -> Engine<'a> {
        Engine { cfg, profile, contention_level: 0.0 }
    }

    /// Contention pressure in [0,1] for a stream count (drives the
    /// bias sigma: 4 streams ~0.43, 8 streams 1.0). Public so the
    /// analytic backend's order-statistics tail uses the exact same
    /// sigma scaling the DES draws with.
    pub fn pressure(n_streams: usize) -> f64 {
        ((((n_streams as f64) - 1.0) / 7.0).clamp(0.0, 1.0)).powf(0.6)
    }

    /// Rates (`gain / slowdown`) for every stream in `running`, in
    /// `running` order. The slowdown term aggregates LDS saturation
    /// (clustering-aware per-CU occupancy, saturating the way Fig 7
    /// measures), L2 miss growth relative to isolated, and external
    /// contention; sparse streams both exert and feel less pressure
    /// (weights precomputed in [`RunStatics`], calibrated to Fig 13's
    /// crossover).
    fn fill_rates(
        &self,
        running: &[usize],
        st: &RunStatics,
        gains: &[f64],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        let s = running.len();
        if s == 0 {
            return;
        }
        let max_n = running
            .iter()
            .map(|&i| st.streams[i].size_max)
            .max()
            .unwrap_or(512);
        let lds_sat = lds_utilization(
            max_n,
            s,
            st.total_cus,
            st.lds_bytes,
            st.lds_double_buffer,
        );
        let eff_streams: f64 =
            running.iter().map(|&i| st.streams[i].mem_w).sum();
        let eff = eff_streams.round().max(1.0) as usize;
        let conc = if s >= 2 { 1.0 } else { 0.0 };
        for &i in running {
            let ss = &st.streams[i];
            let grown = st.l2.miss_ratio(ss.working_set, eff);
            let l2_growth = ((grown / ss.isolated_miss) - 1.0).max(0.0)
                * ss.mem_w
                / self.cfg.calib.l2_miss_stream_slope;
            let slowdown = 1.0
                + self.profile.k_lds * lds_sat * ss.sparse_w * conc
                + self.profile.k_l2 * l2_growth
                + self.profile.k_level * self.contention_level;
            out.push(gains[i] / slowdown);
        }
    }

    /// Occupancy-fragmentation gain (Fig 9): proportional allocation
    /// plus idle-resource exploitation by the dominant kernel.
    fn frag_gain(&self, kernels: &[&KernelDesc], i: usize) -> f64 {
        if kernels.len() < 2 || self.profile.frag_boost == 1.0 {
            return 1.0;
        }
        // Size proxy: geometric mean of the GEMM dims (the paper labels
        // its pairs by size ratio: 2048^3 vs 512^3 = "4:1").
        let waves: Vec<f64> = kernels
            .iter()
            .map(|k| (k.m as f64 * k.n as f64 * k.k as f64).cbrt())
            .collect();
        let mine = waves[i];
        let max = waves.iter().cloned().fold(0.0, f64::max);
        let min = waves.iter().cloned().fold(f64::INFINITY, f64::min);
        if max <= min * 1.5 {
            return 1.0; // balanced occupancy: no fragmentation effect
        }
        let imbalance = (1.0 - min / max).clamp(0.0, 1.0); // 0..1
        if mine >= max * 0.99 {
            1.0 + (self.profile.frag_boost - 1.0) * imbalance
        } else {
            1.0 - (1.0 - self.profile.frag_penalty) * imbalance
        }
    }

    /// Run `kernels` concurrently (one stream each). Deterministic for a
    /// given seed.
    pub fn run(&self, kernels: &[KernelDesc], seed: u64) -> ConcurrentRun {
        assert!(!kernels.is_empty());
        let cost = CostModel::new(self.cfg);
        let mut rng = Rng::new(seed ^ 0xace_c0de);
        let n = kernels.len();
        let pressure = Self::pressure(n);

        // Per-run invariants for the rate model (§Perf: previously
        // rebuilt per event — L2Model construction, working sets,
        // isolated miss ratios, memory weights).
        let statics = RunStatics {
            l2: cost.l2().clone(),
            total_cus: self.cfg.total_cus(),
            lds_bytes: self.cfg.lds_bytes_per_cu() as usize,
            lds_double_buffer: self.cfg.calib.lds_double_buffer,
            streams: kernels
                .iter()
                .map(|k| {
                    let ws = k.working_set();
                    StreamStatic {
                        size_max: k.m.max(k.n),
                        mem_w: if k.sparsity.is_sparse() {
                            self.cfg.sparsity.mem_fraction
                        } else {
                            1.0
                        },
                        sparse_w: if k.sparsity.is_sparse() {
                            self.cfg.sparsity.mem_fraction.powi(2)
                        } else {
                            1.0
                        },
                        working_set: ws,
                        isolated_miss: cost.l2().isolated_miss(ws),
                    }
                })
                .collect(),
        };

        // Reference work: 512^3 FP32 solo (launch_ratio is relative to
        // it); only needed by launch_ref profiles.
        let ref_work = if self.profile.launch_ref {
            cost.solo_work_ns(&KernelDesc::gemm(
                512,
                crate::isa::Precision::F32,
            )) * self.profile.work_scale
        } else {
            0.0
        };

        // Launches serialize through shared command/driver lanes: a
        // stream's launch occupies one lane for its launch_ns (the
        // mechanism behind the paper's moderate overlap efficiencies).
        // Initial launches queue in stream order, and each stream's
        // phase is final from construction (no NaN placeholder).
        let mut lanes = vec![0.0f64; self.profile.launch_lanes.max(1)];
        let mut streams: Vec<StreamState> = Vec::with_capacity(n);
        for (i, k) in kernels.iter().enumerate() {
            let mut srng = rng.fork(i as u64 + 1);
            let mem_w = statics.streams[i].mem_w;
            // Placement bias covers the whole iteration path
            // (launch + work): which ACE/driver lane and which
            // CU/L2 partition the stream landed on. Data-sparse SpMM
            // streams widen the spread further: CSR row-length
            // variance makes a stream's effective speed depend on
            // which rows its wavefronts drew (the fairness hazard the
            // AsyncSparse workloads exercise).
            let sigma = self.profile.bias_sigma
                * pressure
                * self.cfg.jitter_scale(k.precision)
                * mem_w
                * (1.0 + 0.02 * self.contention_level)
                * (1.0 + k.irregularity());
            let bias = srng.lognormal_unit(sigma);
            let solo = cost.solo_work_ns(k) * self.profile.work_scale;
            let launch = if self.profile.pipelined_launch && n >= 2 {
                // Continuous enqueue: launches hide behind prior work.
                0.0
            } else {
                let base = if self.profile.launch_ref {
                    ref_work
                } else {
                    solo
                };
                base * self.profile.launch_ratio * bias
            };
            let until = grab_lane(&mut lanes, 0.0, launch);
            streams.push(StreamState {
                kernel: k.clone(),
                phase: Phase::Launching { until },
                iters_done: 0,
                iter_start: 0.0,
                bias,
                solo_work_ns: solo,
                launch_ns: launch,
                outcome: StreamOutcome {
                    label: k.label(),
                    iter_ns: Vec::with_capacity(k.iters),
                    start_ns: 0.0,
                    end_ns: 0.0,
                },
            });
        }

        // Occupancy-fragmentation gains are static per run: the ACE
        // partitions CUs/bandwidth by what is resident overall (§6.3's
        // proportional allocation), not by instantaneous phase.
        let all_refs: Vec<&KernelDesc> = kernels.iter().collect();
        let static_gains: Vec<f64> = (0..n)
            .map(|i| self.frag_gain(&all_refs, i))
            .collect();

        let mut t = 0.0f64;
        let mut overlap_ns = 0.0f64;
        let mut iter_rng = rng.fork(0x17e7);
        let mut rate_memo = if n <= MEMO_FLAT_STREAMS {
            RateMemo::Flat(vec![None; 1usize << n])
        } else {
            RateMemo::Map(std::collections::HashMap::new())
        };
        // Reusable buffers: allocation-free event loop.
        let mut running: Vec<usize> = Vec::with_capacity(n);
        let mut scratch: Vec<f64> = Vec::with_capacity(n);
        let mut events = 0u64;
        let event_budget =
            10_000 + 64 * kernels.iter().map(|k| k.iters as u64).sum::<u64>();

        loop {
            events += 1;
            assert!(
                events < event_budget,
                "DES event budget exceeded (livelock?): t={t}, states={:?}",
                streams.iter().map(|s| s.phase).collect::<Vec<_>>()
            );
            // Active running set and rates, memoized per running-set
            // bitmask (the slowdown model is evaluated only the first
            // time a set appears; afterwards the memo hands out a
            // borrow).
            running.clear();
            running.extend((0..n).filter(|&i| {
                matches!(streams[i].phase, Phase::Running { .. })
            }));
            let rates: &[f64] = if n <= 64 {
                let mask: u64 =
                    running.iter().fold(0u64, |m, &i| m | (1 << i));
                let missing = match &rate_memo {
                    RateMemo::Flat(v) => v[mask as usize].is_none(),
                    RateMemo::Map(m) => !m.contains_key(&mask),
                };
                if missing {
                    let mut r = Vec::with_capacity(running.len());
                    self.fill_rates(&running, &statics, &static_gains, &mut r);
                    let r = r.into_boxed_slice();
                    match &mut rate_memo {
                        RateMemo::Flat(v) => v[mask as usize] = Some(r),
                        RateMemo::Map(m) => {
                            m.insert(mask, r);
                        }
                    }
                }
                match &rate_memo {
                    RateMemo::Flat(v) => v[mask as usize].as_deref().unwrap(),
                    RateMemo::Map(m) => &m[&mask],
                }
            } else {
                // >64 streams: masks overflow u64; recompute into a
                // reusable scratch buffer (still allocation-free).
                self.fill_rates(&running, &statics, &static_gains, &mut scratch);
                &scratch
            };

            // Next event time.
            let mut next = f64::INFINITY;
            for (pos, &i) in running.iter().enumerate() {
                if let Phase::Running { remaining } = streams[i].phase {
                    next = next.min(t + remaining / rates[pos]);
                }
            }
            for s in streams.iter() {
                if let Phase::Launching { until } = s.phase {
                    next = next.min(until);
                }
            }
            if !next.is_finite() {
                break; // all Done
            }

            let dt = next - t;
            if running.len() >= 2 {
                overlap_ns += dt;
            }
            // Progress running streams. Residuals below EPS (1 fs vs
            // µs-scale works) snap to zero — avoids a float livelock
            // where the residual is smaller than one ULP of `t`.
            const EPS: f64 = 1e-6;
            for (pos, &i) in running.iter().enumerate() {
                if let Phase::Running { remaining } = streams[i].phase {
                    let left = remaining - dt * rates[pos];
                    streams[i].phase = Phase::Running {
                        remaining: if left < EPS { 0.0 } else { left },
                    };
                }
            }
            t = next;

            // Fire transitions at time t.
            for i in 0..n {
                match streams[i].phase {
                    Phase::Launching { until } if until <= t + 1e-9 => {
                        let jitter =
                            iter_rng.lognormal_unit(self.profile.iter_sigma);
                        let work =
                            streams[i].solo_work_ns * streams[i].bias * jitter;
                        streams[i].phase = Phase::Running { remaining: work };
                    }
                    Phase::Running { remaining } if remaining <= 0.0 => {
                        let st = &mut streams[i];
                        st.outcome.iter_ns.push(t - st.iter_start);
                        st.iters_done += 1;
                        st.iter_start = t;
                        if st.iters_done >= st.kernel.iters {
                            st.phase = Phase::Done;
                            st.outcome.end_ns = t;
                        } else {
                            let until = grab_lane(&mut lanes, t, st.launch_ns);
                            st.phase = Phase::Launching { until };
                        }
                    }
                    _ => {}
                }
            }
        }

        let l2_miss: Vec<f64> = kernels
            .iter()
            .map(|k| statics.l2.miss_ratio(k.working_set(), n))
            .collect();
        let max_n = kernels.iter().map(|k| k.m.max(k.n)).max().unwrap();
        let lds_util = lds_utilization(
            max_n,
            n,
            self.cfg.total_cus(),
            self.cfg.lds_bytes_per_cu() as usize,
            self.cfg.calib.lds_double_buffer,
        );

        ConcurrentRun {
            streams: streams.into_iter().map(|s| s.outcome).collect(),
            makespan_ns: t,
            overlap_efficiency: if t > 0.0 { overlap_ns / t } else { 0.0 },
            l2_miss,
            lds_util,
            events,
        }
    }

    /// Solo baseline: the same kernel run alone (no bias pressure).
    pub fn run_solo(&self, kernel: &KernelDesc, seed: u64) -> ConcurrentRun {
        self.run(std::slice::from_ref(kernel), seed)
    }

    /// Makespan of running these kernels one-after-another (each solo,
    /// per-kernel derived seeds). This is the denominator context of the
    /// paper's Fig-4 metric; callers that already hold the concurrent
    /// run derive `speedup` from it without re-simulating.
    pub fn serial_makespan_ns(&self, kernels: &[KernelDesc], seed: u64) -> f64 {
        kernels
            .iter()
            .enumerate()
            .map(|(i, k)| {
                self.run_solo(k, seed.wrapping_add(i as u64)).makespan_ns
            })
            .sum()
    }

    /// Speedup of running these kernels concurrently vs one-after-another
    /// (the paper's Fig 4 metric).
    pub fn speedup(&self, kernels: &[KernelDesc], seed: u64) -> f64 {
        self.serial_makespan_ns(kernels, seed)
            / self.run(kernels, seed).makespan_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;

    fn fp32_512(iters: usize) -> KernelDesc {
        KernelDesc::gemm(512, Precision::F32).with_iters(iters)
    }

    #[test]
    fn solo_run_completes_all_iters() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let run = e.run_solo(&fp32_512(10), 1);
        assert_eq!(run.streams.len(), 1);
        assert_eq!(run.streams[0].iter_ns.len(), 10);
        assert!(run.makespan_ns > 0.0);
        assert_eq!(run.overlap_efficiency, 0.0, "no overlap with one stream");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let ks = vec![fp32_512(5); 4];
        let a = e.run(&ks, 7);
        let b = e.run(&ks, 7);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.per_stream_totals(), b.per_stream_totals());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn concurrency_beats_serial_but_sublinearly() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let ks = vec![fp32_512(20); 4];
        let sp = e.speedup(&ks, 3);
        assert!(sp > 1.2, "4 streams should beat serial: {sp}");
        assert!(sp < 4.0, "speedup must be sublinear: {sp}");
    }

    #[test]
    fn speedup_decomposes_into_serial_over_concurrent() {
        // serve derives speedup from one concurrent run + the serial
        // makespan; it must agree exactly with `speedup()`.
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let ks = vec![fp32_512(10); 4];
        let sp = e.speedup(&ks, 9);
        let derived =
            e.serial_makespan_ns(&ks, 9) / e.run(&ks, 9).makespan_ns;
        assert_eq!(sp, derived);
    }

    #[test]
    fn event_count_reported_and_bounded() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let run = e.run(&vec![fp32_512(10); 4], 2);
        // 4 streams x 10 iters produce 80 transitions; coincident
        // transitions may share a loop iteration, so bound loosely.
        assert!(run.events >= 4 * 10, "events = {}", run.events);
        assert!(run.events < 10_000, "events = {}", run.events);
    }

    #[test]
    fn map_memo_fallback_handles_many_streams() {
        // 17 streams exceeds the flat-memo bound and exercises the
        // HashMap path.
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let run = e.run(&vec![fp32_512(2); 17], 5);
        assert_eq!(run.streams.len(), 17);
        for s in &run.streams {
            assert_eq!(s.iter_ns.len(), 2);
        }
    }

    #[test]
    fn overlap_grows_with_streams() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let o4 = e.run(&vec![fp32_512(20); 4], 3).overlap_efficiency;
        let o8 = e.run(&vec![fp32_512(20); 8], 3).overlap_efficiency;
        assert!(o4 > 0.1 && o4 < 0.9, "overlap@4 = {o4}");
        assert!(o8 > o4, "overlap must grow with streams: {o8} vs {o4}");
    }

    #[test]
    fn contention_level_slows_streams_not_overlap() {
        let cfg = Config::mi300a();
        let mut e = Engine::new(&cfg, ConcurrencyProfile::contention_sweep());
        let ks = vec![fp32_512(20); 4];
        let base = e.run(&ks, 5);
        e.contention_level = 5.0;
        let loaded = e.run(&ks, 5);
        assert!(loaded.makespan_ns > base.makespan_ns);
        // Overlap efficiency stays roughly stable (paper Fig 5b).
        assert!((loaded.overlap_efficiency - base.overlap_efficiency).abs() < 0.08);
    }

    #[test]
    fn fragmentation_boosts_large_kernel() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::fragmentation());
        // Iteration counts equalized so both streams co-execute for the
        // whole window (the paper's §6.3 co-execution setup).
        let big = KernelDesc::gemm(2048, Precision::F32).with_iters(8);
        let small = fp32_512(8);
        let solo_big = e.run_solo(&big, 11).streams[0].total_ns();
        let pair = e.run(&[big.clone(), small.clone()], 11);
        let conc_big = pair.streams[0].total_ns();
        let speedup_big = solo_big / conc_big;
        assert!(
            speedup_big > 1.2,
            "4:1 imbalance should speed up the large kernel: {speedup_big}"
        );
        // The small kernel must not be boosted.
        let solo_small = e.run_solo(&small, 13).streams[0].total_ns();
        let conc_small = pair.streams[1].total_ns();
        assert!(solo_small / conc_small < 1.1);
    }

    #[test]
    fn eight_streams_less_fair_than_four() {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let spread = |n: usize| {
            let run = e.run(&vec![fp32_512(30); n], 17);
            let ts = run.per_stream_totals();
            let mean = ts.iter().sum::<f64>() / ts.len() as f64;
            let max = ts.iter().cloned().fold(0.0, f64::max);
            let min = ts.iter().cloned().fold(f64::INFINITY, f64::min);
            (max - min) / mean
        };
        assert!(
            spread(8) > spread(4),
            "imbalance must intensify at 8 streams"
        );
    }

    #[test]
    fn irregular_spmm_work_degrades_fairness() {
        // The AsyncSparse scenario: half the streams run data-sparse
        // SpMM (CSR row-length variance -> wider placement spread +
        // structurally different work), half run the dense GEMM. The
        // fairness machinery must see a less equitable set than the
        // homogeneous baseline, robustly across seeds.
        use crate::metrics::fairness::fairness;
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        let homog = vec![fp32_512(20); 4];
        let mix: Vec<KernelDesc> = (0..4)
            .map(|i| {
                if i % 2 == 0 {
                    KernelDesc::spmm(512, Precision::F32, 20)
                        .with_iters(20)
                } else {
                    fp32_512(20)
                }
            })
            .collect();
        let mean_fair = |ks: &[KernelDesc]| {
            (0..8u64)
                .map(|s| {
                    fairness(&e.run(ks, 100 + s).per_stream_totals())
                })
                .sum::<f64>()
                / 8.0
        };
        let fh = mean_fair(&homog);
        let fm = mean_fair(&mix);
        assert!((0.0..=1.0).contains(&fm) && (0.0..=1.0).contains(&fh));
        assert!(
            fm < fh,
            "irregular SpMM work must degrade fairness: mix {fm} vs \
             homogeneous {fh}"
        );
    }

    #[test]
    fn sparse_stream_exerts_less_pressure() {
        use crate::sim::kernel::SparsityMode;
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::sparsity());
        let dense = vec![fp32_512(10); 4];
        let sparse: Vec<KernelDesc> = (0..4)
            .map(|_| fp32_512(10).with_sparsity(SparsityMode::SparseLhs))
            .collect();
        let d = e.run(&dense, 23).makespan_ns;
        let s = e.run(&sparse, 23).makespan_ns;
        assert!(
            s < d,
            "sparse set (less L2/bw pressure + half FLOPs) should finish \
             sooner: sparse {s} vs dense {d}"
        );
    }
}
