//! Execution traces: convert a [`ConcurrentRun`] into per-stream
//! timelines and export Chrome-trace JSON (`chrome://tracing` /
//! Perfetto) — the visual counterpart of the paper's Fig 8/15 timeline
//! arguments.

use super::engine::ConcurrentRun;
use crate::util::json::Json;

/// One reconstructed iteration interval.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub stream: usize,
    pub iteration: usize,
    pub start_ns: f64,
    pub end_ns: f64,
}

/// Reconstruct per-iteration spans from a run's iteration durations
/// (iterations within a stream are back-to-back by construction).
pub fn spans(run: &ConcurrentRun) -> Vec<Span> {
    let mut out = Vec::new();
    for (si, stream) in run.streams.iter().enumerate() {
        let mut t = stream.start_ns;
        for (it, &dur) in stream.iter_ns.iter().enumerate() {
            out.push(Span {
                stream: si,
                iteration: it,
                start_ns: t,
                end_ns: t + dur,
            });
            t += dur;
        }
    }
    out
}

/// Chrome-trace JSON ("traceEvents" array of X events, µs timebase)
/// from an explicit span list plus one label per span. This is the
/// shared exporter: the engine path labels spans by stream, the replay
/// path (`crate::replay`) labels each recorded launch by its kernel.
pub fn chrome_trace_spans(spans: &[Span], labels: &[String]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .zip(labels)
        .map(|(s, label)| {
            Json::obj(vec![
                ("name", Json::Str(format!("iter {}", s.iteration))),
                ("cat", Json::Str("kernel".into())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num(s.start_ns / 1e3)),
                ("dur", Json::Num((s.end_ns - s.start_ns) / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.stream as f64)),
                ("args", Json::obj(vec![("label", Json::Str(label.clone()))])),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Chrome-trace JSON for a [`ConcurrentRun`], labelled per stream.
pub fn chrome_trace(run: &ConcurrentRun) -> Json {
    let sp = spans(run);
    let labels: Vec<String> = sp
        .iter()
        .map(|s| run.streams[s.stream].label.clone())
        .collect();
    chrome_trace_spans(&sp, &labels)
}

/// Utilization histogram: fraction of the makespan with exactly `k`
/// streams mid-iteration, for k = 0..=streams (the quantity behind
/// overlap efficiency).
pub fn concurrency_histogram(run: &ConcurrentRun) -> Vec<f64> {
    let n = run.streams.len();
    let spans = spans(run);
    let mut edges: Vec<(f64, i32)> = Vec::with_capacity(spans.len() * 2);
    for s in &spans {
        edges.push((s.start_ns, 1));
        edges.push((s.end_ns, -1));
    }
    edges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut hist = vec![0.0; n + 1];
    let mut active = 0i32;
    let mut last = 0.0;
    for (t, d) in edges {
        hist[(active.max(0) as usize).min(n)] += t - last;
        last = t;
        active += d;
    }
    if run.makespan_ns > last {
        hist[0] += run.makespan_ns - last;
    }
    for h in hist.iter_mut() {
        *h /= run.makespan_ns.max(1e-9);
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::isa::Precision;
    use crate::sim::{ConcurrencyProfile, Engine, KernelDesc};

    fn run() -> ConcurrentRun {
        let cfg = Config::mi300a();
        let e = Engine::new(&cfg, ConcurrencyProfile::ace());
        e.run(
            &vec![KernelDesc::gemm(512, Precision::F32).with_iters(5); 3],
            7,
        )
    }

    #[test]
    fn spans_cover_each_stream_contiguously() {
        let r = run();
        let sp = spans(&r);
        assert_eq!(sp.len(), 15);
        for si in 0..3 {
            let mine: Vec<&Span> =
                sp.iter().filter(|s| s.stream == si).collect();
            for w in mine.windows(2) {
                assert!((w[0].end_ns - w[1].start_ns).abs() < 1e-6,
                        "iterations must be back-to-back");
            }
            assert!((mine.last().unwrap().end_ns - r.streams[si].end_ns)
                .abs() < 1e-3);
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let r = run();
        let j = chrome_trace(&r);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.get("traceEvents").unwrap().as_arr().unwrap().len(),
            15
        );
    }

    #[test]
    fn histogram_sums_to_one_and_matches_overlap() {
        let r = run();
        let h = concurrency_histogram(&r);
        let total: f64 = h.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "fractions sum to 1: {total}");
        let overlap: f64 = h[2..].iter().sum();
        // Same quantity as the engine's overlap efficiency (within the
        // span-reconstruction approximation: spans include the launch
        // phase, the engine counts work phases only).
        assert!(overlap >= r.overlap_efficiency - 1e-6);
    }
}
