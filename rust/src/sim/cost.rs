//! Solo kernel cost model: how long one kernel iteration takes with the
//! whole machine to itself. The DES (engine.rs) scales this under
//! concurrency.

use super::kernel::KernelDesc;
use super::microbench::MicrobenchModel;
use crate::config::Config;
use crate::hw::{HbmModel, L2Model};

/// Roofline-style solo cost: work time is the max of the compute phase
/// (occupancy-dependent MFMA issue, per the Fig-2 model) and the memory
/// phase (HBM transfer at full bandwidth plus L2 miss exposure).
pub struct CostModel<'a> {
    cfg: &'a Config,
    micro: MicrobenchModel<'a>,
    hbm: HbmModel,
    l2: L2Model,
}

impl<'a> CostModel<'a> {
    pub fn new(cfg: &'a Config) -> CostModel<'a> {
        CostModel {
            cfg,
            micro: MicrobenchModel::new(cfg),
            hbm: HbmModel::new(cfg),
            l2: L2Model::new(cfg),
        }
    }

    /// The L2 model this cost model was built with. The DES borrows it
    /// so one run constructs the (anchor-interpolating) model exactly
    /// once instead of once per event (§Perf).
    pub fn l2(&self) -> &L2Model {
        &self.l2
    }

    /// The HBM model this cost model was built with.
    pub fn hbm(&self) -> &HbmModel {
        &self.hbm
    }

    /// Effective compute throughput (GFLOPS) of this kernel running
    /// alone: the occupancy model at the kernel's wavefront count, with
    /// the sparse pipeline efficiency applied to sparse kernels.
    pub fn solo_compute_gflops(&self, k: &KernelDesc) -> f64 {
        let waves = k.blocks().max(1);
        let mut gf = self.micro.throughput_gflops(k.precision, waves);
        gf *= self.micro.shape_factor(k.precision, k.aspect_ratio());
        if k.sparsity.is_sparse() {
            // The sparse pipeline's issue inefficiency (paper Fig 13b:
            // sparse solo 52.1 vs dense 59.98 GFLOPS => ~0.87).
            gf *= self.cfg.sparsity.sparse_pipe_eff;
        }
        // Data-sparse SpMM: CSR row-length variance leaves lanes idle
        // behind the longest row, so effective issue rate falls with
        // the kernel's irregularity (AsyncSparse's load-imbalance
        // finding; 0 for dense GEMM).
        gf /= 1.0 + k.irregularity();
        gf
    }

    /// Memory phase time (ns) for one iteration, solo.
    pub fn solo_mem_ns(&self, k: &KernelDesc) -> f64 {
        let bytes = k.hbm_bytes(self.cfg);
        let transfer = bytes / self.hbm.peak_bpns;
        let miss = self.l2.isolated_miss(k.working_set());
        // Exposed miss latency: a fraction of line fills stall the
        // pipeline; amortized per byte over the cache line.
        let stalls = miss * bytes / crate::hw::l2::CACHE_LINE as f64
            * self.cfg.calib.l2_miss_penalty_ns
            / (k.blocks().max(1) as f64 * self.cfg.calib.hide_half_waves);
        transfer + stalls
    }

    /// Solo work time (ns) for one iteration (excludes launch overhead,
    /// which the engine's profile owns).
    pub fn solo_work_ns(&self, k: &KernelDesc) -> f64 {
        let compute_ns = k.executed_flops(self.cfg) / self.solo_compute_gflops(k);
        compute_ns.max(self.solo_mem_ns(k))
    }

    /// Solo dense-equivalent GFLOPS (work phase only).
    pub fn solo_gflops(&self, k: &KernelDesc) -> f64 {
        k.flops() / self.solo_work_ns(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;
    use crate::sim::kernel::SparsityMode;

    #[test]
    fn bigger_gemm_takes_longer() {
        let cfg = Config::mi300a();
        let c = CostModel::new(&cfg);
        let t256 = c.solo_work_ns(&KernelDesc::gemm(256, Precision::F32));
        let t512 = c.solo_work_ns(&KernelDesc::gemm(512, Precision::F32));
        let t2048 = c.solo_work_ns(&KernelDesc::gemm(2048, Precision::F32));
        assert!(t256 < t512 && t512 < t2048);
        // Work grows faster than linear in n (n^3 FLOPs, sublinear rate
        // gain from more blocks).
        assert!(t2048 / t512 > 8.0);
    }

    #[test]
    fn fp8_faster_than_fp32_at_same_size() {
        let cfg = Config::mi300a();
        let c = CostModel::new(&cfg);
        let t8 = c.solo_work_ns(&KernelDesc::gemm(512, Precision::Fp8));
        let t32 = c.solo_work_ns(&KernelDesc::gemm(512, Precision::F32));
        assert!(t8 < t32, "FP8 {t8} should beat FP32 {t32}");
    }

    #[test]
    fn sparse_work_slightly_slower_than_dense() {
        // rocSPARSE path: dense-equivalent FLOPs through a ~0.87-
        // efficient pipe (paper Fig 13b: 52.1 vs 59.98 GFLOPS solo).
        let cfg = Config::mi300a();
        let c = CostModel::new(&cfg);
        let d = c.solo_work_ns(&KernelDesc::gemm(512, Precision::Fp8));
        let s = c.solo_work_ns(
            &KernelDesc::gemm(512, Precision::Fp8)
                .with_sparsity(SparsityMode::SparseLhs),
        );
        let ratio = d / s;
        assert!(
            (0.80..1.0).contains(&ratio),
            "dense/sparse work ratio {ratio} should be ~0.87"
        );
    }

    #[test]
    fn solo_gflops_finite_and_positive() {
        let cfg = Config::mi300a();
        let c = CostModel::new(&cfg);
        for p in Precision::SWEEP {
            for n in [256usize, 512, 2048] {
                let g = c.solo_gflops(&KernelDesc::gemm(n, p));
                assert!(g.is_finite() && g > 0.0, "{p} n={n}: {g}");
            }
        }
    }
}
