//! Tiny command-line argument parser (offline build: no clap).
//!
//! Supports `subcommand --flag value --switch positional` layouts, typed
//! accessors with defaults, and generated usage text. Each experiment
//! driver and example declares its options through [`Args`].

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, bare
/// `--switch` flags, and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_switches` lists flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_switches: &[&str],
    ) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if known_switches.contains(&name) {
                    args.switches.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        args.switches.push(name.to_string());
                    } else {
                        args.opts.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    args.switches.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env(known_switches: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_switches)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["verbose", "json"])
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("repro fig2 --seed 7 --streams 4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positional, vec!["fig2"]);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get_usize("streams", 1), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("json"));
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("sim --size=512 run");
        assert_eq!(a.get_usize("size", 0), 512);
        assert_eq!(a.get_or("missing", "x"), "x");
        assert_eq!(a.subcommand.as_deref(), Some("sim"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn trailing_switch_without_value() {
        let a = parse("run --json");
        assert!(a.flag("json"));
    }

    #[test]
    fn unknown_flag_followed_by_flag_becomes_switch() {
        let a = parse("run --fast --seed 3");
        assert!(a.flag("fast"));
        assert_eq!(a.get_u64("seed", 0), 3);
    }
}
