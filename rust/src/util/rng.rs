//! Deterministic pseudo-random number generation for the simulator.
//!
//! Every stochastic element of the DES (dispatch jitter, eviction
//! conflicts, contention noise) draws from a seeded [`Rng`] so experiment
//! runs are reproducible bit-for-bit given `--seed` (DESIGN.md §7).
//!
//! Implementation: xoshiro256** (Blackman & Vigna) seeded through
//! SplitMix64 — the reference parameterization, implemented in-repo
//! because the build is fully offline (no external `rand` crate).

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Derive an independent child generator (for per-stream RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough method; bias is
        // negligible for simulator purposes (n << 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal multiplicative jitter with E[x] = 1 and the given sigma
    /// (of the underlying normal). Used for contention-scaled noise: the
    /// mean is exactly 1 so jitter never biases throughput, only spread.
    pub fn lognormal_unit(&mut self, sigma: f64) -> f64 {
        if sigma <= 0.0 {
            return 1.0;
        }
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_unit_mean_is_one() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.lognormal_unit(0.3)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
        // sigma = 0 must be exactly 1 (no jitter path).
        assert_eq!(r.lognormal_unit(0.0), 1.0);
    }

    #[test]
    fn fork_is_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
