//! Minimal JSON parser/serializer.
//!
//! Used to read the AOT artifact manifest (`artifacts/manifest.json`,
//! written by `python/compile/aot.py`) and to emit machine-readable
//! experiment reports. Implemented in-repo because the build is fully
//! offline (no serde_json available in the vendor tree).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for our ASCII manifests; parse errors are explicit).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helper: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"name":"gemm","inputs":[{"shape":[128,128],"dtype":"float32"}]}],"format":"hlo-text"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": "hlo-text",
          "entries": [
            {"name": "gemm_fp8_128", "path": "gemm_fp8_128.hlo.txt",
             "inputs": [{"shape": [128, 128], "dtype": "float32"},
                         {"shape": [128, 128], "dtype": "float32"}],
             "outputs": [{"shape": [128, 128], "dtype": "float32"}]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("gemm_fp8_128"));
        let shape: Vec<usize> = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![128, 128]);
    }
}
