//! In-repo substrate utilities (the build is fully offline, so the RNG,
//! JSON, CLI, bench, and property-testing layers usually pulled from
//! crates.io are implemented — and tested — here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
