//! Micro-benchmark harness (offline build: no criterion).
//!
//! `cargo bench` targets declare `harness = false` and drive this module.
//! It mirrors the paper's measurement discipline (§4.2): warm-up
//! iterations are discarded, reported values are stable averages, and
//! variability is quantified with the coefficient of variation.
//!
//! ## Machine-readable baselines
//!
//! Each bench target writes a `BENCH_<name>.json` file (schema
//! `mi300a-char/bench-v1`, see [`Bencher::to_json`]) so perf
//! trajectories are diffable across PRs; PERF.md documents the schema
//! and records the current baseline. Smoke runs (CI) shrink the
//! iteration counts via `MI300A_BENCH_WARMUP` / `MI300A_BENCH_ITERS`.

use crate::util::json::Json;
use std::time::Instant;

/// One benchmark's summary statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn cv(&self) -> f64 {
        if self.mean_ns > 0.0 {
            self.std_ns / self.mean_ns
        } else {
            0.0
        }
    }

    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            0.0
        }
    }

    /// Work-unit rate: `units_per_iter` units of work per timed call
    /// (e.g. DES events per simulated point, points per sweep) over the
    /// mean iteration time. The per-backend bench reports events/sec
    /// and points/sec through this.
    pub fn units_per_sec(&self, units_per_iter: f64) -> f64 {
        units_per_iter * self.throughput_per_sec()
    }

    /// One `results[]` entry of the bench-v1 schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("std_ns", Json::Num(self.std_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            ("max_ns", Json::Num(self.max_ns)),
            ("cv", Json::Num(self.cv())),
            ("ops_per_sec", Json::Num(self.throughput_per_sec())),
        ])
    }
}

/// Benchmark runner: fixed warm-up then timed iterations.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, iters: 10, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters, results: Vec::new() }
    }

    /// Like [`Bencher::new`], with `MI300A_BENCH_WARMUP` /
    /// `MI300A_BENCH_ITERS` overriding the defaults — CI smoke runs set
    /// both to 1 so the bench targets stay exercised without costing a
    /// full measurement pass.
    pub fn from_env(warmup: usize, iters: usize) -> Self {
        let get = |key: &str, default: usize| {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n >= 1)
                .unwrap_or(default)
        };
        Bencher::new(
            get("MI300A_BENCH_WARMUP", warmup),
            get("MI300A_BENCH_ITERS", iters),
        )
    }

    /// Time `f` (one logical operation per call) and record the result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / samples.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_ns: samples.iter().cloned().fold(0.0, f64::max),
        };
        println!(
            "{:<52} {:>12.1} ns/iter (±{:>5.1}%, {} iters)",
            result.name,
            result.mean_ns,
            result.cv() * 100.0,
            result.iters
        );
        self.results.push(result.clone());
        result
    }

    /// Prevent the optimizer from discarding a computed value.
    #[inline]
    pub fn black_box<T>(x: T) -> T {
        std::hint::black_box(x)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Record an externally-measured result (e.g. the serve load
    /// generator's latency percentiles, which come from wall-clock
    /// samples rather than a `bench()` closure) so it lands in the same
    /// bench-v1 document as timed results.
    pub fn record(&mut self, r: BenchResult) {
        println!(
            "{:<52} {:>12.1} ns/iter (±{:>5.1}%, {} iters)",
            r.name,
            r.mean_ns,
            r.cv() * 100.0,
            r.iters
        );
        self.results.push(r);
    }

    /// All recorded results as a bench-v1 JSON document:
    ///
    /// ```text
    /// { "schema": "mi300a-char/bench-v1",
    ///   "bench": "<target name>",
    ///   "warmup": N, "iters": N,
    ///   "results": [ { "name", "iters", "mean_ns", "std_ns",
    ///                  "min_ns", "max_ns", "cv", "ops_per_sec" }, ... ],
    ///   "extra": { <target-specific derived metrics> } }
    /// ```
    pub fn to_json(&self, bench_name: &str, extra: Vec<(&str, Json)>) -> Json {
        Json::obj(vec![
            ("schema", Json::Str("mi300a-char/bench-v1".into())),
            ("bench", Json::Str(bench_name.into())),
            ("warmup", Json::Num(self.warmup as f64)),
            ("iters", Json::Num(self.iters as f64)),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
            ("extra", Json::obj(extra)),
        ])
    }

    /// Write `BENCH_<name>.json` into `MI300A_BENCH_OUT` (default: the
    /// working directory — `rust/` under `cargo bench`). Returns the
    /// path written.
    pub fn write_json(
        &self,
        bench_name: &str,
        extra: Vec<(&str, Json)>,
    ) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("MI300A_BENCH_OUT")
            .unwrap_or_else(|_| ".".to_string());
        let path = std::path::Path::new(&dir)
            .join(format!("BENCH_{bench_name}.json"));
        std::fs::write(&path, self.to_json(bench_name, extra).to_string_pretty())?;
        Ok(path)
    }

    /// Render all recorded results as a markdown table.
    pub fn markdown(&self) -> String {
        let mut out = String::from(
            "| benchmark | mean | cv | ops/s |\n|---|---:|---:|---:|\n",
        );
        for r in &self.results {
            out.push_str(&format!(
                "| {} | {} | {:.1}% | {:.0} |\n",
                r.name,
                fmt_ns(r.mean_ns),
                r.cv() * 100.0,
                r.throughput_per_sec()
            ));
        }
        out
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut b = Bencher::new(1, 5);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            Bencher::black_box(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        assert_eq!(b.results().len(), 1);
        // 10 units per iteration = 10x the op rate.
        assert!(
            (r.units_per_sec(10.0) - 10.0 * r.throughput_per_sec()).abs()
                < 1e-6
        );
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_ns(1.5e9), "1.50 s");
    }

    #[test]
    fn json_document_has_schema_results_and_extra() {
        let mut b = Bencher::new(0, 2);
        b.bench("x", || {});
        let j = b.to_json("hotpath", vec![("events_per_sec", Json::Num(42.0))]);
        assert_eq!(
            j.get("schema").unwrap().as_str(),
            Some("mi300a-char/bench-v1")
        );
        assert_eq!(j.get("bench").unwrap().as_str(), Some("hotpath"));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("x"));
        assert!(results[0].get("mean_ns").unwrap().as_f64().is_some());
        assert_eq!(
            j.get("extra").unwrap().get("events_per_sec").unwrap().as_f64(),
            Some(42.0)
        );
        // Round-trips through the in-repo parser.
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("hotpath"));
    }

    #[test]
    fn write_json_emits_bench_file() {
        // Default output dir is the cwd (no env mutation — tests run
        // multithreaded); clean up the artifact afterwards.
        let mut b = Bencher::new(0, 1);
        b.bench("y", || {});
        let path = b.write_json("selftest_smoke", vec![]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(path.ends_with("BENCH_selftest_smoke.json"));
        let j = Json::parse(&text).unwrap();
        assert_eq!(
            j.get("schema").unwrap().as_str(),
            Some("mi300a-char/bench-v1")
        );
    }

    #[test]
    fn markdown_has_all_rows() {
        let mut b = Bencher::new(0, 2);
        b.bench("a", || {});
        b.bench("b", || {});
        let md = b.markdown();
        assert!(md.contains("| a |") && md.contains("| b |"));
    }
}
