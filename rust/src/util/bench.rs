//! Micro-benchmark harness (offline build: no criterion).
//!
//! `cargo bench` targets declare `harness = false` and drive this module.
//! It mirrors the paper's measurement discipline (§4.2): warm-up
//! iterations are discarded, reported values are stable averages, and
//! variability is quantified with the coefficient of variation.

use std::time::Instant;

/// One benchmark's summary statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn cv(&self) -> f64 {
        if self.mean_ns > 0.0 {
            self.std_ns / self.mean_ns
        } else {
            0.0
        }
    }

    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            0.0
        }
    }
}

/// Benchmark runner: fixed warm-up then timed iterations.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, iters: 10, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters, results: Vec::new() }
    }

    /// Time `f` (one logical operation per call) and record the result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
            / samples.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_ns: samples.iter().cloned().fold(0.0, f64::max),
        };
        println!(
            "{:<52} {:>12.1} ns/iter (±{:>5.1}%, {} iters)",
            result.name,
            result.mean_ns,
            result.cv() * 100.0,
            result.iters
        );
        self.results.push(result.clone());
        result
    }

    /// Prevent the optimizer from discarding a computed value.
    #[inline]
    pub fn black_box<T>(x: T) -> T {
        std::hint::black_box(x)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all recorded results as a markdown table.
    pub fn markdown(&self) -> String {
        let mut out = String::from(
            "| benchmark | mean | cv | ops/s |\n|---|---:|---:|---:|\n",
        );
        for r in &self.results {
            out.push_str(&format!(
                "| {} | {} | {:.1}% | {:.0} |\n",
                r.name,
                fmt_ns(r.mean_ns),
                r.cv() * 100.0,
                r.throughput_per_sec()
            ));
        }
        out
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut b = Bencher::new(1, 5);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            Bencher::black_box(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_ns(1.5e9), "1.50 s");
    }

    #[test]
    fn markdown_has_all_rows() {
        let mut b = Bencher::new(0, 2);
        b.bench("a", || {});
        b.bench("b", || {});
        let md = b.markdown();
        assert!(md.contains("| a |") && md.contains("| b |"));
    }
}
