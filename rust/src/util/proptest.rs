//! Property-based testing harness (offline build: no proptest crate).
//!
//! `check(cases, seed, |g| ...)` runs a property over `cases` random
//! inputs drawn through a [`Gen`]; on failure it reports the failing
//! case's seed so the exact input is reproducible with `replay(seed)`.
//! A bisecting "shrink-lite" pass retries the property with progressively
//! smaller sizes drawn from the same sub-seed family.

use super::rng::Rng;

/// Random input generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0, 1]: properties should scale their input sizes by
    /// this so the shrink pass can retry "smaller" versions.
    pub size: f64,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Rng::new(seed), size, case_seed: seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// A size-scaled integer in [lo, hi]: shrinks toward lo.
    pub fn sized(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + (((hi - lo) as f64) * self.size).round() as usize;
        self.usize_in(lo, hi_eff.max(lo))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of a property: Ok, or a failure message.
pub type PropResult = Result<(), String>;

/// Convenience: build a failure.
pub fn fail(msg: impl Into<String>) -> PropResult {
    Err(msg.into())
}

/// Assert-style helper usable inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Run `prop` over `cases` random inputs derived from `seed`.
///
/// Panics (test failure) with the case seed and message on the first
/// failing case after attempting a shrink pass.
pub fn check<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen::new(case_seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink-lite: retry the same sub-seed family at smaller sizes
            // and report the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for step in 1..=8 {
                let size = 1.0 - step as f64 / 9.0;
                let mut g = Gen::new(case_seed, size);
                if let Err(m) = prop(&mut g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, \
                 smallest failing size {:.2}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Re-run a single failing case by seed (debugging helper).
pub fn replay<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let mut g = Gen::new(case_seed, 1.0);
    if let Err(msg) = prop(&mut g) {
        panic!("replayed case {case_seed:#x} failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(50, 1, |g| {
            count += 1;
            let x = g.usize_in(0, 100);
            if x <= 100 {
                Ok(())
            } else {
                fail("out of range")
            }
        });
        assert_eq!(count, 50 );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, 2, |g| {
            let x = g.usize_in(0, 100);
            if x < 95 {
                Ok(())
            } else {
                fail(format!("x = {x}"))
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seq1 = Vec::new();
        check(10, 3, |g| {
            seq1.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        let mut seq2 = Vec::new();
        check(10, 3, |g| {
            seq2.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        assert_eq!(seq1, seq2);
    }

    #[test]
    fn sized_shrinks_toward_lo() {
        let mut g_small = Gen::new(7, 0.0);
        for _ in 0..20 {
            assert_eq!(g_small.sized(3, 1000), 3);
        }
    }
}
