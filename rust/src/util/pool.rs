//! Minimal std-only scoped thread pool for deterministic parallel
//! sweeps (offline build: no rayon).
//!
//! The experiment drivers fan out *independent, seed-deterministic*
//! units of work — per-figure drivers, per-stream-count replications,
//! per-seed fairness repetitions. [`scoped_map`] runs such units across
//! worker threads and returns results **in item order**, so output is
//! byte-identical to the serial path no matter how the OS schedules the
//! workers (the determinism regression test in
//! `tests/parallel_determinism.rs` enforces this across 1/2/8 workers).
//!
//! Work distribution is a shared atomic cursor (work stealing degenerates
//! to self-balancing round-robin), which keeps long items — an 8-stream
//! DES run vs a 1-stream one — from serializing behind a static split.
//!
//! ## Worker budget (nested fan-out)
//!
//! Drivers size their inner fan-outs with [`default_workers`], and the
//! outer sweep (`experiments::run_all`) fans drivers out too. To keep
//! nesting from oversubscribing (outer N x inner N threads) — and to
//! make a `workers = 1` outer sweep *truly* serial end to end — the
//! pool carries a thread-local worker budget: `scoped_map` hands each
//! worker thread `budget / workers` (min 1), and the serial path runs
//! its items under the caller's requested budget. `default_workers`
//! returns the active budget when one is set, so inner `scoped_map` /
//! [`join`] calls inherit the division automatically.
//!
//! [`TaskPool`] is the third primitive: a *persistent* executor
//! (long-lived workers, fire-and-forget boxed tasks) for callers that
//! dispatch work continuously rather than mapping a known slice — the
//! serve reactor offloads request execution through one.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Worker budget imposed by an enclosing scoped_map/join, if any.
    static WORKER_BUDGET: Cell<Option<usize>> = Cell::new(None);
}

/// RAII guard: installs a worker budget on this thread, restoring the
/// previous value on drop (nested maps on one thread stay correct).
struct BudgetGuard(Option<usize>);

impl BudgetGuard {
    fn set(n: usize) -> BudgetGuard {
        BudgetGuard(WORKER_BUDGET.with(|b| b.replace(Some(n.max(1)))))
    }
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        let prev = self.0;
        WORKER_BUDGET.with(|b| b.set(prev));
    }
}

/// Worker count for parallel sweeps: the enclosing pool's budget if one
/// is active on this thread, else `MI300A_CHAR_THREADS` (>= 1), else
/// the machine's available parallelism.
pub fn default_workers() -> usize {
    if let Some(n) = WORKER_BUDGET.with(|b| b.get()) {
        return n.max(1);
    }
    if let Ok(v) = std::env::var("MI300A_CHAR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` with up to `workers` threads; results come back
/// in item order regardless of completion order. `workers <= 1` (or a
/// single item) short-circuits to a plain serial loop with zero thread
/// overhead — and pins the worker budget so nested maps inside `f`
/// honor the serial request.
pub fn scoped_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let budget = default_workers();
    let requested = workers.max(1);
    let workers = requested.min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 {
        // Serial path: a single-item map keeps the caller's concurrency
        // for nested work; an explicit workers<=1 request pins nested
        // fan-outs to serial too.
        let _guard = BudgetGuard::set(if requested <= 1 { 1 } else { budget });
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // Split the budget across workers so nested fan-outs never exceed
    // roughly `budget` threads in total.
    let inner_budget = (budget / workers).max(1);
    let cursor = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, R)>> =
        Mutex::new(Vec::with_capacity(items.len()));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                let _guard = BudgetGuard::set(inner_budget);
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                collected.lock().unwrap().extend(local);
            });
        }
    });
    let mut pairs = collected.into_inner().unwrap();
    pairs.sort_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, r)| r).collect()
}

/// Run two closures concurrently and return both results (`fa` on the
/// calling thread, `fb` on a scoped worker), splitting the active
/// worker budget between the sides. Degrades to strictly sequential
/// execution when the budget is 1 (e.g. inside a `workers = 1` sweep).
/// Panics propagate.
pub fn join<A, B, FA, FB>(fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    B: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B + Send,
{
    let budget = default_workers();
    if budget <= 1 {
        let a = fa();
        let b = fb();
        return (a, b);
    }
    let fb_budget = (budget / 2).max(1);
    let fa_budget = (budget - fb_budget).max(1);
    std::thread::scope(|s| {
        let hb = s.spawn(move || {
            let _guard = BudgetGuard::set(fb_budget);
            fb()
        });
        let a = {
            let _guard = BudgetGuard::set(fa_budget);
            fa()
        };
        let b = match hb.join() {
            Ok(b) => b,
            Err(e) => std::panic::resume_unwind(e),
        };
        (a, b)
    })
}

/// A small persistent executor: long-lived worker threads pulling boxed
/// tasks from a shared queue. Built for the serve reactor, which must
/// never run request execution on the event-loop thread — a slow DES
/// point parks a *worker*, not the reactor — but is generic enough for
/// any fire-and-forget fan-out. Dropping the pool closes the queue and
/// joins the workers after in-flight tasks finish.
pub struct TaskPool {
    tx: Option<std::sync::mpsc::Sender<Task>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

type Task = Box<dyn FnOnce() + Send + 'static>;

impl TaskPool {
    /// Spawn `workers` (min 1) threads named `task-pool-worker-{i}`.
    pub fn new(workers: usize) -> TaskPool {
        let workers = workers.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Task>();
        let rx = std::sync::Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = std::sync::Arc::clone(&rx);
            let h = std::thread::Builder::new()
                .name(format!("task-pool-worker-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only for the dequeue; the
                    // task itself runs unlocked so workers overlap.
                    let task = {
                        let guard =
                            rx.lock().unwrap_or_else(|e| e.into_inner());
                        guard.recv()
                    };
                    match task {
                        Ok(task) => task(),
                        Err(_) => break, // sender dropped: shutdown
                    }
                })
                .expect("spawn task-pool worker");
            handles.push(h);
        }
        TaskPool { tx: Some(tx), workers: handles }
    }

    /// Enqueue a task. Tasks run in roughly FIFO order across the
    /// workers; ordering between tasks is otherwise unspecified —
    /// callers needing per-key serialization (the reactor's
    /// one-in-flight-per-connection rule) enforce it themselves.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        if let Some(tx) = &self.tx {
            // Send only fails after shutdown began; dropping the task
            // is the correct behavior then.
            let _ = tx.send(Box::new(f));
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue: workers exit after draining
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_item_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = scoped_map(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 3
        });
        assert_eq!(out, (0..100).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_matches_serial() {
        let items: Vec<u64> = (0..64).collect();
        let f = |_: usize, &x: &u64| x.wrapping_mul(0x9E3779B97F4A7C15) >> 7;
        let serial = scoped_map(&items, 1, f);
        for workers in [2usize, 4, 16] {
            assert_eq!(scoped_map(&items, workers, f), serial);
        }
    }

    #[test]
    fn empty_and_single_item_inputs() {
        let none: Vec<i32> = vec![];
        assert!(scoped_map(&none, 4, |_, &x| x).is_empty());
        assert_eq!(scoped_map(&[7], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn join_runs_both_sides() {
        let (a, b) = join(|| 2 + 2, || "ok".to_string());
        assert_eq!(a, 4);
        assert_eq!(b, "ok");
    }

    #[test]
    fn default_workers_at_least_one() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn serial_map_pins_nested_budget_to_one() {
        // Inside a workers=1 map, nested code must see a budget of 1 —
        // that is what makes `run_all(cfg, 1)` truly serial end to end.
        let budgets = scoped_map(&[0, 1, 2], 1, |_, _| default_workers());
        assert_eq!(budgets, vec![1, 1, 1]);
        // And the budget must be restored afterwards.
        assert!(default_workers() >= 1);
    }

    #[test]
    fn parallel_map_divides_budget_across_workers() {
        // An outer 4-worker map over 4 items on whatever machine: each
        // worker's nested budget is budget/4 (min 1), never the full
        // machine width times 4.
        let outer = default_workers();
        let inner = scoped_map(&[(); 4], 4, |_, _| default_workers());
        for b in inner {
            assert!(b >= 1);
            assert!(
                b <= (outer / 4).max(1),
                "inner budget {b} exceeds fair share of outer {outer}"
            );
        }
    }

    #[test]
    fn join_inside_serial_map_is_sequential() {
        let flags = scoped_map(&[()], 1, |_, _| {
            // budget is pinned to 1 here, so join must not spawn.
            let (a, b) = join(|| default_workers(), || default_workers());
            (a, b)
        });
        assert_eq!(flags, vec![(1, 1)]);
    }

    #[test]
    fn task_pool_runs_all_tasks_and_joins_on_drop() {
        let counter = std::sync::Arc::new(AtomicUsize::new(0));
        {
            let pool = TaskPool::new(4);
            for _ in 0..64 {
                let c = std::sync::Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins the workers after the queue drains.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn uneven_work_still_complete() {
        // Items with wildly different costs must all be mapped once.
        let items: Vec<usize> = (0..20).collect();
        let out = scoped_map(&items, 4, |_, &x| {
            let spin = if x % 7 == 0 { 20_000 } else { 10 };
            let mut acc = 0u64;
            for i in 0..spin {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            x
        });
        assert_eq!(out, items);
    }
}
