//! Blocking native client for the versioned JSON-line protocol
//! (DESIGN.md §6). Used by the `mi300a-char client` subcommand, the
//! examples, and the integration tests — everything that talks to a
//! served instance goes through here instead of hand-rolled TCP strings.

use super::protocol::{Request, Response};
use crate::util::json::Json;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One connection to a serving instance. Requests are tagged with an
/// auto-incrementing `id`; [`Client::request`] verifies the echo so
/// pipelined connections cannot misattribute replies.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 1 })
    }

    /// Connect to a server that may still be binding its listener
    /// (retries every 5 ms up to `attempts` times).
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        attempts: usize,
    ) -> io::Result<Client> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Client::connect(addr.clone()) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::TimedOut, "no connect attempts")
        }))
    }

    /// Issue one typed request, returning the typed response (which may
    /// be [`Response::Error`] — protocol-level failures the server
    /// reported; transport failures surface as `io::Error`).
    pub fn request(&mut self, req: &Request) -> io::Result<Response> {
        self.request_opts(req, true)
    }

    /// Issue one typed request with an explicit cache mode. `cache:
    /// false` sends the `"cache":false` envelope escape hatch, so the
    /// server answers cold even when its result cache is warm (for
    /// measurement runs).
    pub fn request_opts(
        &mut self,
        req: &Request,
        cache: bool,
    ) -> io::Result<Response> {
        let (v, id) = self.request_json_opts(req, cache)?;
        let (resp, got) = Response::from_json(&v)
            .map_err(|e| invalid(format!("bad server response: {e}")))?;
        if got != Some(id) {
            return Err(invalid(format!(
                "response id mismatch: sent {id}, got {got:?}"
            )));
        }
        Ok(resp)
    }

    /// Issue one batch of typed sub-requests and return the per-item
    /// responses, item `k` answering `items[k]`. A server-side rejection
    /// of the batch envelope itself (e.g. over the item cap) surfaces
    /// as an `io::Error`; use [`Client::request`] with
    /// [`Request::Batch`] to receive it as a typed response instead.
    pub fn batch(&mut self, items: &[Request]) -> io::Result<Vec<Response>> {
        let req = Request::Batch { items: items.to_vec() };
        match self.request(&req)? {
            Response::Batch { items: got } => {
                // Mirror the id check in `request_opts`: positional
                // callers must never index past a short reply.
                if got.len() != items.len() {
                    return Err(invalid(format!(
                        "batch answered {} items for {} requests",
                        got.len(),
                        items.len()
                    )));
                }
                Ok(got)
            }
            Response::Error { code, message } => Err(invalid(format!(
                "batch rejected: {}: {message}",
                code.as_str()
            ))),
            other => Err(invalid(format!(
                "unexpected batch response type {:?}",
                other.type_name()
            ))),
        }
    }

    /// Issue one typed request and return the raw response JSON plus the
    /// id it was sent under (the `client` subcommand prints this
    /// verbatim).
    pub fn request_json(&mut self, req: &Request) -> io::Result<(Json, u64)> {
        self.request_json_opts(req, true)
    }

    /// [`Client::request_json`] with an explicit cache mode.
    pub fn request_json_opts(
        &mut self,
        req: &Request,
        cache: bool,
    ) -> io::Result<(Json, u64)> {
        let id = self.next_id;
        self.next_id += 1;
        writeln!(self.writer, "{}", req.to_json_opts(Some(id), cache))?;
        Ok((self.read_json_line()?, id))
    }

    /// Send one raw line (legacy text command or pre-encoded JSON) and
    /// read one JSON response line. Exists for protocol tests comparing
    /// framings; prefer [`Client::request`].
    pub fn raw_line(&mut self, line: &str) -> io::Result<Json> {
        writeln!(self.writer, "{line}")?;
        self.read_json_line()
    }

    fn read_json_line(&mut self) -> io::Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Json::parse(line.trim())
            .map_err(|e| invalid(format!("unparseable response: {e}")))
    }
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}
