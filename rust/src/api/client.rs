//! Blocking native client for the versioned JSON-line protocol
//! (DESIGN.md §6). Used by the `mi300a-char client`/`scenario`
//! subcommands, the examples, and the integration tests — everything
//! that talks to a served instance goes through here instead of
//! hand-rolled TCP strings.
//!
//! ## Timeouts
//!
//! Connect and read both default to [`DEFAULT_TIMEOUT`] (30 s), so a
//! dead or wedged server surfaces as an `io::ErrorKind::TimedOut`
//! error instead of a hang; [`Client::set_timeout`] adjusts or disables
//! it. After a read timeout the connection's framing state is
//! undefined — reconnect rather than reuse it. Job waits are the
//! exception: [`Client::wait_job`] polls (each poll bounded by the
//! timeout, the overall wait unbounded) and
//! [`Client::submit_and_wait`] disables the read timeout while blocked
//! on pushed progress frames, restoring it afterwards — long sweeps
//! are the whole point of the job API.
//!
//! ## Progress frames
//!
//! A server may interleave `{"type":"progress",…}` frames (keyed by the
//! submitting request's `id`) between response lines. The typed request
//! paths skip any stray frames automatically;
//! [`Client::submit_and_wait`] consumes them as a callback stream.

use super::job::JobView;
use super::protocol::{
    BackendInfo, ErrorCode, Request, RequestEnvelope, Response,
};
use super::scenario::ScenarioSpec;
use crate::util::json::Json;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Default connect/read timeout; see [`Client::set_timeout`].
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// Bounded retry policy for typed `overloaded` responses (DESIGN.md
/// §6.7): re-issue the request up to `attempts` further times, sleeping
/// `backoff` before the first retry and doubling it per attempt (capped
/// at 250 ms, like [`Client::wait_job`]'s poll backoff). Opt-in via
/// [`Client::set_overloaded_retry`]; the default client fails fast so
/// the CLI surfaces `overloaded` as the typed error it is. The
/// cluster coordinator turns it on for inter-node calls
/// (docs/cluster.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverloadedRetry {
    /// Further attempts after the first `overloaded` answer.
    pub attempts: u32,
    /// Sleep before the first retry; doubles per further attempt.
    pub backoff: Duration,
}

impl Default for OverloadedRetry {
    fn default() -> OverloadedRetry {
        OverloadedRetry { attempts: 3, backoff: Duration::from_millis(10) }
    }
}

/// One connection to a serving instance. Requests are tagged with an
/// auto-incrementing `id`; [`Client::request`] verifies the echo so
/// pipelined connections cannot misattribute replies.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    timeout: Option<Duration>,
    overloaded_retry: Option<OverloadedRetry>,
}

impl Client {
    /// Connect with the default timeout on every resolved address.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let mut last = None;
        for sock in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sock, DEFAULT_TIMEOUT) {
                Ok(stream) => return Client::from_stream(stream),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidInput,
                "address resolved to no socket addresses",
            )
        }))
    }

    fn from_stream(stream: TcpStream) -> io::Result<Client> {
        stream.set_read_timeout(Some(DEFAULT_TIMEOUT))?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
            next_id: 1,
            timeout: Some(DEFAULT_TIMEOUT),
            overloaded_retry: None,
        })
    }

    /// Connect to a server that may still be binding its listener
    /// (retries every 5 ms up to `attempts` times).
    pub fn connect_retry<A: ToSocketAddrs + Clone>(
        addr: A,
        attempts: usize,
    ) -> io::Result<Client> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Client::connect(addr.clone()) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::TimedOut, "no connect attempts")
        }))
    }

    /// Adjust (or with `None` disable) the per-read timeout.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        self.timeout = timeout;
        Ok(())
    }

    /// The active read timeout.
    pub fn timeout(&self) -> Option<Duration> {
        self.timeout
    }

    /// Opt in to (or with `None` restore the fail-fast default and
    /// disable) bounded retry-with-backoff on typed `overloaded`
    /// responses. Only the typed request paths
    /// ([`Client::request`]/[`Client::request_env`] and everything
    /// built on them) retry; the raw-JSON paths the `client`
    /// subcommand prints always surface the first answer verbatim.
    pub fn set_overloaded_retry(&mut self, retry: Option<OverloadedRetry>) {
        self.overloaded_retry = retry;
    }

    /// The active `overloaded` retry policy (`None` = fail fast).
    pub fn overloaded_retry(&self) -> Option<OverloadedRetry> {
        self.overloaded_retry
    }

    /// Issue one typed request, returning the typed response (which may
    /// be [`Response::Error`] — protocol-level failures the server
    /// reported; transport failures surface as `io::Error`).
    pub fn request(&mut self, req: &Request) -> io::Result<Response> {
        self.request_opts(req, true)
    }

    /// Issue one typed request with an explicit cache mode. `cache:
    /// false` sends the `"cache":false` envelope escape hatch, so the
    /// server answers cold even when its result cache is warm (for
    /// measurement runs).
    pub fn request_opts(
        &mut self,
        req: &Request,
        cache: bool,
    ) -> io::Result<Response> {
        self.request_env(
            req,
            &RequestEnvelope { cache, ..RequestEnvelope::default() },
        )
    }

    /// Issue one typed request with full envelope options — the cache
    /// escape hatch plus the `"backend"` selector (DESIGN.md §6.8). The
    /// envelope's `id` is ignored: the client assigns its own
    /// pipelining id and verifies the echo. When an
    /// [`OverloadedRetry`] policy is set, a typed `overloaded` answer
    /// is retried with exponential backoff before being surfaced.
    pub fn request_env(
        &mut self,
        req: &Request,
        env: &RequestEnvelope,
    ) -> io::Result<Response> {
        let mut left = self.overloaded_retry.map_or(0, |r| r.attempts);
        let mut wait = self
            .overloaded_retry
            .map_or(Duration::ZERO, |r| r.backoff);
        loop {
            let resp = self.request_env_once(req, env)?;
            let overloaded = matches!(
                resp,
                Response::Error { code: ErrorCode::Overloaded, .. }
            );
            if !overloaded || left == 0 {
                return Ok(resp);
            }
            left -= 1;
            std::thread::sleep(wait);
            wait = (wait * 2).min(Duration::from_millis(250));
        }
    }

    /// One send/receive round of [`Client::request_env`], no retries.
    fn request_env_once(
        &mut self,
        req: &Request,
        env: &RequestEnvelope,
    ) -> io::Result<Response> {
        let (v, id) = self.request_json_env(req, env)?;
        let (resp, got) = Response::from_json(&v)
            .map_err(|e| invalid(format!("bad server response: {e}")))?;
        if got != Some(id) {
            return Err(invalid(format!(
                "response id mismatch: sent {id}, got {got:?}"
            )));
        }
        Ok(resp)
    }

    /// Fetch the server's execution-backend registry (capability
    /// discovery; DESIGN.md §6.8).
    pub fn backends(&mut self) -> io::Result<Vec<BackendInfo>> {
        match self.request(&Request::Backends)? {
            Response::Backends { backends } => Ok(backends),
            Response::Error { code, message } => Err(invalid(format!(
                "backends rejected: {}: {message}",
                code.as_str()
            ))),
            other => Err(invalid(format!(
                "unexpected backends response type {:?}",
                other.type_name()
            ))),
        }
    }

    /// Issue one batch of typed sub-requests and return the per-item
    /// responses, item `k` answering `items[k]`. A server-side rejection
    /// of the batch envelope itself (e.g. over the item cap) surfaces
    /// as an `io::Error`; use [`Client::request`] with
    /// [`Request::Batch`] to receive it as a typed response instead.
    pub fn batch(&mut self, items: &[Request]) -> io::Result<Vec<Response>> {
        let req = Request::Batch { items: items.to_vec() };
        match self.request(&req)? {
            Response::Batch { items: got } => {
                // Mirror the id check in `request_opts`: positional
                // callers must never index past a short reply.
                if got.len() != items.len() {
                    return Err(invalid(format!(
                        "batch answered {} items for {} requests",
                        got.len(),
                        items.len()
                    )));
                }
                Ok(got)
            }
            Response::Error { code, message } => Err(invalid(format!(
                "batch rejected: {}: {message}",
                code.as_str()
            ))),
            other => Err(invalid(format!(
                "unexpected batch response type {:?}",
                other.type_name()
            ))),
        }
    }

    /// Submit a scenario as an async job. On acceptance the response is
    /// [`Response::Job`] (server-assigned id, state, 0/total points);
    /// rejections come back as the *typed* [`Response::Error`] — so a
    /// caller can tell the retryable `overloaded` case from a fatal
    /// `bad_range` without string-parsing. `progress: true` asks the
    /// server to push frames on this connection — pair it with
    /// [`Client::submit_and_wait`], or the frames are silently skipped
    /// by later reads.
    pub fn submit(
        &mut self,
        spec: &ScenarioSpec,
        progress: bool,
    ) -> io::Result<Response> {
        self.request(&Request::Submit { spec: spec.clone(), progress })
    }

    /// Poll a job to its terminal state, then fetch its result. Each
    /// poll is bounded by the read timeout; the overall wait is not
    /// (jobs are long-running by design). Polls back off exponentially
    /// (5 ms doubling to a 250 ms cap) so waiting on a long sweep does
    /// not hammer the server. Returns the `scenario` response, or the
    /// typed error response (`not_ready` after a cancel, `unknown_job`
    /// after eviction, …).
    pub fn wait_job(&mut self, job: u64) -> io::Result<Response> {
        let mut backoff = Duration::from_millis(5);
        loop {
            match self.request(&Request::JobStatus { job })? {
                Response::Job(view) if view.state.terminal() => break,
                Response::Job(_) => {
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(250));
                }
                resp @ Response::Error { .. } => return Ok(resp),
                other => {
                    return Err(invalid(format!(
                        "unexpected job_status response type {:?}",
                        other.type_name()
                    )))
                }
            }
        }
        self.request(&Request::JobResult { job })
    }

    /// Submit with progress push, stream every frame into
    /// `on_progress` (registration snapshot, queued→running, one per
    /// completed point, terminal), then fetch the result. A rejected
    /// submit returns its typed [`Response::Error`]. The read timeout
    /// is disabled while blocked on frames and restored afterwards.
    pub fn submit_and_wait(
        &mut self,
        spec: &ScenarioSpec,
        mut on_progress: impl FnMut(&JobView),
    ) -> io::Result<Response> {
        let submitted = match self.submit(spec, true)? {
            Response::Job(view) => view,
            resp @ Response::Error { .. } => return Ok(resp),
            other => {
                return Err(invalid(format!(
                    "unexpected submit response type {:?}",
                    other.type_name()
                )))
            }
        };
        let job = submitted.job;
        let prev = self.timeout;
        self.set_timeout(None)?;
        let mut failure: Option<io::Error> = None;
        loop {
            let v = match self.read_json_line() {
                Ok(v) => v,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            };
            if v.get("type").and_then(|t| t.as_str()) != Some("progress") {
                failure = Some(invalid(format!(
                    "unexpected frame while waiting for job {job}: {v}"
                )));
                break;
            }
            match Response::from_json(&v) {
                Ok((Response::Progress(view), _)) if view.job == job => {
                    on_progress(&view);
                    if view.state.terminal() {
                        break;
                    }
                }
                Ok(_) => {} // a frame for some other job: skip
                Err(e) => {
                    failure =
                        Some(invalid(format!("bad progress frame: {e}")));
                    break;
                }
            }
        }
        self.set_timeout(prev)?;
        if let Some(e) = failure {
            return Err(e);
        }
        self.request(&Request::JobResult { job })
    }

    /// Issue one typed request and return the raw response JSON plus the
    /// id it was sent under (the `client` subcommand prints this
    /// verbatim).
    pub fn request_json(&mut self, req: &Request) -> io::Result<(Json, u64)> {
        self.request_json_opts(req, true)
    }

    /// [`Client::request_json`] with an explicit cache mode.
    pub fn request_json_opts(
        &mut self,
        req: &Request,
        cache: bool,
    ) -> io::Result<(Json, u64)> {
        self.request_json_env(
            req,
            &RequestEnvelope { cache, ..RequestEnvelope::default() },
        )
    }

    /// [`Client::request_json`] with full envelope options (the
    /// envelope's `id` is replaced by the client's pipelining id).
    ///
    /// A top-level `scenario` request flattens its spec into the
    /// payload, so a spec-level `backend` and a *different* envelope
    /// `backend` cannot both be represented on the wire (one key). The
    /// server rejects that pair as `bad_request` when it can see both;
    /// the client refuses to encode it at all rather than silently
    /// sending whichever key survives.
    pub fn request_json_env(
        &mut self,
        req: &Request,
        env: &RequestEnvelope,
    ) -> io::Result<(Json, u64)> {
        if let Request::Scenario { spec } = req {
            if let (Some(a), Some(b)) = (spec.backend, env.backend) {
                if a != b {
                    return Err(invalid(format!(
                        "backend requested twice and disagreeing: the \
                         spec says {:?}, the envelope says {:?}",
                        a.as_str(),
                        b.as_str()
                    )));
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let env = RequestEnvelope { id: Some(id), ..*env };
        writeln!(self.writer, "{}", req.to_json_env(&env))?;
        Ok((self.read_response_json()?, id))
    }

    /// Send one raw line (legacy text command or pre-encoded JSON) and
    /// read one JSON response line. Exists for protocol tests comparing
    /// framings; prefer [`Client::request`].
    pub fn raw_line(&mut self, line: &str) -> io::Result<Json> {
        writeln!(self.writer, "{line}")?;
        self.read_json_line()
    }

    /// The next non-progress line: stray pushed frames (from a `submit`
    /// whose progress stream was not consumed) are skipped so they can
    /// never be misread as a response.
    fn read_response_json(&mut self) -> io::Result<Json> {
        loop {
            let v = self.read_json_line()?;
            if v.get("type").and_then(|t| t.as_str()) == Some("progress") {
                continue;
            }
            return Ok(v);
        }
    }

    fn read_json_line(&mut self) -> io::Result<Json> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "server closed the connection",
                ))
            }
            Ok(_) => {}
            // A read timeout (TimedOut on some platforms, WouldBlock on
            // others) becomes one typed, explanatory error instead of a
            // hang.
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock
                ) =>
            {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    format!(
                        "server did not answer within {:?} \
                         (Client::set_timeout adjusts or disables this)",
                        self.timeout
                    ),
                ))
            }
            Err(e) => return Err(e),
        }
        Json::parse(line.trim())
            .map_err(|e| invalid(format!("unparseable response: {e}")))
    }
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}
