//! The service core: every transport (CLI, TCP serve, client examples)
//! routes typed [`Request`]s through one [`Service`].
//!
//! The service owns the shared immutable [`Config`] (`Arc`, so
//! connection threads scale across cores the way the paper's ACEs scale
//! independent streams) and the one non-`Sync` resource — the PJRT
//! executor — isolated on a single worker thread behind an mpsc channel.
//! `run` requests serialize through that worker (like launches through a
//! command lane) without ever blocking the simulator paths.
//!
//! Input validation is typed: out-of-range values produce
//! [`ErrorCode::BadRange`] errors naming the accepted range (DESIGN.md
//! §6.3) instead of the pre-API behavior of silently clamping stream
//! counts and answering a different question.
//!
//! ## Caching
//!
//! The service embeds a [`ResultCache`] (see [`super::cache`]):
//! `sim`/`plan`/`sparsity` requests and `repro` of deterministic
//! registry entries are memoized under their canonical key, so a
//! repeated request returns a byte-identical response with zero DES
//! engine re-execution — provable through the `stats` request, whose
//! `engine_runs` counter only moves on cold executions. Batch items
//! route through the same path and therefore share the cache within
//! one call. [`Service::handle_opts`] with `use_cache: false` (the
//! wire `"cache":false` escape hatch) always runs cold.

use super::cache::{CachePolicy, CacheStats, ResultCache};
use super::protocol::{
    objective_name, ApiError, ErrorCode, ExperimentInfo, PlanGroup, Request,
    Response, MAX_BATCH_ITEMS,
};
use crate::config::Config;
use crate::coordinator::{decide_sparsity, Coordinator};
use crate::experiments;
use crate::isa::Precision;
use crate::metrics::fairness;
use crate::runtime::manifest::EntrySpec;
use crate::runtime::{Executor, Manifest};
use crate::sim::{ConcurrencyProfile, Engine, KernelDesc, SparsityMode};
use crate::sparsity::SpeedupModel;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Accepted `streams` range for `sim` requests (the DES models the
/// MI300A's hardware queues; beyond 16 the model is uncalibrated).
pub const SIM_STREAMS: (usize, usize) = (1, 16);
/// Accepted `streams` range for `plan` and `sparsity` requests.
pub const POOL_STREAMS: (usize, usize) = (1, 64);
/// Accepted GEMM size range for `sim`/`plan`/`sparsity` requests.
pub const SIZE_RANGE: (usize, usize) = (1, 16384);

/// A queued artifact execution: run `entry`, reply on `reply`.
struct ExecJob {
    entry: String,
    reply: mpsc::Sender<Result<RunOutcome, ApiError>>,
}

struct RunOutcome {
    entry: String,
    outputs: usize,
    checksum: f64,
    exec_ms: f64,
}

/// The single front door to the system. `Send + Sync`: share it behind
/// an `Arc` across connection threads.
pub struct Service {
    cfg: Arc<Config>,
    artifacts_dir: PathBuf,
    // The worker-channel sender lives behind a Mutex only to guarantee
    // `Sync` on every toolchain; senders are cloned out per request.
    exec_tx: Mutex<mpsc::Sender<ExecJob>>,
    cache: ResultCache,
    // Cold executions of a simulator/coordinator/driver path — the
    // engine-invocation counter `stats` reports. Cache hits never
    // touch it, which is what lets tests prove a repeat request did
    // zero re-execution.
    engine_runs: AtomicU64,
}

impl Service {
    /// Service over the default artifacts directory and cache policy.
    pub fn new(cfg: Config) -> Service {
        Service::with_options(
            cfg,
            Manifest::default_dir(),
            CachePolicy::default(),
        )
    }

    /// Service executing artifacts from `artifacts_dir` (default cache
    /// policy).
    pub fn with_artifacts_dir(cfg: Config, artifacts_dir: PathBuf) -> Service {
        Service::with_options(cfg, artifacts_dir, CachePolicy::default())
    }

    /// Service with an explicit result-cache policy (the CLI's
    /// `--no-cache` builds one from [`CachePolicy::disabled`]).
    pub fn with_cache_policy(cfg: Config, policy: CachePolicy) -> Service {
        Service::with_options(cfg, Manifest::default_dir(), policy)
    }

    /// Fully-explicit constructor. Spawns the executor worker thread;
    /// it exits when the service is dropped.
    pub fn with_options(
        cfg: Config,
        artifacts_dir: PathBuf,
        policy: CachePolicy,
    ) -> Service {
        let (tx, rx) = mpsc::channel::<ExecJob>();
        let worker_dir = artifacts_dir.clone();
        thread::Builder::new()
            .name("api-exec-worker".into())
            .spawn(move || exec_worker(&worker_dir, rx))
            .expect("spawn executor worker");
        Service {
            cfg: Arc::new(cfg),
            artifacts_dir,
            exec_tx: Mutex::new(tx),
            cache: ResultCache::new(policy),
            engine_runs: AtomicU64::new(0),
        }
    }

    /// The active (immutable) configuration.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load the artifact manifest (introspection; no execution).
    pub fn load_manifest(&self) -> Result<Manifest, String> {
        Manifest::load(&self.artifacts_dir)
    }

    /// Handle one typed request through the result cache. Never panics
    /// on bad input: every failure is a typed [`Response::Error`].
    pub fn handle(&self, req: &Request) -> Response {
        self.handle_opts(req, true)
    }

    /// Handle one typed request with an explicit cache mode.
    /// `use_cache: false` is the `"cache":false` / `--no-cache` escape
    /// hatch: the request always runs cold and counts neither a hit
    /// nor a miss. A batch fans its items through the same path, so
    /// identical items within one batch share the cache.
    pub fn handle_opts(&self, req: &Request, use_cache: bool) -> Response {
        if let Request::Batch { items } = req {
            // Mirror the wire decoder's 1..=MAX_BATCH_ITEMS contract for
            // programmatically built batches too.
            if items.is_empty() {
                return Response::from(ApiError::bad_request(
                    "batch: \"items\" must not be empty",
                ));
            }
            if items.len() > MAX_BATCH_ITEMS {
                return Response::from(ApiError::new(
                    ErrorCode::BadRange,
                    format!(
                        "batch items must be in 1..={MAX_BATCH_ITEMS} \
                         (got {})",
                        items.len()
                    ),
                ));
            }
            return Response::Batch {
                items: items
                    .iter()
                    .map(|item| self.handle_one(item, use_cache))
                    .collect(),
            };
        }
        self.handle_one(req, use_cache)
    }

    /// Result-cache counters (the `stats` request's `cache_*` fields).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Cold engine/driver executions so far (the `stats` request's
    /// `engine_runs` field).
    pub fn engine_runs(&self) -> u64 {
        self.engine_runs.load(Ordering::Relaxed)
    }

    /// One non-batch request: consult the cache when allowed, fall
    /// through to a cold execution, and memoize successful cacheable
    /// responses. Error responses are never cached.
    fn handle_one(&self, req: &Request, use_cache: bool) -> Response {
        let cold = |r: &Request| match self.try_handle(r) {
            Ok(resp) => resp,
            Err(e) => Response::from(e),
        };
        if use_cache && self.cacheable(req) {
            let key = req.cache_key();
            if let Some(resp) = self.cache.get(&key) {
                return resp;
            }
            let resp = cold(req);
            if !matches!(resp, Response::Error { .. }) {
                self.cache.insert(key, &resp);
            }
            return resp;
        }
        cold(req)
    }

    /// Whether `req` is a pure function of the immutable config:
    /// simulator/coordinator questions always are; `repro` is iff the
    /// registry entry is flagged deterministic; `run` (real PJRT
    /// execution), introspection, and `stats` never are.
    fn cacheable(&self, req: &Request) -> bool {
        match req {
            Request::Sim { .. }
            | Request::Plan { .. }
            | Request::Sparsity { .. } => true,
            Request::Repro { experiment } => experiments::spec(experiment)
                .map_or(false, |s| s.deterministic),
            Request::Run { .. }
            | Request::ListExperiments
            | Request::Config
            | Request::Batch { .. }
            | Request::Stats => false,
        }
    }

    /// Run the whole experiment registry with up to `workers` driver
    /// threads (the CLI's `repro all`; reports come back in registry
    /// order, byte-identical to a serial run).
    pub fn repro_all(
        &self,
        workers: usize,
    ) -> Vec<experiments::ExperimentReport> {
        experiments::run_all(&self.cfg, workers)
    }

    fn try_handle(&self, req: &Request) -> Result<Response, ApiError> {
        match req {
            Request::Sim { n, precision, streams } => {
                let n = check_range("n", *n, SIZE_RANGE)?;
                let streams = check_range("streams", *streams, SIM_STREAMS)?;
                self.engine_runs.fetch_add(1, Ordering::Relaxed);
                let engine = Engine::new(&self.cfg, ConcurrencyProfile::ace());
                let ks =
                    vec![KernelDesc::gemm(n, *precision).with_iters(50); streams];
                // One concurrent simulation per request: the speedup
                // derives from this run plus the (much cheaper) serial
                // solo makespans instead of re-simulating the set.
                let run = engine.run(&ks, self.cfg.seed);
                let speedup = engine.serial_makespan_ns(&ks, self.cfg.seed)
                    / run.makespan_ns;
                Ok(Response::Sim {
                    makespan_ms: run.makespan_ns / 1e6,
                    speedup_vs_serial: speedup,
                    overlap_efficiency: run.overlap_efficiency,
                    fairness: fairness(&run.per_stream_totals()),
                    l2_miss: run.l2_miss[0],
                    lds_util: run.lds_util,
                })
            }
            Request::Plan { objective, streams, n, precision } => {
                let streams = check_range("streams", *streams, POOL_STREAMS)?;
                let n = check_range("n", *n, SIZE_RANGE)?;
                self.engine_runs.fetch_add(1, Ordering::Relaxed);
                let pool = vec![
                    KernelDesc::gemm(n, *precision).with_iters(100);
                    streams
                ];
                let coord =
                    Coordinator::new(self.cfg.as_ref().clone(), *objective);
                let plan = coord.plan(&pool, true);
                Ok(Response::Plan {
                    objective: objective_name(*objective).to_string(),
                    sparse: plan.groups.iter().any(|g| {
                        g.kernels.iter().any(|k| k.sparsity.is_sparse())
                    }),
                    groups: plan
                        .groups
                        .iter()
                        .map(|g| PlanGroup {
                            kernels: g
                                .kernels
                                .iter()
                                .map(|k| k.label())
                                .collect(),
                            streams: g.streams,
                            expected_fairness: g.expected_fairness,
                            process_isolation: g.process_isolation,
                        })
                        .collect(),
                })
            }
            Request::Sparsity { n, streams } => {
                let n = check_range("n", *n, SIZE_RANGE)?;
                let streams = check_range("streams", *streams, POOL_STREAMS)?;
                self.engine_runs.fetch_add(1, Ordering::Relaxed);
                let k = KernelDesc::gemm(n, Precision::Fp8);
                let d = decide_sparsity(&k, streams, true);
                let model = SpeedupModel::new(&self.cfg);
                Ok(Response::Sparsity {
                    enable: d.enable,
                    reason: format!("{:?}", d.reason),
                    isolated_speedup: model
                        .isolated(&k, SparsityMode::SparseLhs)
                        .speedup(),
                    concurrent_speedup: model
                        .concurrent_per_stream(&k, streams.max(2)),
                })
            }
            Request::Run { entry } => {
                let (reply_tx, reply_rx) = mpsc::channel();
                let sender = self
                    .exec_tx
                    .lock()
                    .map_err(|_| {
                        ApiError::new(
                            ErrorCode::Runtime,
                            "executor worker lock poisoned",
                        )
                    })?
                    .clone();
                sender
                    .send(ExecJob { entry: entry.clone(), reply: reply_tx })
                    .map_err(|_| {
                        ApiError::new(
                            ErrorCode::Runtime,
                            "executor worker unavailable",
                        )
                    })?;
                let outcome = reply_rx.recv().map_err(|_| {
                    ApiError::new(
                        ErrorCode::Runtime,
                        "executor worker dropped",
                    )
                })??;
                Ok(Response::Run {
                    entry: outcome.entry,
                    outputs: outcome.outputs,
                    checksum: outcome.checksum,
                    exec_ms: outcome.exec_ms,
                })
            }
            Request::Repro { experiment } => {
                let spec =
                    experiments::spec(experiment).ok_or_else(|| {
                        ApiError::new(
                            ErrorCode::UnknownExperiment,
                            format!(
                                "unknown experiment {experiment:?} (ask \
                                 list_experiments for the registry)"
                            ),
                        )
                    })?;
                self.engine_runs.fetch_add(1, Ordering::Relaxed);
                let report = (spec.runner)(&self.cfg);
                Ok(Response::Repro {
                    experiment: spec.id.to_string(),
                    title: report.title.clone(),
                    report: report.json.clone(),
                    rendered: report.render(),
                })
            }
            Request::ListExperiments => Ok(Response::Experiments {
                experiments: experiments::REGISTRY
                    .iter()
                    .map(|s| ExperimentInfo {
                        id: s.id.to_string(),
                        title: s.title.to_string(),
                        section: s.section.to_string(),
                    })
                    .collect(),
            }),
            Request::Config => {
                Ok(Response::Config { config: self.cfg.to_json() })
            }
            Request::Stats => Ok(Response::Stats {
                cache: self.cache.stats(),
                engine_runs: self.engine_runs(),
            }),
            // Top-level batches are fanned out by `handle_opts`; a
            // batch reaching this point was nested inside another (the
            // wire decoder rejects that too).
            Request::Batch { .. } => {
                Err(ApiError::bad_request("batches do not nest"))
            }
        }
    }
}

fn check_range(
    what: &str,
    v: usize,
    (lo, hi): (usize, usize),
) -> Result<usize, ApiError> {
    if v < lo || v > hi {
        return Err(ApiError::new(
            ErrorCode::BadRange,
            format!("{what} must be in {lo}..={hi} (got {v})"),
        ));
    }
    Ok(v)
}

/// The executor worker: owns the (lazily created) PJRT executor for the
/// service lifetime and services `run` requests one at a time. Exits
/// when the service (the last sender) is dropped.
fn exec_worker(dir: &Path, rx: mpsc::Receiver<ExecJob>) {
    let mut exec: Option<Executor> = None;
    while let Ok(job) = rx.recv() {
        let result = run_artifact(dir, &mut exec, &job.entry);
        // A dropped reply sender just means the requester went away.
        let _ = job.reply.send(result);
    }
}

/// Execute one artifact with the deterministic input pattern. This is
/// the one place artifact-run logic lives; the CLI `run` subcommand and
/// the socket `run` request both land here.
fn run_artifact(
    dir: &Path,
    exec: &mut Option<Executor>,
    entry: &str,
) -> Result<RunOutcome, ApiError> {
    if exec.is_none() {
        *exec = Some(Executor::new(dir).map_err(|e| {
            ApiError::new(
                ErrorCode::Runtime,
                format!("{e} (run `make artifacts` first)"),
            )
        })?);
    }
    let exec = exec.as_mut().unwrap();
    let spec = exec
        .manifest
        .get(entry)
        .ok_or_else(|| {
            ApiError::new(
                ErrorCode::UnknownEntry,
                format!("unknown entry {entry:?} (see `mi300a-char list`)"),
            )
        })?
        .clone();
    let inputs = deterministic_inputs(&spec);
    let t0 = std::time::Instant::now();
    let out = exec
        .run_f32(entry, &inputs)
        .map_err(|e| ApiError::new(ErrorCode::Runtime, e.to_string()))?;
    Ok(RunOutcome {
        entry: entry.to_string(),
        outputs: out.len(),
        checksum: out.iter().map(|&v| v as f64).sum(),
        exec_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Deterministic inputs for an artifact entry — the same pattern the
/// golden tests use: input `i`, element `j` = `((j mod (13+i)) - 6) / 3`.
pub fn deterministic_inputs(spec: &EntrySpec) -> Vec<Vec<f32>> {
    spec.inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            (0..t.elements())
                .map(|j| ((j % (13 + i)) as f32 - 6.0) / 3.0)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> Service {
        Service::new(Config::mi300a())
    }

    #[test]
    fn sim_answers_with_physical_invariants() {
        let s = svc();
        match s.handle(&Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
        }) {
            Response::Sim { speedup_vs_serial, fairness, .. } => {
                assert!(
                    speedup_vs_serial > 1.0 && speedup_vs_serial < 4.0,
                    "speedup {speedup_vs_serial}"
                );
                assert!((0.0..=1.0).contains(&fairness));
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn out_of_range_streams_is_a_typed_range_error_not_a_clamp() {
        let s = svc();
        match s.handle(&Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 32,
        }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRange);
                assert!(message.contains("1..=16"), "{message}");
                assert!(message.contains("32"), "{message}");
            }
            other => panic!("expected a range error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_experiment_is_typed() {
        match svc().handle(&Request::Repro { experiment: "fig99".into() }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::UnknownExperiment)
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn list_experiments_mirrors_the_registry() {
        match svc().handle(&Request::ListExperiments) {
            Response::Experiments { experiments } => {
                assert_eq!(experiments.len(), experiments::REGISTRY.len());
                assert_eq!(experiments[0].id, "table1");
                assert!(!experiments[0].title.is_empty());
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn config_response_matches_the_active_config() {
        let s = svc();
        match s.handle(&Request::Config) {
            Response::Config { config } => {
                assert_eq!(config, s.config().to_json())
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn repeat_requests_hit_the_cache_with_zero_reexecution() {
        let s = svc();
        let req = Request::Sparsity { n: 512, streams: 4 };
        let cold = s.handle(&req);
        assert_eq!(s.engine_runs(), 1);
        let warm = s.handle(&req);
        assert_eq!(s.engine_runs(), 1, "second call must not re-execute");
        assert_eq!(cold, warm);
        assert_eq!(
            cold.to_json(None).to_string(),
            warm.to_json(None).to_string(),
            "cached response must re-serialize byte-identically"
        );
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }

    #[test]
    fn disabled_cache_always_runs_cold() {
        let s = Service::with_cache_policy(
            Config::mi300a(),
            super::CachePolicy::disabled(),
        );
        let req = Request::Sparsity { n: 512, streams: 4 };
        let a = s.handle(&req);
        let b = s.handle(&req);
        assert_eq!(a, b, "cold runs are still deterministic");
        assert_eq!(s.engine_runs(), 2);
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (0, 0, 0));
    }

    #[test]
    fn cache_false_escape_hatch_bypasses_a_warm_cache() {
        let s = svc();
        let req = Request::Sparsity { n: 512, streams: 4 };
        let warm = s.handle(&req);
        assert_eq!(s.engine_runs(), 1);
        let bypass = s.handle_opts(&req, false);
        assert_eq!(s.engine_runs(), 2, "bypass must run cold");
        assert_eq!(warm, bypass);
        let stats = s.cache_stats();
        // The bypass counted neither a hit nor a miss.
        assert_eq!((stats.hits, stats.misses), (0, 1));
    }

    #[test]
    fn error_responses_are_not_cached() {
        let s = svc();
        let req = Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 99,
        };
        for _ in 0..2 {
            match s.handle(&req) {
                Response::Error { code, .. } => {
                    assert_eq!(code, ErrorCode::BadRange)
                }
                other => panic!("unexpected response: {other:?}"),
            }
        }
        let stats = s.cache_stats();
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.misses, 2, "both attempts fell through");
    }

    #[test]
    fn oversized_batches_are_a_typed_range_error() {
        let s = svc();
        let items =
            vec![Request::Stats; super::MAX_BATCH_ITEMS + 1];
        match s.handle(&Request::Batch { items }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRange);
                assert!(
                    message.contains(&super::MAX_BATCH_ITEMS.to_string()),
                    "{message}"
                );
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn run_without_artifacts_is_a_typed_runtime_error() {
        let dir = std::env::temp_dir().join("mi300a_api_service_no_artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let s = Service::with_artifacts_dir(Config::mi300a(), dir);
        match s.handle(&Request::Run { entry: "gemm_fp8_128".into() }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::Runtime)
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }
}
